use ssr::model::{handle::KvCache, tokenizer, ModelHandle};
use ssr::runtime::literals::lit_f32;
use ssr::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let m = Manifest::load(&dir)?;
    let rt = Runtime::new(&dir)?;
    let target = ModelHandle::load(&m, "target")?;
    let v = m.vocab.clone();
    let prompt = tokenizer::prompt(&v, &tokenizer::tokenize_expr(&v, "23+4+9")?, None);
    let spec = &target.spec;
    let dims = spec.cache_dims(1);
    let n: usize = dims.iter().product();
    let zeros = vec![0f32; n];
    let mut cache = KvCache { k: lit_f32(&zeros, &dims)?, v: lit_f32(&zeros, &dims)?, batch: 1 };
    let out = target.ingest(&rt, &mut cache, &[0], &[prompt.clone()])?;
    let nl = &out.last_logits[0];
    let mut idx: Vec<usize> = (0..nl.len()).collect();
    idx.sort_by(|&a, &b| nl[b].partial_cmp(&nl[a]).unwrap());
    println!("ingest pos_out={} cnt={}", out.pos[0], out.cnt[0]);
    for &i in idx.iter().take(3) {
        println!("ingest top: {} {:.4}", v.names.get(&(i as i32)).map(|s| s.as_str()).unwrap_or("?"), nl[i]);
    }
    Ok(())
}
