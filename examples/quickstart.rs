//! Quickstart: load the AOT artifacts, solve one problem with every
//! inference method, and print what the SSR machinery did.
//!
//!     make artifacts && cargo run --release --example quickstart

use ssr::backend::pjrt::PjrtBackend;
use ssr::config::{SsrConfig, StopRule};
use ssr::coordinator::engine::{Engine, Method};
use ssr::workload::problems::problem_from_text;

fn main() -> anyhow::Result<()> {
    ssr::util::logging::init();
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut backend = PjrtBackend::load(&dir)?;
    backend.temp = 0.5;
    let vocab = backend.manifest().vocab.clone();

    let expr = std::env::args().nth(1).unwrap_or_else(|| "(31+17)*2-5".to_string());
    let problem = problem_from_text(&vocab, &expr)?;
    println!("problem: {expr}   (gold answer: {})\n", problem.answer);

    let methods = [
        Method::Baseline,
        Method::Parallel { n: 3, spm: true },
        Method::SpecReason { tau: 7 },
        Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
        Method::Ssr { n: 3, tau: 7, stop: StopRule::Fast2 },
    ];
    println!(
        "{:<18} {:>8} {:>8} {:>6} {:>9} {:>10} {:>9}",
        "method", "answer", "correct", "steps", "rewrites", "tok(d/t)", "model(s)"
    );
    for (i, m) in methods.into_iter().enumerate() {
        let mut engine = Engine::new(&mut backend, SsrConfig::default());
        let r = engine.run(&problem, m, 100 + i as u64)?;
        println!(
            "{:<18} {:>8} {:>8} {:>6} {:>9} {:>5}/{:<5} {:>8.2}",
            m.name(),
            r.answer().map(|a| a.to_string()).unwrap_or_else(|| "-".into()),
            r.answer() == Some(problem.answer),
            r.steps,
            r.rewrites,
            r.draft_tokens,
            r.target_tokens,
            r.model_secs,
        );
    }
    Ok(())
}
