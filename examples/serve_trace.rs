//! End-to-end serving driver (the DESIGN.md "e2e" experiment): load the
//! real draft/target pair, replay a batch trace of synth-math500
//! problems through the full SSR stack, and report accuracy, latency,
//! throughput, rewrite rate and normalized FLOPs — the serving-paper
//! headline run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_trace -- [n_requests] [method]
//!     methods: ssr (default) | baseline | spec-reason | parallel-spm

use std::time::Instant;

use ssr::backend::pjrt::PjrtBackend;
use ssr::backend::Backend;
use ssr::config::{SsrConfig, StopRule};
use ssr::coordinator::engine::{Engine, Method};
use ssr::coordinator::metrics::Metrics;
use ssr::util::stats;
use ssr::workload::{suites, traces};

fn main() -> anyhow::Result<()> {
    ssr::util::logging::init();
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let method = match std::env::args().nth(2).as_deref() {
        None | Some("ssr") => Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
        Some("baseline") => Method::Baseline,
        Some("spec-reason") => Method::SpecReason { tau: 7 },
        Some("parallel-spm") => Method::Parallel { n: 3, spm: true },
        Some(other) => anyhow::bail!("unknown method {other}"),
    };

    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut backend = PjrtBackend::load(&dir)?;
    backend.temp = 0.5;
    backend.warmup(3)?; // compile ahead of serving (see §Perf)
    let vocab = backend.manifest().vocab.clone();
    let suite = suites::generate(suites::spec("synth-math500")?, &vocab);
    let trace = traces::batch_trace(&suite, n, 0xE2E);

    println!("serving {} requests of synth-math500 with {}\n", trace.len(), method.name());
    let mut metrics = Metrics::new();
    let mut correct = 0usize;
    let t0 = Instant::now();
    let mut per_req = Vec::new();
    for req in &trace.requests {
        let rt0 = Instant::now();
        let mut engine = Engine::new(&mut backend, SsrConfig::default());
        let r = engine.run(&req.problem, method, req.id)?;
        let lat = rt0.elapsed().as_secs_f64();
        let ok = r.answer() == Some(req.problem.answer);
        correct += ok as usize;
        metrics.record_request(lat, r.answer().is_some());
        metrics.record_tokens(r.draft_tokens, r.target_tokens, r.steps, r.rewrites);
        per_req.push(lat);
        println!(
            "  req {:>3}: answer {:>4} gold {:>4} {} {:>5.2}s  ({} steps, {} rewrites)",
            req.id,
            r.answer().map(|a| a.to_string()).unwrap_or_else(|| "-".into()),
            req.problem.answer,
            if ok { "OK " } else { "ERR" },
            lat,
            r.steps,
            r.rewrites
        );
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let meta = backend.meta();

    println!("\n=== serve_trace summary ===");
    println!("requests          : {}", trace.len());
    println!("accuracy          : {:.1}%", 100.0 * correct as f64 / trace.len() as f64);
    println!("throughput        : {:.3} req/s", trace.len() as f64 / elapsed);
    println!("latency mean/p50/p99: {:.2}/{:.2}/{:.2} s",
        stats::mean(&per_req), stats::median(&per_req), stats::percentile(&per_req, 99.0));
    println!("rewrite rate R    : {:.2}", metrics.rewrite_rate());
    println!(
        "tokens draft/target: {}/{}  (alpha = {:.3})",
        metrics.draft_tokens, metrics.target_tokens, meta.alpha
    );
    println!(
        "model time        : {:.2}s of {:.2}s wall ({:.0}% in PJRT)",
        backend.clock_secs(),
        elapsed,
        100.0 * backend.clock_secs() / elapsed
    );
    let hist = backend.score_histogram();
    if hist.total() > 0 {
        println!("step-score dist   : {:?}", hist.fractions().iter().map(|f| (f * 100.0).round()).collect::<Vec<_>>());
    }
    Ok(())
}
