//! SPM deep-dive: how does the target model's own strategy selection
//! compare with random and oracle selection across problem families?
//! (the mechanism behind the paper's Fig. 4 gains).
//!
//!     cargo run --release --example strategy_explorer -- [pjrt|calibrated]

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::pjrt::PjrtBackend;
use ssr::backend::Backend;
use ssr::config::Selection;
use ssr::coordinator::spm;
use ssr::model::tokenizer;
use ssr::util::rng::Rng;
use ssr::workload::{strategies, suites};

fn main() -> anyhow::Result<()> {
    ssr::util::logging::init();
    let kind = std::env::args().nth(1).unwrap_or_else(|| "calibrated".into());
    let vocab = tokenizer::builtin_vocab();
    let suite = suites::generate(suites::spec("synth-livemath")?, &vocab);

    let mut backend: Box<dyn Backend> = match kind.as_str() {
        "pjrt" => {
            let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            Box::new(PjrtBackend::load(&dir)?)
        }
        _ => Box::new(CalibratedBackend::for_suite("synth-livemath", 1)?),
    };

    let meta = strategies::builtin_meta();
    println!("strategy pool (paper Appendix D):");
    for (i, name) in meta.names.iter().enumerate().take(12) {
        let style = meta.styles[i];
        println!(
            "  {} {:<26} -> {:<12} aptitude(add/mul/paren/mod) = {:?}",
            (b'A' + i as u8) as char,
            name,
            meta.style_names[style],
            meta.aptitude[style]
        );
    }

    let mut rng = Rng::new(7);
    println!("\nper-family selection quality (mean aptitude of n=5 picks):");
    println!("{:<10} {:>8} {:>8} {:>8}", "family", "model", "random", "oracle");
    for fam in ssr::workload::problems::FAMILIES {
        let probs: Vec<_> =
            suite.problems.iter().filter(|p| p.family == fam).take(12).collect();
        let (mut qm, mut qr, mut qo) = (0.0, 0.0, 0.0);
        for p in &probs {
            let sm = spm::select(backend.as_mut(), p, 12, 5, Selection::ModelTopN, &mut rng)?;
            let sr = spm::select(backend.as_mut(), p, 12, 5, Selection::Random, &mut rng)?;
            let so = spm::select(backend.as_mut(), p, 12, 5, Selection::Oracle, &mut rng)?;
            qm += spm::selection_quality(&sm, p);
            qr += spm::selection_quality(&sr, p);
            qo += spm::selection_quality(&so, p);
        }
        let n = probs.len() as f64;
        println!(
            "{:<10} {:>8.3} {:>8.3} {:>8.3}",
            fam.name(),
            qm / n,
            qr / n,
            qo / n
        );
    }

    println!("\nexample selections (model-internal scoring):");
    for p in suite.problems.iter().take(6) {
        let picked =
            spm::select(backend.as_mut(), p, 12, 5, Selection::ModelTopN, &mut rng)?;
        let letters: String =
            picked.iter().map(|&s| (b'A' + s as u8) as char).collect::<String>();
        println!(
            "  {} [{}]  ->  {letters}",
            tokenizer::detokenize(&vocab, &p.tokens),
            p.family.name(),
        );
    }
    Ok(())
}
