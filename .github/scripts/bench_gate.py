#!/usr/bin/env python3
"""Bench regression gate: diff two bench-report.json files (JSON lines,
one BENCH_JSON record per bench) and fail on a >10% drop of any shared
higher-is-better scalar.

Usage: bench_gate.py <previous-report> <current-report>

Records are matched on their "bench" field. A scalar is gated when its
key contains "throughput" — the convention the benches follow for
per-virtual-second rates, which are deterministic on the calibrated
substrate. Wall-clock-derived scalars (drain times, speedup ratios)
and workload-shaped counts are reported by the benches but never
gated: CI machine jitter would make a 10% bound on them flaky.

Exit codes: 0 = pass (or nothing comparable), 1 = regression.
"""

import json
import sys

THRESHOLD = 0.10  # fail when current < (1 - THRESHOLD) * previous


def load(path):
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            name = rec.get("bench")
            if isinstance(name, str):
                out[name] = rec
    return out


def gated_key(key):
    return "throughput" in key


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 1
    prev, cur = load(sys.argv[1]), load(sys.argv[2])
    shared = sorted(set(prev) & set(cur))
    if not shared:
        print("bench gate: no shared bench records; nothing to compare")
        return 0
    failures = []
    compared = 0
    for bench in shared:
        for key, old in sorted(prev[bench].items()):
            if not gated_key(key):
                continue
            new = cur[bench].get(key)
            if not isinstance(old, (int, float)) or not isinstance(new, (int, float)):
                continue
            if old <= 0:
                continue  # degenerate baseline: nothing meaningful to gate
            compared += 1
            change = (new - old) / old
            status = "OK"
            if new < (1.0 - THRESHOLD) * old:
                status = "FAIL"
                failures.append((bench, key, old, new, change))
            print(
                f"  [{status}] {bench}.{key}: {old:.4g} -> {new:.4g} "
                f"({change:+.1%})"
            )
    if not compared:
        print("bench gate: no comparable throughput scalars found")
        return 0
    if failures:
        print(f"\nbench gate: {len(failures)} regression(s) beyond {THRESHOLD:.0%}:")
        for bench, key, old, new, change in failures:
            print(f"  {bench}.{key}: {old:.4g} -> {new:.4g} ({change:+.1%})")
        return 1
    print(f"\nbench gate: {compared} scalar(s) within {THRESHOLD:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
