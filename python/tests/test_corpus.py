"""Corpus substrate invariants: every generated reasoning trace is
arithmetically correct, every suite is deterministic, and the grammar
round-trips."""

import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus

settings.register_profile("corpus", max_examples=200, deadline=None)
settings.load_profile("corpus")


def test_vocab_ids_disjoint():
    ids = list(corpus.TOKEN_NAMES)
    assert len(ids) == len(set(ids))
    assert max(ids) < corpus.VOCAB_SIZE
    assert corpus.STRAT0 + corpus.NUM_STRATEGIES <= corpus.VOCAB_SIZE


@given(st.integers(0, 2**32 - 1), st.integers(0, 3))
def test_problem_answers_match_evaluator(seed, family):
    rng = corpus.SplitMix64(seed)
    p = corpus.gen_problem(rng, family, 50, rng.range(2, 4))
    assert p.answer == corpus.ev(p.expr)
    assert p.family == family


@given(st.integers(0, 2**32 - 1), st.integers(0, 3),
       st.integers(0, corpus.NUM_STRATEGIES - 1))
def test_every_decomposition_reaches_the_answer(seed, family, strategy):
    """Whatever style decomposes the expression, the final value equals
    the exact evaluator's answer and every step is itself correct."""
    rng = corpus.SplitMix64(seed)
    p = corpus.gen_problem(rng, family, 40, rng.range(2, 4))
    style = corpus.style_for_strategy(strategy, rng)
    steps, answer = corpus.decompose(p.expr, style, rng)
    assert answer == p.answer
    assert len(steps) >= 1
    for lhs_tokens, value in steps:
        # each step's rendered lhs must evaluate to its claimed value
        assert _eval_tokens(lhs_tokens) == value


def _eval_tokens(toks):
    """Tiny evaluator over rendered token strings (parens + precedence)."""
    text = "".join(corpus.TOKEN_NAMES[t] for t in toks)
    return eval(text)  # trusted: our own generator output, digits/ops only


@given(st.integers(0, 2**32 - 1))
def test_training_example_well_formed(seed):
    rng = corpus.SplitMix64(seed)
    ex = None
    for _ in range(20):
        ex = corpus.sample_training_example(rng, 160)
        if ex is not None:
            break
    assert ex is not None
    toks, n = ex
    assert len(toks) == 160
    assert toks[0] == corpus.BOS and toks[1] == corpus.Q
    assert toks[n - 1] == corpus.EOS
    assert all(t == corpus.PAD for t in toks[n:])
    assert all(t != corpus.PAD for t in toks[:n])
    # exactly one strategy token, right after the first SEP
    strat_positions = [i for i, t in enumerate(toks[:n])
                       if corpus.STRAT0 <= t < corpus.STRAT0 + corpus.NUM_STRATEGIES]
    assert len(strat_positions) == 1
    assert toks[strat_positions[0] - 1] == corpus.SEP


def test_suites_deterministic_and_sized():
    for spec in corpus.SUITES:
        a = corpus.gen_suite(spec)
        b = corpus.gen_suite(spec)
        assert len(a) == spec.n_problems
        assert [p.answer for p in a] == [p.answer for p in b]
        assert [p.tokens() for p in a] == [p.tokens() for p in b]
        for p in a:
            assert 0 <= p.answer <= 999
            assert _eval_tokens(p.tokens()) == p.answer


def test_aptitude_shapes():
    for fam in range(4):
        apts = [corpus.strategy_aptitude(s, fam)
                for s in range(corpus.NUM_STRATEGIES)]
        assert all(0.0 < a <= 1.0 for a in apts)
    # the modular family is best served by the mod-reduce strategies
    assert corpus.strategy_aptitude(4, corpus.FAM_MODULAR) > \
        corpus.strategy_aptitude(2, corpus.FAM_MODULAR)


@given(st.integers(0, 2**32 - 1))
def test_splitmix_below_in_range(seed):
    rng = corpus.SplitMix64(seed)
    for n in (1, 2, 7, 100):
        x = rng.below(n)
        assert 0 <= x < n


def test_splitmix_reference_vector():
    """Pinned outputs — rust/src/util/rng.rs asserts the same vector."""
    rng = corpus.SplitMix64(42)
    got = [rng.next_u64() for _ in range(4)]
    assert got == [
        13679457532755275413,
        2949826092126892291,
        5139283748462763858,
        6349198060258255764,
    ], got


def test_prompt_tokens_shape():
    rng = corpus.SplitMix64(3)
    p = corpus.gen_problem(rng, corpus.FAM_MUL_MIX, 30, 2)
    with_strat = corpus.prompt_tokens(p, 5)
    without = corpus.prompt_tokens(p, None)
    assert with_strat[:-1] == without
    assert with_strat[-1] == corpus.STRAT0 + 5
    assert with_strat[0] == corpus.BOS
