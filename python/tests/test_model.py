"""L2 model invariants: prefill/decode equivalence, span & ingest cache
contracts, pallas-vs-ref lowering agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model

CFG = model.ModelConfig("t", n_layers=2, d_model=32, n_heads=2, s_max=64)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def _rand_tokens(key, b, s, lo=1, hi=36):
    return jax.random.randint(key, (b, s), lo, hi, jnp.int32)


def test_prefill_shapes(params):
    toks = _rand_tokens(jax.random.PRNGKey(1), 2, CFG.s_max)
    lens = jnp.array([10, 20], jnp.int32)
    logits, k, v = model.prefill(CFG, params, toks, lens)
    assert logits.shape == (2, CFG.s_max, CFG.vocab)
    assert k.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.s_max, CFG.d_head)
    assert v.shape == k.shape


def test_prefill_matches_decode_chain(params):
    """Prefill logits at position i == decode_step logits after feeding
    tokens 0..i — the fundamental KV-cache correctness invariant."""
    b = 2
    toks = _rand_tokens(jax.random.PRNGKey(2), b, CFG.s_max)
    lens = jnp.array([12, 9], jnp.int32)
    logits, _, _ = model.prefill(CFG, params, toks, lens)
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    k = jnp.zeros(shape)
    v = jnp.zeros(shape)
    pos = jnp.zeros((b,), jnp.int32)
    for i in range(12):
        lg, k, v = model.decode_step(CFG, params, k, v, pos, toks[:, i])
        for bb in range(b):
            if i < int(lens[bb]):
                np.testing.assert_allclose(
                    np.asarray(lg[bb]), np.asarray(logits[bb, i]),
                    atol=1e-4, rtol=1e-4)
        pos = pos + 1


def test_pallas_and_ref_agree_end_to_end(params):
    toks = _rand_tokens(jax.random.PRNGKey(3), 2, CFG.s_max)
    lens = jnp.array([15, 30], jnp.int32)
    lp, _, _ = model.prefill(CFG, params, toks, lens, use_pallas=True)
    lr, _, _ = model.prefill(CFG, params, toks, lens, use_pallas=False)
    valid = np.arange(CFG.s_max)[None, :] < np.asarray(lens)[:, None]
    np.testing.assert_allclose(
        np.asarray(lp)[valid], np.asarray(lr)[valid], atol=1e-4, rtol=1e-4)


def test_span_stops_at_delimiter(params):
    """With a rigged head that always emits SEP, span must take exactly
    one token and report done."""
    rig = dict(params)
    head = np.zeros((CFG.d_model, CFG.vocab), np.float32)
    head[:, corpus.SEP] = 1.0  # every position votes SEP
    rig["head"] = jnp.asarray(head)
    rig["ln_f_bias"] = jnp.ones((CFG.d_model,)) * 0.5  # keep x positive-ish
    b = 2
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    k = jnp.zeros(shape)
    v = jnp.zeros(shape)
    pos = jnp.array([3, 5], jnp.int32)
    cur = jnp.array([corpus.STEP, corpus.STEP], jnp.int32)
    toks, ntake, done, pos_out, _, _ = model.span(
        CFG, rig, k, v, pos, cur, jnp.float32(0.0), jnp.int32(0))
    assert list(np.asarray(ntake)) == [1, 1]
    assert list(np.asarray(done)) == [1, 1]
    assert list(np.asarray(toks[:, 0])) == [corpus.SEP, corpus.SEP]
    # one active iteration -> pos advanced by exactly 1
    assert list(np.asarray(pos_out)) == [4, 6]


def test_span_cache_contract(params):
    """span caches cur + all-but-last sampled tokens: replaying the same
    tokens through ingest from the same start state must produce an
    identical cache prefix."""
    b = 1
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    prompt = _rand_tokens(jax.random.PRNGKey(4), b, 8)
    k = jnp.zeros(shape); v = jnp.zeros(shape)
    pos0 = jnp.zeros((b,), jnp.int32)
    _, _, ll, pos, k, v = model.ingest(
        CFG, params, k, v, pos0, prompt, jnp.array([8], jnp.int32))
    cur = jnp.argmax(ll, axis=-1).astype(jnp.int32)

    toks, ntake, done, pos_out, k1, v1 = model.span(
        CFG, params, k, v, pos, cur, jnp.float32(0.0), jnp.int32(0))
    n = int(ntake[0])
    # replay: ingest cur + sampled[:-1] (the cached portion)
    replay = jnp.concatenate([cur[:, None], toks[:, :model.T_SPAN - 1]], axis=1)
    replay_len = jnp.array([n], jnp.int32)  # cur + (n-1) sampled
    _, _, _, pos2, k2, v2 = model.ingest(
        CFG, params, k, v, pos, replay, replay_len)
    assert int(pos2[0]) == int(pos_out[0])
    m = int(pos_out[0])
    np.testing.assert_allclose(np.asarray(k1)[:, :, :, :m],
                               np.asarray(k2)[:, :, :, :m], atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1)[:, :, :, :m],
                               np.asarray(v2)[:, :, :, :m], atol=1e-5)


def test_ingest_scores_match_prefill_logprobs(params):
    """ingest's sum_lp must equal the teacher-forcing logprob computed
    from prefill logits."""
    b = 1
    n = 10
    toks_full = _rand_tokens(jax.random.PRNGKey(5), b, CFG.s_max)
    lens = jnp.array([n], jnp.int32)
    logits, _, _ = model.prefill(CFG, params, toks_full, lens)
    lp_ref = 0.0
    for i in range(n - 1):
        row = jax.nn.log_softmax(logits[0, i])
        lp_ref += float(row[int(toks_full[0, i + 1])])

    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    sum_lp, cnt, _, _, _, _ = model.ingest(
        CFG, params, jnp.zeros(shape), jnp.zeros(shape),
        jnp.zeros((b,), jnp.int32), toks_full[:, :model.T_SPAN],
        jnp.array([min(n, model.T_SPAN)], jnp.int32))
    assert int(cnt[0]) == min(n, model.T_SPAN) - 1
    np.testing.assert_allclose(float(sum_lp[0]), lp_ref, atol=1e-3)


def test_ingest_inactive_lanes_frozen(params):
    """Lanes with len=0 must not change their cache, position or score."""
    b = 2
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    k = jax.random.normal(jax.random.PRNGKey(6), shape)
    v = jax.random.normal(jax.random.PRNGKey(7), shape)
    pos = jnp.array([4, 9], jnp.int32)
    toks = _rand_tokens(jax.random.PRNGKey(8), b, model.T_SPAN)
    lens = jnp.array([5, 0], jnp.int32)
    sum_lp, cnt, _, pos_out, k2, v2 = model.ingest(
        CFG, params, k, v, pos, toks, lens)
    assert int(pos_out[1]) == 9
    assert float(sum_lp[1]) == 0.0
    assert int(cnt[1]) == 0
    np.testing.assert_allclose(np.asarray(k2)[:, 1], np.asarray(k)[:, 1])
    np.testing.assert_allclose(np.asarray(v2)[:, 1], np.asarray(v)[:, 1])


def test_span_greedy_deterministic(params):
    b = 1
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    k = jnp.zeros(shape); v = jnp.zeros(shape)
    pos = jnp.zeros((b,), jnp.int32)
    cur = jnp.array([corpus.Q], jnp.int32)
    r1 = model.span(CFG, params, k, v, pos, cur, jnp.float32(0.0), jnp.int32(1))
    r2 = model.span(CFG, params, k, v, pos, cur, jnp.float32(0.0), jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(r1[0]), np.asarray(r2[0]))


def test_sampling_seed_changes_output(params):
    b = 4
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.s_max, CFG.d_head)
    k = jnp.zeros(shape); v = jnp.zeros(shape)
    pos = jnp.zeros((b,), jnp.int32)
    cur = jnp.full((b,), corpus.Q, jnp.int32)
    r1 = model.span(CFG, params, k, v, pos, cur, jnp.float32(2.0), jnp.int32(1))
    r2 = model.span(CFG, params, k, v, pos, cur, jnp.float32(2.0), jnp.int32(9))
    assert not np.array_equal(np.asarray(r1[0]), np.asarray(r2[0]))


def test_param_shapes_roundtrip():
    shapes = model.param_shapes(CFG)
    names = [n for n, _ in shapes]
    assert len(names) == len(set(names))
    p = model.init_params(CFG, jax.random.PRNGKey(0))
    leaves = model.flatten_params(CFG, p)
    p2 = model.unflatten_params(CFG, leaves)
    assert set(p2) == set(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(p2[k]))


def test_flops_per_token_alpha():
    a = model.DRAFT_CONFIG.flops_per_token()
    t = model.TARGET_CONFIG.flops_per_token()
    assert 0.0 < a / t < 0.2  # real compute gap between draft and target
