"""Hypothesis sweeps of the Pallas kernels against the pure-jnp oracles.

This is the L1 correctness signal: every (shape, dtype, mask) combination
generated here must match ref.py to tight tolerance under interpret=True.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.flash_attention import flash_attention
from compile.kernels.ref import attention_ref, decode_attention_ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 2e-2


@st.composite
def attn_case(draw):
    b = draw(st.sampled_from([1, 2, 4]))
    h = draw(st.sampled_from([1, 2, 4]))
    blk = draw(st.sampled_from([16, 32]))
    n_blk = draw(st.integers(1, 4))
    s = blk * n_blk
    d = draw(st.sampled_from([16, 32, 64]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    seed = draw(st.integers(0, 2**31 - 1))
    lengths = draw(st.one_of(
        st.none(),
        st.lists(st.integers(1, s), min_size=b, max_size=b),
    ))
    return b, h, s, d, blk, dtype, seed, lengths


@given(attn_case(), st.booleans())
def test_flash_attention_matches_ref(case, causal):
    b, h, s, d, blk, dtype, seed, lengths = case
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, s, d), dtype)
    k = _rand(kk, (b, h, s, d), dtype)
    v = _rand(kv, (b, h, s, d), dtype)
    lens = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    out = flash_attention(q, k, v, lens, causal=causal,
                          block_q=blk, block_k=blk)
    ref = attention_ref(q, k, v, causal=causal, lengths=lens)
    # rows that are fully masked (query pos >= length, non-causal) are
    # defined as zero by the kernel but NaN-free garbage in ref; compare
    # only valid rows.
    out_f = out.astype(jnp.float32)
    if lens is not None:
        valid = (jnp.arange(s)[None, :] < lens[:, None])
        if causal:
            pass  # causal rows are always self-attending -> well defined
        out_f = jnp.where(valid[:, None, :, None], out_f, 0.0)
        ref = jnp.where(valid[:, None, :, None], ref, 0.0)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref),
                               atol=_tol(dtype), rtol=_tol(dtype))


@given(attn_case())
def test_decode_attention_matches_ref(case):
    b, h, s, d, blk, dtype, seed, lengths = case
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, d), dtype)
    k = _rand(kk, (b, h, s, d), dtype)
    v = _rand(kv, (b, h, s, d), dtype)
    lens = (jnp.full((b,), s, jnp.int32) if lengths is None
            else jnp.asarray(lengths, jnp.int32))
    out = decode_attention(q, k, v, lens, block_k=blk)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_causality():
    """Future keys must not influence outputs: perturb k/v at position j,
    outputs at positions < j are unchanged."""
    key = jax.random.PRNGKey(0)
    b, h, s, d = 1, 2, 64, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, s, d), jnp.float32)
    k = _rand(kk, (b, h, s, d), jnp.float32)
    v = _rand(kv, (b, h, s, d), jnp.float32)
    out1 = flash_attention(q, k, v, causal=True)
    k2 = k.at[:, :, 40:].set(99.0)
    v2 = v.at[:, :, 40:].set(-99.0)
    out2 = flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :40]),
                               np.asarray(out2[:, :, :40]), atol=1e-6)
    assert float(jnp.abs(out1[:, :, 41:] - out2[:, :, 41:]).max()) > 1.0


def test_decode_attention_length_mask():
    """Entries at position >= length must not influence the output."""
    key = jax.random.PRNGKey(1)
    b, h, s, d = 2, 2, 32, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, d), jnp.float32)
    k = _rand(kk, (b, h, s, d), jnp.float32)
    v = _rand(kv, (b, h, s, d), jnp.float32)
    lens = jnp.array([5, 17], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    k2 = jnp.where(jnp.arange(s)[None, None, :, None] >= lens[:, None, None, None], 50.0, k)
    v2 = jnp.where(jnp.arange(s)[None, None, :, None] >= lens[:, None, None, None], -50.0, v)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_flash_attention_rejects_bad_block():
    q = jnp.zeros((1, 1, 48, 16))
    with pytest.raises(AssertionError):
        flash_attention(q, q, q, block_q=32, block_k=32)


def test_decode_softmax_normalization():
    """Uniform keys -> output is the mean of valid values."""
    b, h, s, d = 1, 1, 32, 8
    q = jnp.ones((b, h, d))
    k = jnp.ones((b, h, s, d))
    v = jnp.tile(jnp.arange(s, dtype=jnp.float32)[None, None, :, None],
                 (b, h, 1, d))
    lens = jnp.array([10], jnp.int32)
    out = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out)[0, 0, 0], np.mean(np.arange(10)),
                               rtol=1e-5)
