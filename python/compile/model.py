"""L2 — decoder-only transformer for the SSR draft/target pair.

Stand-ins for the paper's QwQ-32B (target) and R1-Distill-1.5B (draft):
same architecture family, trained on the synthetic reasoning corpus
(`corpus.py`). The serving entry points exported by `aot.py` are:

  prefill(params, tokens[B,S], lengths[B])
      -> (logits[B,S,V], k[L,B,H,S,D], v[L,B,H,S,D])
  span(params, k, v, pos[B], cur[B], temp, seed)
      -> (toks[B,T], ntake[B], done[B], pos_out[B], k', v')
      speculative *step* generation: a lax.scan decodes up to T_SPAN
      tokens inside one XLA execution, stopping at a step delimiter
      (`;` or `.`) — one host<->device round-trip per reasoning STEP,
      which is the L2 half of the paper's step-level granularity.
  ingest(params, k, v, pos[B], toks[B,T], lens[B])
      -> (sum_lp[B], cnt[B], last_logits[B,V], pos_out[B], k', v')
      teacher-forcing: extends the cache with given tokens and returns
      the summed next-token log-prob — used by the target model both to
      SCORE a drafted step (paper Eq. 2) and to sync caches after a
      rewrite.

Cache contract (mirrored by rust/src/model/handle.rs):
  * `pos[b]` = number of valid cache entries for path b.
  * span caches `cur` plus all sampled tokens EXCEPT the final one
    (the final sampled token — usually the delimiter — must be fed as
    `cur`/first ingest token of the next call).
  * ingest caches every token in `toks[:len]`.

Attention is the Pallas kernels (interpret=True) in export mode and the
pure-jnp refs in training mode; `python/tests/test_model.py` asserts the
two paths agree.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .kernels.decode_attention import decode_attention
from .kernels.flash_attention import flash_attention
from .kernels.ref import attention_ref, decode_attention_ref

T_SPAN = 16  # max tokens drafted/ingested per reasoning step


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab: int = corpus.VOCAB_SIZE
    s_max: int = 128

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_shapes(self))

    def flops_per_token(self) -> int:
        """Dense fwd FLOPs/token ≈ 2 * matmul params (paper's F_t / F_d)."""
        per_layer = 2 * (4 * self.d_model * self.d_model
                         + 2 * self.d_model * self.d_ff)
        return self.n_layers * per_layer + 2 * self.d_model * self.vocab


TARGET_CONFIG = ModelConfig("target", n_layers=4, d_model=128, n_heads=4)
DRAFT_CONFIG = ModelConfig("draft", n_layers=2, d_model=64, n_heads=2)


# ---------------------------------------------------------------------------
# Parameters — explicit canonical ordering (the artifact manifest and the
# rust weight loader both rely on this exact order).
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes: list[tuple[str, tuple[int, ...]]] = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        shapes += [
            (p + "ln1_scale", (d,)), (p + "ln1_bias", (d,)),
            (p + "wq", (d, d)), (p + "wk", (d, d)), (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_scale", (d,)), (p + "ln2_bias", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    shapes += [("ln_f_scale", (d,)), ("ln_f_bias", (d,)), ("head", (d, v))]
    return shapes


def init_params(cfg: ModelConfig, key) -> dict:
    params = {}
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_bias", "b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * (1.0 / np.sqrt(fan_in)))
    return params


def flatten_params(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[name] for name, _ in param_shapes(cfg)]


def unflatten_params(cfg: ModelConfig, leaves) -> dict:
    return {name: leaf for (name, _), leaf in zip(param_shapes(cfg), leaves)}


# ---------------------------------------------------------------------------
# Building blocks.
# ---------------------------------------------------------------------------

def layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def sinusoid_table(s_max: int, d: int) -> jnp.ndarray:
    """Fixed sinusoidal position encodings (no learned rows: positions
    beyond the training length behave sanely at serving time)."""
    pos = np.arange(s_max)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, jnp.float32)


def _mlp(params, prefix, x):
    h = jax.nn.gelu(x @ params[prefix + "w1"] + params[prefix + "b1"])
    return h @ params[prefix + "w2"] + params[prefix + "b2"]


# ---------------------------------------------------------------------------
# Prefill (full-sequence forward, builds the KV cache).
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: dict, tokens, lengths, *,
            use_pallas: bool = True):
    """tokens [B,S] int32, lengths [B] int32 ->
    (logits [B,S,V] f32, k [L,B,H,S_MAX,D], v [L,B,H,S_MAX,D])."""
    b, s = tokens.shape
    h_, dh = cfg.n_heads, cfg.d_head
    x = params["embed"][tokens] + sinusoid_table(cfg.s_max, cfg.d_model)[:s]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        hn = layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = (hn @ params[p + "wq"]).reshape(b, s, h_, dh).transpose(0, 2, 1, 3)
        k = (hn @ params[p + "wk"]).reshape(b, s, h_, dh).transpose(0, 2, 1, 3)
        v = (hn @ params[p + "wv"]).reshape(b, s, h_, dh).transpose(0, 2, 1, 3)
        if use_pallas:
            att = flash_attention(q, k, v, lengths, causal=True)
        else:
            att = attention_ref(q, k, v, causal=True, lengths=lengths)
        att = att.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + att @ params[p + "wo"]
        x = x + _mlp(params, p, layer_norm(
            x, params[p + "ln2_scale"], params[p + "ln2_bias"]))
        ks.append(k)
        vs.append(v)
    x = layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])
    logits = x @ params["head"]
    k_cache = jnp.stack(ks)  # [L,B,H,S,D]
    v_cache = jnp.stack(vs)
    if s < cfg.s_max:
        pad = [(0, 0), (0, 0), (0, 0), (0, cfg.s_max - s), (0, 0)]
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    return logits, k_cache, v_cache


# ---------------------------------------------------------------------------
# Single-token decode (shared by span and ingest scans).
# ---------------------------------------------------------------------------

def _write_kv(cache_l, new_bhd, pos):
    """cache_l [B,H,S,D], new [B,H,D], pos [B] -> per-path write at pos."""
    def one(c, n, p):  # c [H,S,D], n [H,D], p scalar
        return jax.lax.dynamic_update_slice(c, n[:, None, :], (0, p, 0))
    return jax.vmap(one)(cache_l, new_bhd, pos)


def decode_step(cfg: ModelConfig, params: dict, k_cache, v_cache, pos, tok, *,
                use_pallas: bool = True):
    """One-token forward. Writes tok's k/v at `pos`, attends over pos+1
    entries. Returns (logits [B,V], k_cache', v_cache')."""
    b = tok.shape[0]
    h_, dh = cfg.n_heads, cfg.d_head
    table = sinusoid_table(cfg.s_max, cfg.d_model)
    x = params["embed"][tok] + table[pos]
    lengths = pos + 1
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        hn = layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = (hn @ params[p + "wq"]).reshape(b, h_, dh)
        k = (hn @ params[p + "wk"]).reshape(b, h_, dh)
        v = (hn @ params[p + "wv"]).reshape(b, h_, dh)
        k_cache = k_cache.at[i].set(_write_kv(k_cache[i], k, pos))
        v_cache = v_cache.at[i].set(_write_kv(v_cache[i], v, pos))
        if use_pallas:
            att = decode_attention(q, k_cache[i], v_cache[i], lengths)
        else:
            att = decode_attention_ref(q, k_cache[i], v_cache[i], lengths)
        x = x + att.astype(x.dtype).reshape(b, cfg.d_model) @ params[p + "wo"]
        x = x + _mlp(params, p, layer_norm(
            x, params[p + "ln2_scale"], params[p + "ln2_bias"]))
    x = layer_norm(x, params["ln_f_scale"], params["ln_f_bias"])
    return x @ params["head"], k_cache, v_cache


def _sample(logits, temp, key):
    """Greedy when temp<=0 else temperature sampling; [B,V] -> [B] i32.

    PAD/BOS are masked out: a sampled PAD would corrupt the span's
    token-count contract (PAD marks inactive emit slots).
    """
    mask = jnp.zeros(logits.shape[-1]).at[corpus.PAD].set(-1e30)
    mask = mask.at[corpus.BOS].set(-1e30)
    logits = logits + mask
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temp, 1e-3)
    sampled = jax.random.categorical(key, logits / t, axis=-1).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)


def span(cfg: ModelConfig, params: dict, k_cache, v_cache, pos, cur, temp,
         seed, *, use_pallas: bool = True, t_span: int = T_SPAN):
    """Draft one reasoning step (up to t_span tokens) inside one XLA call.

    Returns (toks [B,T] i32 — sampled tokens, PAD after the delimiter;
    ntake [B] i32 — sampled count incl. delimiter; done [B] i32;
    pos_out [B] i32; k', v').
    """
    key0 = jax.random.PRNGKey(seed)
    b = cur.shape[0]
    delims = jnp.asarray(corpus.STEP_DELIMS, jnp.int32)

    def body(carry, i):
        k_c, v_c, pos, cur, done = carry
        logits, k_c, v_c = decode_step(cfg, params, k_c, v_c, pos, cur,
                                       use_pallas=use_pallas)
        nxt = _sample(logits, temp, jax.random.fold_in(key0, i))
        active = jnp.logical_not(done)
        emit = jnp.where(active, nxt, corpus.PAD)
        is_delim = jnp.isin(nxt, delims)
        done = jnp.logical_or(done, jnp.logical_and(active, is_delim))
        pos = jnp.where(active, pos + 1, pos)
        cur = jnp.where(active, nxt, cur)
        return (k_c, v_c, pos, cur, done), emit

    done0 = jnp.zeros((b,), bool)
    (k_cache, v_cache, pos_out, _, done), emits = jax.lax.scan(
        body, (k_cache, v_cache, pos, cur, done0), jnp.arange(t_span))
    toks = emits.T  # [B, T]
    ntake = jnp.sum(toks != corpus.PAD, axis=-1).astype(jnp.int32)
    return (toks.astype(jnp.int32), ntake, done.astype(jnp.int32),
            pos_out.astype(jnp.int32), k_cache, v_cache)


def ingest(cfg: ModelConfig, params: dict, k_cache, v_cache, pos, toks, lens,
           *, use_pallas: bool = True):
    """Teacher-force `toks[:, :lens]` into the cache.

    Returns (sum_lp [B] f32 — sum over i>=1 of log P(toks[i] | ...);
    cnt [B] i32 — number of scored predictions (lens-1 clamped >= 0);
    last_logits [B,V] — logits after the final ingested token;
    pos_out [B]; k', v').
    """
    b = toks.shape[0]

    def body(carry, i):
        k_c, v_c, pos, sum_lp, cnt, last_logits = carry
        cur = toks[:, i]
        active = i < lens
        logits, k_c2, v_c2 = decode_step(cfg, params, k_c, v_c, pos, cur,
                                         use_pallas=use_pallas)
        logprobs = jax.nn.log_softmax(logits, axis=-1)
        nxt_active = (i + 1) < lens
        nxt_tok = toks[:, jnp.minimum(i + 1, toks.shape[1] - 1)]
        lp = jnp.take_along_axis(logprobs, nxt_tok[:, None], axis=-1)[:, 0]
        sum_lp = sum_lp + jnp.where(nxt_active, lp, 0.0)
        cnt = cnt + nxt_active.astype(jnp.int32)
        new_pos = jnp.where(active, pos + 1, pos)
        last_logits = jnp.where(active[:, None], logits, last_logits)
        # inactive lanes must not mutate the cache state they already hold
        k_c = jnp.where(active[None, :, None, None, None], k_c2, k_c)
        v_c = jnp.where(active[None, :, None, None, None], v_c2, v_c)
        return (k_c, v_c, new_pos, sum_lp, cnt, last_logits), None

    sum0 = jnp.zeros((b,), jnp.float32)
    cnt0 = jnp.zeros((b,), jnp.int32)
    ll0 = jnp.zeros((b, cfg.vocab), jnp.float32)
    (k_cache, v_cache, pos_out, sum_lp, cnt, last_logits), _ = jax.lax.scan(
        body, (k_cache, v_cache, pos, sum0, cnt0, ll0),
        jnp.arange(toks.shape[1]))
    return (sum_lp, cnt, last_logits, pos_out.astype(jnp.int32),
            k_cache, v_cache)


# ---------------------------------------------------------------------------
# Training-path loss (teacher forcing over full sequences, ref attention).
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: dict, tokens, lengths):
    """Mean next-token cross-entropy over valid positions."""
    logits, _, _ = prefill(cfg, params, tokens, lengths, use_pallas=False)
    targets = tokens[:, 1:]
    logprobs = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    lp = jnp.take_along_axis(logprobs, targets[..., None], axis=-1)[..., 0]
    mask = (jnp.arange(tokens.shape[1] - 1)[None, :] + 1
            < lengths[:, None]).astype(jnp.float32)
    return -(lp * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def kv_cache_bytes(cfg: ModelConfig, batch: int) -> int:
    """Bytes held by one f32 KV cache pair at batch `batch` (for §Perf)."""
    return (2 * cfg.n_layers * batch * cfg.n_heads * cfg.s_max
            * cfg.d_head * 4)
