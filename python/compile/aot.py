"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.

Emits HLO *text* (never `.serialize()`): jax >= 0.5 writes HloModuleProto
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all consumed by rust/src/runtime/):
  artifacts/<entry>.hlo.txt          one per entry point x batch variant
  artifacts/<model>.weights.bin/.json  trained parameters (train.py)
  artifacts/manifest.json            vocab, configs, entry-point registry,
                                     suite files, token-id constants
  artifacts/suite-<name>.json        benchmark problem sets (corpus.py)

Python runs ONCE at build time; the rust binary is self-contained after
`make artifacts`.

Usage: python -m compile.aot [--out DIR] [--random]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpus, model, train

PREFILL_BATCHES = (1, 2, 4, 8)
STEP_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big constant
    # tensors as `constant({...})`, which the 0.5.1-era text parser happily
    # reads back as GARBAGE (we lost a day's worth of position-embedding
    # table to this). Guard against any residual elision.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text still contains elided constants"
    return text


def _f32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape=()):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _param_specs(cfg: model.ModelConfig):
    return tuple(_f32(shape) for _, shape in model.param_shapes(cfg))


def _cache_spec(cfg: model.ModelConfig, b: int):
    return _f32((cfg.n_layers, b, cfg.n_heads, cfg.s_max, cfg.d_head))


def entry_points(cfg: model.ModelConfig, batches_prefill, batches_step):
    """Yield (name, fn, example_args, signature_doc)."""
    p_specs = _param_specs(cfg)
    n_p = len(p_specs)

    for b in batches_prefill:
        def prefill_fn(*args, _b=b):
            params = model.unflatten_params(cfg, args[:n_p])
            tokens, lengths = args[n_p], args[n_p + 1]
            return model.prefill(cfg, params, tokens, lengths)

        yield (
            f"prefill_{cfg.name}_b{b}",
            prefill_fn,
            p_specs + (_i32((b, cfg.s_max)), _i32((b,))),
            {"kind": "prefill", "model": cfg.name, "batch": b,
             "inputs": ["params*", "tokens[B,S]", "lengths[B]"],
             "outputs": ["logits[B,S,V]", "k[L,B,H,S,D]", "v[L,B,H,S,D]"]},
        )

    for b in batches_step:
        def span_fn(*args, _b=b):
            params = model.unflatten_params(cfg, args[:n_p])
            k, v, pos, cur, temp, seed = args[n_p:]
            return model.span(cfg, params, k, v, pos, cur, temp, seed)

        yield (
            f"span_{cfg.name}_b{b}",
            span_fn,
            p_specs + (_cache_spec(cfg, b), _cache_spec(cfg, b),
                       _i32((b,)), _i32((b,)), _f32(), _i32()),
            {"kind": "span", "model": cfg.name, "batch": b,
             "inputs": ["params*", "k", "v", "pos[B]", "cur[B]",
                        "temp", "seed"],
             "outputs": ["toks[B,T]", "ntake[B]", "done[B]", "pos_out[B]",
                         "k", "v"]},
        )

        def ingest_fn(*args, _b=b):
            params = model.unflatten_params(cfg, args[:n_p])
            k, v, pos, toks, lens = args[n_p:]
            return model.ingest(cfg, params, k, v, pos, toks, lens)

        yield (
            f"ingest_{cfg.name}_b{b}",
            ingest_fn,
            p_specs + (_cache_spec(cfg, b), _cache_spec(cfg, b),
                       _i32((b,)), _i32((b, model.T_SPAN)), _i32((b,))),
            {"kind": "ingest", "model": cfg.name, "batch": b,
             "inputs": ["params*", "k", "v", "pos[B]", "toks[B,T]",
                        "lens[B]"],
             "outputs": ["sum_lp[B]", "cnt[B]", "last_logits[B,V]",
                         "pos_out[B]", "k", "v"]},
        )


def model_manifest(cfg: model.ModelConfig) -> dict:
    return {
        "name": cfg.name,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "d_head": cfg.d_head,
        "d_ff": cfg.d_ff,
        "vocab": cfg.vocab,
        "s_max": cfg.s_max,
        "n_params": cfg.n_params,
        "flops_per_token": cfg.flops_per_token(),
        "weights_bin": f"{cfg.name}.weights.bin",
        "weights_json": f"{cfg.name}.weights.json",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--random", action="store_true",
                    help="write random weights instead of requiring train.py "
                         "output (smoke/testing only)")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    configs = (model.DRAFT_CONFIG, model.TARGET_CONFIG)

    # Weights must exist (or be faked) before the manifest claims them.
    for cfg in configs:
        wpath = os.path.join(out, f"{cfg.name}.weights.bin")
        if not os.path.exists(wpath):
            if not args.random:
                raise SystemExit(
                    f"missing {wpath}; run `python -m compile.train` first "
                    f"(or pass --random for smoke testing)")
            params = model.init_params(cfg, jax.random.PRNGKey(0))
            train.save_weights(cfg, params, out)

    entries = []
    for cfg in configs:
        for name, fn, specs, sig in entry_points(cfg, PREFILL_BATCHES,
                                                 STEP_BATCHES):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            path = os.path.join(out, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            sig["file"] = f"{name}.hlo.txt"
            sig["name"] = name
            entries.append(sig)
            print(f"lowered {name}: {len(text)} chars", flush=True)

    # Benchmark suites.
    suites = []
    for spec in corpus.SUITES:
        data = corpus.suite_to_json(spec)
        fname = f"suite-{spec.name}.json"
        with open(os.path.join(out, fname), "w") as f:
            json.dump(data, f)
        suites.append({"name": spec.name, "file": fname,
                       "n_problems": data and len(data["problems"])})
        print(f"wrote {fname} ({len(data['problems'])} problems)")

    manifest = {
        "version": 1,
        "t_span": model.T_SPAN,
        "vocab": {
            "size": corpus.VOCAB_SIZE,
            "names": {str(k): v for k, v in corpus.TOKEN_NAMES.items()},
            "pad": corpus.PAD, "bos": corpus.BOS, "q": corpus.Q,
            "sep": corpus.SEP, "step": corpus.STEP, "fin": corpus.FIN,
            "eos": corpus.EOS, "digit0": corpus.DIGIT0,
            "plus": corpus.PLUS, "minus": corpus.MINUS, "mul": corpus.MUL,
            "lparen": corpus.LPAREN, "rparen": corpus.RPAREN,
            "eq": corpus.EQ, "mod": corpus.MOD,
            "strat0": corpus.STRAT0,
            "num_strategies": corpus.NUM_STRATEGIES,
        },
        "strategies": {
            "names": corpus.STRATEGY_NAMES,
            "styles": corpus.STRATEGY_STYLE,
            "style_names": corpus.STYLE_NAMES,
            "aptitude": {
                str(style): apt for style, apt in corpus.STYLE_APTITUDE.items()
            },
        },
        "families": corpus.FAMILY_NAMES,
        "models": [model_manifest(cfg) for cfg in configs],
        "alpha": (model.DRAFT_CONFIG.flops_per_token()
                  / model.TARGET_CONFIG.flops_per_token()),
        "prefill_batches": list(PREFILL_BATCHES),
        "step_batches": list(STEP_BATCHES),
        "entries": entries,
        "suites": suites,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json ({len(entries)} entry points)")


if __name__ == "__main__":
    main()
