"""Pallas decode attention — the serving hot-spot (one token vs KV cache).

Flash-decoding structure: grid = (B*H,); each program owns one (batch,
head) pair, holds the single query vector in VMEM and streams the K/V
cache row through BLOCK_K-sized tiles with an online-softmax carry, so
every cache byte is read exactly once (decode is bandwidth-bound — one
pass over the cache is the roofline optimum; see DESIGN.md §8).

Positions >= length are masked: the KV cache is a fixed S_MAX ring of
which only `length` entries are valid.

Must run with interpret=True on CPU (Mosaic custom-calls are TPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                   seq_len: int, scale: float):
    q = q_ref[0].astype(jnp.float32) * scale             # [d]
    d = q.shape[-1]
    length = len_ref[0]

    def body(ki, carry):
        acc, m_prev, l_prev = carry
        k_tile = pl.load(
            k_ref, (0, pl.dslice(ki * block_k, block_k), slice(None))
        ).astype(jnp.float32)                            # [block_k, d]
        v_tile = pl.load(
            v_ref, (0, pl.dslice(ki * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        s = k_tile @ q                                   # [block_k]
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_cur = jnp.max(s)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + p @ v_tile
        return acc, m_new, l_new

    num_k = seq_len // block_k
    acc0 = jnp.zeros((d,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(
        0, num_k, body, (acc0, jnp.float32(NEG_INF), jnp.float32(0.0))
    )
    safe_l = jnp.where(l > 0.0, l, 1.0)
    o_ref[0] = (acc / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret")
)
def decode_attention(q, k, v, lengths, *, block_k: int = 32,
                     interpret: bool = True):
    """Single-token attention against a KV cache.

    q: [B, H, D]; k, v: [B, H, S, D]; lengths: [B] int32 (valid entries,
    including the current token's freshly-written k/v). Returns [B, H, D]
    with q's dtype.
    """
    b, h, d = q.shape
    s = k.shape[2]
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    assert s % block_k == 0, (s, block_k)
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)
    len_r = jnp.repeat(lengths.astype(jnp.int32), h)

    kernel = functools.partial(
        _decode_kernel, block_k=block_k, seq_len=s, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda bh: (bh,)),
            pl.BlockSpec((1, d), lambda bh: (bh, 0)),
            pl.BlockSpec((1, s, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda bh: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        interpret=interpret,
    )(len_r, qr, kr, vr)
    return out.reshape(b, h, d)
