"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels (run
under interpret=True) match these to tight tolerances. The L2 model also
uses these implementations for *training* (faster than interpret-mode
Pallas); the exported inference artifacts use the Pallas kernels, and a
dedicated test asserts the two lowerings agree.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, lengths=None, scale=None):
    """Reference multi-head attention.

    q, k, v: [B, H, S, D]. `lengths`: optional [B] int32 — positions >= length
    are masked out of the keys (padding). Returns [B, H, S, D] in f32.
    """
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(ki <= qi, logits, NEG_INF)
    if lengths is not None:
        ki = jnp.arange(s)[None, None, None, :]
        logits = jnp.where(ki < lengths[:, None, None, None], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)


def decode_attention_ref(q, k, v, lengths, *, scale=None):
    """Reference single-token decode attention against a KV cache.

    q: [B, H, D] (the current token's query);
    k, v: [B, H, S, D] caches; lengths: [B] int32 — valid cache length
    (the current token's k/v must already be written, so the mask is
    `position < length`). Returns [B, H, D] in f32.
    """
    b, h, s, d = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    ki = jnp.arange(s)[None, None, :]
    logits = jnp.where(ki < lengths[:, None, None], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
