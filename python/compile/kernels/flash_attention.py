"""Pallas flash attention (causal, length-masked) — the prefill hot-spot.

Design (TPU mapping, see DESIGN.md §Hardware-Adaptation):
  * grid = (B*H, S // BLOCK_Q): one program per (batch·head, query tile).
  * The query tile (BLOCK_Q × D) is pinned in VMEM; K/V stream through in
    BLOCK_K × D tiles (the `BlockSpec` below hands the kernel the whole
    (S × D) row and the kernel walks it tile-by-tile with `pl.dslice` — on
    TPU this is the HBM→VMEM schedule the paper's GPU baselines express
    with threadblocks / shared memory).
  * Online softmax: running (m, l, acc) state so each K/V tile is read
    exactly once; both matmuls are MXU-shaped (BLOCK×D · D×BLOCK).
  * Causal tiles beyond the query tile are skipped entirely (upper bound
    on the tile loop), halving prefill FLOPs.

Must run with interpret=True on CPU: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                  block_k: int, seq_len: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # [block_q, d]
    d = q.shape[-1]
    length = len_ref[0]                                  # valid key count

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    # Causal programs only need key tiles up to the end of their own query
    # tile; non-causal (scoring) programs walk the full row.
    if causal:
        num_k = (qi + 1) * block_q // block_k
    else:
        num_k = seq_len // block_k

    def body(ki, carry):
        acc, m_prev, l_prev = carry
        k_tile = pl.load(
            k_ref, (0, pl.dslice(ki * block_k, block_k), slice(None))
        ).astype(jnp.float32)                            # [block_k, d]
        v_tile = pl.load(
            v_ref, (0, pl.dslice(ki * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        s = q @ k_tile.T                                 # [block_q, block_k]
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < length
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                  # rescale old state
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, num_k, body, (acc0, m0, l0))
    # Fully-masked rows (query position >= length) have l == 0; emit zeros.
    safe_l = jnp.where(l > 0.0, l, 1.0)
    o_ref[0] = (acc / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(q, k, v, lengths=None, *, causal: bool = True,
                    block_q: int = 32, block_k: int = 32,
                    interpret: bool = True):
    """Multi-head attention via a Pallas flash kernel.

    q, k, v: [B, H, S, D]; lengths: [B] int32 (defaults to S). Returns
    [B, H, S, D] with the dtype of q.
    """
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)
    len_r = jnp.repeat(lengths.astype(jnp.int32), h)     # [B*H]

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        scale=scale, causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, qi: (bh,)),          # lengths
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),  # K row
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),  # V row
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(len_r, qr, kr, vr)
    return out.reshape(b, h, s, d)
