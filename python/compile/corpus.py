"""Synthetic step-structured arithmetic-reasoning corpus.

This is the data substrate standing in for the paper's math benchmarks
(AIME 2024 / MATH-500 / LiveMathBench): procedurally generated arithmetic
chain problems with exact ground-truth answers, rendered as multi-step
reasoning traces

    BOS Q <expr> ; <strategy> S <a><op><b>=<v> ; ... F <answer> .

The strategy token conditions the *decomposition style* of the steps, so
the Selective Parallel Module has a real signal to learn: some styles are
a much better fit for some problem families (e.g. precedence-first on
mul-heavy expressions, modular-reduce on `% m` problems), mirroring the
paper's Appendix-D strategy pool.

Everything here is deterministic given a seed (splitmix64, mirrored
bit-for-bit by `rust/src/util/rng.rs`).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable

# ---------------------------------------------------------------------------
# Vocabulary — fixed ids, mirrored by rust/src/model/vocab.rs via
# artifacts/manifest.json (rust never hard-codes these).
# ---------------------------------------------------------------------------

PAD, BOS, Q, SEP, STEP, FIN, EOS = 0, 1, 2, 3, 4, 5, 6
DIGIT0 = 7  # ids 7..16 are digits 0..9
PLUS, MINUS, MUL, LPAREN, RPAREN, EQ, MOD = 17, 18, 19, 20, 21, 22, 23
STRAT0 = 24  # ids 24..36 are strategy tokens A..M (M = "Unknown")
NUM_STRATEGIES = 13  # A..L real strategies + M
VOCAB_SIZE = 64

TOKEN_NAMES = {
    PAD: "<pad>", BOS: "<bos>", Q: "Q", SEP: ";", STEP: "S", FIN: "F",
    EOS: ".", PLUS: "+", MINUS: "-", MUL: "*", LPAREN: "(", RPAREN: ")",
    EQ: "=", MOD: "%",
}
for _d in range(10):
    TOKEN_NAMES[DIGIT0 + _d] = str(_d)
for _s in range(NUM_STRATEGIES):
    TOKEN_NAMES[STRAT0 + _s] = f"<{chr(ord('A') + _s)}>"

STEP_DELIMS = (SEP, EOS)

# Problem families (mirrored in rust/src/workload/problems.rs).
FAM_ADD_CHAIN = 0   # a + b - c + d
FAM_MUL_MIX = 1     # a + b*c - d   (precedence matters)
FAM_PAREN = 2       # (a + b) * c - d
FAM_MODULAR = 3     # (a*b + c) % m
FAMILY_NAMES = ["add_chain", "mul_mix", "paren", "modular"]

# Decomposition styles.
STYLE_L2R = 0        # leftmost evaluable reduction
STYLE_PREC = 1       # all '*' first (left to right), then +/- l2r
STYLE_PAREN = 2      # innermost parenthesis first, then precedence
STYLE_RTL = 3        # rightmost evaluable reduction (awkward)
STYLE_TENS = 4       # like l2r, but 2-digit additions split into tens+ones
STYLE_MODRED = 5     # reduce operands mod m early (modular family)
STYLE_NAMES = ["l2r", "prec_first", "paren_first", "rtl", "tens", "mod_reduce"]

# Strategy -> style mapping (paper Appendix D pool A..M; M = unknown).
# Several paper strategies share a decomposition style in the arithmetic
# domain but keep distinct tokens, so the pool stays at K=12 (+M).
STRATEGY_STYLE = [
    STYLE_PREC,    # A algebraic simplification
    STYLE_PAREN,   # B clever substitution
    STYLE_L2R,     # C coordinate geometry
    STYLE_RTL,     # D complex numbers
    STYLE_MODRED,  # E number theory
    STYLE_TENS,    # F combinatorics
    STYLE_PREC,    # G probability
    STYLE_L2R,     # H functional equations
    STYLE_RTL,     # I recursion / invariants
    STYLE_PAREN,   # J geometry
    STYLE_TENS,    # K casework / constructive
    STYLE_MODRED,  # L calculus / inequalities
    # M ("Unknown") handled by callers: uniform random style.
]
STRATEGY_NAMES = [
    "algebraic_simplification", "clever_substitution", "coordinate_geometry",
    "complex_numbers", "number_theory", "combinatorics", "probability",
    "functional_equations", "recursion_invariants", "geometry",
    "casework_constructive", "calculus_inequalities", "unknown",
]

# Aptitude of each *style* for each family, in [0, 1]; used to sample the
# strategy paired with a problem in the training corpus (good pairings are
# seen more often), and by the calibrated backend's success model.
STYLE_APTITUDE = {
    #               add   mul   paren modular
    STYLE_L2R:     [0.95, 0.35, 0.30, 0.40],
    STYLE_PREC:    [0.80, 0.95, 0.55, 0.55],
    STYLE_PAREN:   [0.70, 0.70, 0.95, 0.50],
    STYLE_RTL:     [0.45, 0.25, 0.25, 0.30],
    STYLE_TENS:    [0.90, 0.45, 0.40, 0.35],
    STYLE_MODRED:  [0.30, 0.30, 0.30, 0.95],
}


def strategy_aptitude(strategy: int, family: int) -> float:
    """Aptitude of strategy token `strategy` (0..12) for `family`."""
    if strategy >= len(STRATEGY_STYLE):  # M / unknown
        return 0.40
    return STYLE_APTITUDE[STRATEGY_STYLE[strategy]][family]


# ---------------------------------------------------------------------------
# Deterministic RNG — splitmix64, mirrored by rust/src/util/rng.rs.
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & _M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform in [0, n) (multiply-shift, matches rust)."""
        return (self.next_u64() * n) >> 64

    def range(self, lo: int, hi: int) -> int:
        """Uniform in [lo, hi] inclusive."""
        return lo + self.below(hi - lo + 1)

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def choice_weighted(self, weights: list[float]) -> int:
        total = sum(weights)
        x = self.f64() * total
        acc = 0.0
        for i, w in enumerate(weights):
            acc += w
            if x < acc:
                return i
        return len(weights) - 1


# ---------------------------------------------------------------------------
# Expressions — tiny AST: int leaf, or (op, left, right); op in '+-*%'.
# ---------------------------------------------------------------------------

Node = object  # int | tuple[str, Node, Node]


def ev(node) -> int:
    if isinstance(node, int):
        return node
    op, a, b = node
    x, y = ev(a), ev(b)
    if op == "+":
        return x + y
    if op == "-":
        return x - y
    if op == "*":
        return x * y
    if op == "%":
        return x % y
    raise ValueError(op)


def num_tokens(v: int) -> list[int]:
    assert v >= 0, "corpus values are non-negative"
    return [DIGIT0 + int(c) for c in str(v)]


_OP_TOK = {"+": PLUS, "-": MINUS, "*": MUL, "%": MOD}


def expr_tokens(node, parent_prec: int = 0) -> list[int]:
    """Render with minimal parentheses (matching the rust renderer)."""
    if isinstance(node, int):
        return num_tokens(node)
    op, a, b = node
    prec = {"+": 1, "-": 1, "*": 2, "%": 0}[op]
    # `%` binds loosest in our grammar but tightest in conventional
    # notation — force parens around a compound left operand so the
    # rendered string is unambiguous under standard precedence too.
    lhs_prec = 3 if op == "%" else prec
    inner = (
        expr_tokens(a, lhs_prec)
        + [_OP_TOK[op]]
        + expr_tokens(b, prec + 1)  # left-assoc: rhs binds tighter
    )
    if prec < parent_prec:
        return [LPAREN] + inner + [RPAREN]
    return inner


# ---------------------------------------------------------------------------
# Problem generation per family.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Problem:
    family: int
    expr: Node
    answer: int
    difficulty: int  # 1 (easy) .. 5 (hard)

    def tokens(self) -> list[int]:
        return expr_tokens(self.expr)


def _gen_add_chain(rng: SplitMix64, max_operand: int, n_ops: int) -> Node:
    node: Node = rng.range(1, max_operand)
    total = node
    for _ in range(n_ops):
        if total > 10 and rng.below(2) == 0:
            v = rng.range(1, min(total, max_operand))
            node = ("-", node, v)
            total -= v
        else:
            v = rng.range(1, max_operand)
            node = ("+", node, v)
            total += v
    return node


def _gen_mul_mix(rng: SplitMix64, max_operand: int, n_ops: int) -> Node:
    # a +/- b*c [+/- d [* e]] — at least one multiplication.
    small = max(2, min(9, max_operand // 4))
    prod = ("*", rng.range(2, small), rng.range(2, small))
    node: Node = ("+", rng.range(1, max_operand), prod)
    for _ in range(max(0, n_ops - 2)):
        if rng.below(3) == 0:
            node = ("+", node, ("*", rng.range(2, small), rng.range(2, small)))
        elif ev(node) > max_operand and rng.below(2) == 0:
            node = ("-", node, rng.range(1, max_operand))
        else:
            node = ("+", node, rng.range(1, max_operand))
    return node


def _gen_paren(rng: SplitMix64, max_operand: int, n_ops: int) -> Node:
    inner = ("+", rng.range(1, max_operand // 2 + 1), rng.range(1, max_operand // 2 + 1))
    node: Node = ("*", inner, rng.range(2, 5))
    for _ in range(max(0, n_ops - 2)):
        if ev(node) > 20 and rng.below(2) == 0:
            node = ("-", node, rng.range(1, 20))
        else:
            node = ("+", node, rng.range(1, max_operand))
    return node


def _gen_modular(rng: SplitMix64, max_operand: int, n_ops: int) -> Node:
    small = max(2, min(9, max_operand // 4))
    base: Node = ("+", ("*", rng.range(2, small), rng.range(2, small)),
                  rng.range(1, max_operand))
    for _ in range(max(0, n_ops - 3)):
        base = ("+", base, rng.range(1, max_operand))
    return ("%", base, rng.range(3, 9))


_FAMILY_GEN = [_gen_add_chain, _gen_mul_mix, _gen_paren, _gen_modular]


def gen_problem(rng: SplitMix64, family: int, max_operand: int, n_ops: int) -> Problem:
    expr = _FAMILY_GEN[family](rng, max_operand, n_ops)
    diff = min(5, 1 + n_ops + (1 if max_operand > 30 else 0)
               + (1 if family in (FAM_PAREN, FAM_MODULAR) else 0))
    return Problem(family=family, expr=expr, answer=ev(expr), difficulty=diff)


# ---------------------------------------------------------------------------
# Decomposition styles — turn an expression into reasoning steps.
# Each step is (lhs_tokens, value); rendered `S <lhs>=<value> ;`.
# ---------------------------------------------------------------------------

def _find_redex(node, path=()):  # leftmost innermost reducible pair
    """Return path to the leftmost node whose children are both ints."""
    if isinstance(node, int):
        return None
    op, a, b = node
    p = _find_redex(a, path + (1,))
    if p is not None:
        return p
    p = _find_redex(b, path + (2,))
    if p is not None:
        return p
    if isinstance(a, int) and isinstance(b, int):
        return path
    return None


def _find_redex_rtl(node, path=()):
    if isinstance(node, int):
        return None
    op, a, b = node
    p = _find_redex_rtl(b, path + (2,))
    if p is not None:
        return p
    p = _find_redex_rtl(a, path + (1,))
    if p is not None:
        return p
    if isinstance(a, int) and isinstance(b, int):
        return path
    return None


def _find_redex_prec(node):
    """Prefer '*' redexes (leftmost), then '%', then leftmost any."""
    best = None  # (prec_rank, order, path)
    order = [0]

    def walk(n, path):
        if isinstance(n, int):
            return
        op, a, b = n
        walk(a, path + (1,))
        # in-order position
        order[0] += 1
        here = order[0]
        walk(b, path + (2,))
        if isinstance(a, int) and isinstance(b, int):
            rank = {"*": 0, "%": 2, "+": 1, "-": 1}[op]
            nonlocal best
            key = (rank, here)
            if best is None or key < best[0]:
                best = (key, path)

    walk(node, ())
    return None if best is None else best[1]


def _get(node, path):
    for step in path:
        node = node[step]
    return node


def _set(node, path, value):
    if not path:
        return value
    op, a, b = node
    if path[0] == 1:
        return (op, _set(a, path[1:], value), b)
    return (op, a, _set(b, path[1:], value))


def _reduce_once(node, path):
    red = _get(node, path)
    op, a, b = red
    v = ev(red)
    lhs = expr_tokens(red)
    return _set(node, path, v), (lhs, v)


def decompose(node, style: int, rng: SplitMix64 | None = None):
    """Return (steps, answer); steps = list[(lhs_tokens, value)]."""
    steps = []
    guard = 0
    while not isinstance(node, int):
        guard += 1
        assert guard < 64, "runaway decomposition"
        if style == STYLE_RTL:
            path = _find_redex_rtl(node)
        elif style in (STYLE_PREC, STYLE_PAREN):
            # paren-first == leftmost-innermost with precedence tiebreak;
            # our _find_redex already returns innermost-leftmost, so use
            # precedence search for PREC and innermost for PAREN.
            path = _find_redex_prec(node) if style == STYLE_PREC else _find_redex(node)
        elif style == STYLE_MODRED and isinstance(node, tuple) and node[0] == "%":
            path = _modred_path(node)
        else:
            path = _find_redex(node)
        assert path is not None
        red = _get(node, path)
        op, a, b = red
        if (style == STYLE_TENS and op == "+" and isinstance(a, int)
                and isinstance(b, int) and b >= 10 and a >= 10):
            # split a + b into (a + tens(b)) + ones(b); two smaller steps
            tens, ones = (b // 10) * 10, b % 10
            mid = a + tens
            steps.append((num_tokens(a) + [PLUS] + num_tokens(tens), mid))
            if ones:
                steps.append((num_tokens(mid) + [PLUS] + num_tokens(ones), mid + ones))
            node = _set(node, path, a + b)
            continue
        node, step = _reduce_once(node, path)
        steps.append(step)
    return steps, node


def _modred_path(node):
    """For `(X) % m`: reduce inside X first but emit mod-m reductions of
    completed subterms when they exceed m (early modular reduction)."""
    # Practical approximation: innermost-leftmost redex inside X.
    op, x, m = node
    if isinstance(x, int):
        return ()
    p = _find_redex(x)
    return None if p is None else (1,) + tuple(p)


def style_for_strategy(strategy: int, rng: SplitMix64) -> int:
    if strategy >= len(STRATEGY_STYLE):
        return rng.below(len(STYLE_APTITUDE))
    return STRATEGY_STYLE[strategy]


# ---------------------------------------------------------------------------
# Sequence rendering.
# ---------------------------------------------------------------------------

def render_sequence(problem: Problem, strategy: int, steps, answer: int,
                    max_len: int) -> tuple[list[int], int]:
    """Full training sequence; returns (tokens padded to max_len, true_len)."""
    toks = [BOS, Q] + problem.tokens() + [SEP, STRAT0 + strategy]
    for lhs, v in steps:
        toks += [STEP] + lhs + [EQ] + num_tokens(v) + [SEP]
    toks += [FIN] + num_tokens(answer) + [EOS]
    n = len(toks)
    if n > max_len:
        toks = toks[:max_len]
        n = max_len
    return toks + [PAD] * (max_len - n), n


def prompt_tokens(problem: Problem, strategy: int | None) -> list[int]:
    """Serving-time prompt: `BOS Q <expr> ; [<strategy>]`."""
    toks = [BOS, Q] + problem.tokens() + [SEP]
    if strategy is not None:
        toks.append(STRAT0 + strategy)
    return toks


# ---------------------------------------------------------------------------
# Corpus sampling (training) and benchmark suites (evaluation).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuiteSpec:
    name: str
    n_problems: int
    seed: int
    family_mix: list[float]      # sampling weights over the 4 families
    max_operand: int
    ops_lo: int
    ops_hi: int


SUITES = [
    SuiteSpec("synth-math500", 500, 0x4D415448, [0.40, 0.30, 0.20, 0.10], 30, 2, 3),
    SuiteSpec("synth-livemath", 138, 0x4C495645, [0.25, 0.25, 0.25, 0.25], 50, 2, 4),
    SuiteSpec("synth-aime", 30, 0x41494D45, [0.10, 0.25, 0.35, 0.30], 99, 3, 4),
]


def gen_suite(spec: SuiteSpec) -> list[Problem]:
    rng = SplitMix64(spec.seed)
    out = []
    while len(out) < spec.n_problems:
        fam = rng.choice_weighted(spec.family_mix)
        n_ops = rng.range(spec.ops_lo, spec.ops_hi)
        p = gen_problem(rng, fam, spec.max_operand, n_ops)
        # keep answers in a renderable (non-negative, small-ish) range
        if 0 <= p.answer <= 999 and len(prompt_tokens(p, 0)) <= 40:
            out.append(p)
    return out


def sample_training_example(rng: SplitMix64, max_len: int):
    """One (tokens, length) training row; strategy sampled ∝ aptitude."""
    fam = rng.below(4)
    max_operand = (20, 40, 60, 99)[rng.below(4)]
    n_ops = rng.range(2, 4)
    p = gen_problem(rng, fam, max_operand, n_ops)
    if not (0 <= p.answer <= 999):
        return None
    weights = [strategy_aptitude(s, fam) ** 2 for s in range(NUM_STRATEGIES)]
    strat = rng.choice_weighted(weights)
    style = style_for_strategy(strat, rng)
    steps, ans = decompose(p.expr, style, rng)
    toks, n = render_sequence(p, strat, steps, ans, max_len)
    if n >= max_len:  # truncated: drop, keep corpus clean
        return None
    return toks, n


def suite_to_json(spec: SuiteSpec) -> dict:
    problems = gen_suite(spec)
    return {
        "name": spec.name,
        "seed": spec.seed,
        "problems": [
            {
                "family": p.family,
                "tokens": p.tokens(),
                "answer": p.answer,
                "difficulty": p.difficulty,
            }
            for p in problems
        ],
    }


def detokenize(toks: Iterable[int]) -> str:
    return "".join(TOKEN_NAMES.get(t, "?") for t in toks if t != PAD)


if __name__ == "__main__":
    rng = SplitMix64(7)
    for _ in range(4):
        ex = None
        while ex is None:
            ex = sample_training_example(rng, 160)
        toks, n = ex
        print(n, detokenize(toks))
    for spec in SUITES:
        s = gen_suite(spec)
        print(spec.name, len(s), "answers", [p.answer for p in s[:8]])
