"""Train the draft/target transformer pair on the synthetic corpus.

Build-time only (invoked by `make artifacts`); produces
`artifacts/{draft,target}.weights.bin` + `.weights.json` consumed by
`aot.py` (which bakes nothing — weights stay runtime inputs) and by the
rust runtime. A final held-out evaluation reports the exact-match answer
accuracy of both models, giving the real capability gap that the SSD
acceptance rate is built on (recorded in EXPERIMENTS.md).

Usage: python -m compile.train [--out DIR] [--steps-target N]
       [--steps-draft N] [--batch B] [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model

TRAIN_SEQ = 80  # covers ~all corpus rows; serving s_max is 128


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def batch_iter(seed: int, batch: int):
    rng = corpus.SplitMix64(seed)
    while True:
        rows, lens = [], []
        while len(rows) < batch:
            ex = corpus.sample_training_example(rng, TRAIN_SEQ)
            if ex is None:
                continue
            toks, n = ex
            rows.append(toks)
            lens.append(n)
        yield (jnp.asarray(np.array(rows, np.int32)),
               jnp.asarray(np.array(lens, np.int32)))


# ---------------------------------------------------------------------------
# Adam (hand-rolled: optax is not in the build environment).
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    corr = jnp.sqrt(1 - b2 ** tf) / (1 - b1 ** tf)
    new = {k: params[k] - lr * corr * m[k] / (jnp.sqrt(v[k]) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


def _clip_grads(grads, max_norm=1.0):
    norm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return {k: g * scale for k, g in grads.items()}


def train_model(cfg: model.ModelConfig, steps: int, batch: int, lr: float,
                seed: int, log_every: int = 100, warmup: int = 50):
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @jax.jit
    def update(params, opt, tokens, lengths, lr_t):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, tokens, lengths))(params)
        params, opt = adam_update(params, _clip_grads(grads), opt, lr_t)
        return params, opt, loss

    it = batch_iter(seed * 7919 + 13, batch)
    t0 = time.time()
    for step in range(1, steps + 1):
        # linear warmup then cosine decay to 10% of peak
        if step <= warmup:
            lr_t = lr * step / warmup
        else:
            import math
            frac = (step - warmup) / max(1, steps - warmup)
            lr_t = lr * (0.1 + 0.9 * 0.5 * (1 + math.cos(math.pi * frac)))
        tokens, lengths = next(it)
        params, opt, loss = update(params, opt, tokens, lengths,
                                   jnp.float32(lr_t))
        if step % log_every == 0 or step == 1:
            print(f"[{cfg.name}] step {step:5d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params


# ---------------------------------------------------------------------------
# Held-out evaluation: greedy decode, exact-match answers.
# ---------------------------------------------------------------------------

def generate_greedy(cfg, params, prompts: list[list[int]], max_new: int = 90):
    """Batched greedy generation until EOS; returns list of token lists."""
    b = len(prompts)
    s = cfg.s_max
    toks = np.zeros((b, s), np.int32)
    lens = np.zeros((b,), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
        lens[i] = len(p)
    toks_j = jnp.asarray(toks)
    lens_j = jnp.asarray(lens)
    logits, k, v = jax.jit(
        lambda pr, t, l: model.prefill(cfg, pr, t, l, use_pallas=False)
    )(params, toks_j, lens_j)
    last = jnp.take_along_axis(
        logits, (lens_j - 1)[:, None, None], axis=1)[:, 0]
    cur = jnp.argmax(last, axis=-1).astype(jnp.int32)

    @jax.jit
    def gen(params, k, v, pos, cur):
        def body(carry, i):
            k, v, pos, cur, done = carry
            lg, k, v = model.decode_step(cfg, params, k, v, pos, cur,
                                         use_pallas=False)
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            active = jnp.logical_not(done)
            emit = jnp.where(active, cur, corpus.PAD)
            done = done | (cur == corpus.EOS) | (pos + 1 >= cfg.s_max - 1)
            pos = jnp.where(active, pos + 1, pos)
            cur = jnp.where(active, nxt, cur)
            return (k, v, pos, cur, done), emit

        done0 = jnp.zeros(cur.shape, bool)
        _, emits = jax.lax.scan(body, (k, v, pos, cur, done0),
                                jnp.arange(max_new))
        return emits.T

    out = np.asarray(gen(params, k, v, lens_j, cur))
    return [[int(t) for t in row if t != corpus.PAD] for row in out]


def parse_answer(tokens: list[int]) -> int | None:
    """Extract the answer from `... F <digits> .`"""
    try:
        fi = len(tokens) - 1 - tokens[::-1].index(corpus.FIN)
    except ValueError:
        return None
    digits = []
    for t in tokens[fi + 1:]:
        if corpus.DIGIT0 <= t < corpus.DIGIT0 + 10:
            digits.append(t - corpus.DIGIT0)
        else:
            break
    if not digits:
        return None
    return int("".join(map(str, digits)))


def evaluate(cfg, params, n_problems: int = 32, seed: int = 99) -> float:
    rng = corpus.SplitMix64(seed)
    problems, strategies = [], []
    while len(problems) < n_problems:
        fam = rng.below(4)
        p = corpus.gen_problem(rng, fam, 40, rng.range(2, 3))
        if not (0 <= p.answer <= 999):
            continue
        weights = [corpus.strategy_aptitude(s, fam) ** 2
                   for s in range(corpus.NUM_STRATEGIES)]
        problems.append(p)
        strategies.append(rng.choice_weighted(weights))
    correct = 0
    bs = 8
    for i in range(0, len(problems), bs):
        chunk = problems[i:i + bs]
        prompts = [corpus.prompt_tokens(p, s)
                   for p, s in zip(chunk, strategies[i:i + bs])]
        outs = generate_greedy(cfg, params, prompts)
        for p, o in zip(chunk, outs):
            if parse_answer(o) == p.answer:
                correct += 1
    return correct / len(problems)


# ---------------------------------------------------------------------------
# Weight export.
# ---------------------------------------------------------------------------

def save_weights(cfg: model.ModelConfig, params: dict, out_dir: str):
    leaves = model.flatten_params(cfg, params)
    manifest, offset = [], 0
    flat = []
    for (name, shape), leaf in zip(model.param_shapes(cfg), leaves):
        arr = np.asarray(leaf, np.float32).reshape(-1)
        manifest.append({"name": name, "shape": list(shape),
                         "offset": offset, "size": int(arr.size)})
        offset += int(arr.size)
        flat.append(arr)
    blob = np.concatenate(flat)
    with open(os.path.join(out_dir, f"{cfg.name}.weights.bin"), "wb") as f:
        f.write(blob.astype("<f4").tobytes())
    with open(os.path.join(out_dir, f"{cfg.name}.weights.json"), "w") as f:
        json.dump({"model": cfg.name, "n_elems": int(offset),
                   "params": manifest}, f, indent=1)
    print(f"[{cfg.name}] wrote {offset} f32 weights")


def load_weights(cfg: model.ModelConfig, out_dir: str) -> dict:
    with open(os.path.join(out_dir, f"{cfg.name}.weights.json")) as f:
        manifest = json.load(f)
    blob = np.fromfile(os.path.join(out_dir, f"{cfg.name}.weights.bin"),
                       dtype="<f4")
    params = {}
    for ent in manifest["params"]:
        arr = blob[ent["offset"]: ent["offset"] + ent["size"]]
        params[ent["name"]] = jnp.asarray(arr.reshape(ent["shape"]))
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps-target", type=int, default=4000)
    ap.add_argument("--steps-draft", type=int, default=1500)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--quick", action="store_true",
                    help="tiny run for smoke testing")
    ap.add_argument("--only", choices=["draft", "target"], default=None,
                    help="train just one of the two models")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.quick:
        args.steps_target, args.steps_draft = 30, 20

    results = {}
    for cfg, steps, seed in ((model.TARGET_CONFIG, args.steps_target, 1),
                             (model.DRAFT_CONFIG, args.steps_draft, 2)):
        if args.only and cfg.name != args.only:
            continue
        print(f"=== training {cfg.name}: {cfg.n_params} params, "
              f"{steps} steps ===", flush=True)
        params = train_model(cfg, steps, args.batch, args.lr, seed)
        acc = evaluate(cfg, params)
        print(f"[{cfg.name}] held-out exact-match accuracy: {acc:.3f}",
              flush=True)
        save_weights(cfg, params, args.out)
        results[cfg.name] = {"accuracy": acc, "steps": steps,
                             "params": cfg.n_params}
    tj = os.path.join(args.out, "training.json")
    if os.path.exists(tj):
        with open(tj) as f:
            prev = json.load(f)
        prev.update(results)
        results = prev
    with open(tj, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
