//! Live run migration + autoscaler, end to end on the calibrated
//! backend (no artifacts needed): drain-via-migration in O(one step),
//! in-flight shed migration to idle thieves, decision equivalence of
//! migrated runs, and the queue-driven autoscaler growing/shrinking a
//! pool under a burst without flapping (DESIGN.md §12).
//!
//! Engine-level every-step-boundary equivalence lives in
//! `coordinator::engine::tests`; backend-level bit-identity in
//! `backend::calibrated::tests`. These tests cover the serving path.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::{
    Backend, BackendMeta, LaneSnapshot, PathId, PathStats, PrefillStats, PrefixHandle,
    StepOutcome,
};
use ssr::config::{PlacePolicy, ShardClass, SpecDepth, SsrConfig, StopRule};
use ssr::coordinator::admission::QosClass;
use ssr::coordinator::autoscaler::Autoscaler;
use ssr::coordinator::engine::Method;
use ssr::coordinator::metrics::Metrics;
use ssr::coordinator::pool::{BackendPool, PoolHandle};
use ssr::coordinator::scheduler::SolveRequest;
use ssr::model::tokenizer;
use ssr::util::json::Value;
use ssr::workload::Problem;

/// Delegating wrapper that makes each generation step cost real wall
/// time (so a solve is reliably "in flight" when a drain or steal
/// happens) and signals the first step. Decisions are untouched — the
/// inner calibrated substrate drives them.
struct ThrottledBackend {
    inner: CalibratedBackend,
    step_sleep: Duration,
    started: Option<mpsc::Sender<()>>,
    /// When set, `score_step` returns all zeros: every speculative
    /// proposal is rejected, so a run's gamma EWMA collapses to 0 and
    /// the gamma rebalancer must fire deterministically.
    zero_scores: bool,
}

impl ThrottledBackend {
    fn new(
        inner: CalibratedBackend,
        step_sleep: Duration,
        started: Option<mpsc::Sender<()>>,
    ) -> Self {
        ThrottledBackend { inner, step_sleep, started, zero_scores: false }
    }

    fn zero_scores(mut self) -> Self {
        self.zero_scores = true;
        self
    }

    fn note_step(&mut self) {
        if let Some(tx) = self.started.take() {
            let _ = tx.send(());
        }
        std::thread::sleep(self.step_sleep);
    }
}

impl Backend for ThrottledBackend {
    fn meta(&self) -> BackendMeta {
        self.inner.meta()
    }

    fn select_scores(&mut self, problem: &Problem) -> Result<Vec<f32>> {
        self.inner.select_scores(problem)
    }

    fn open_paths(
        &mut self,
        problem: &Problem,
        strategies: &[Option<usize>],
        seed: u64,
        use_draft: bool,
    ) -> Result<Vec<PathId>> {
        self.inner.open_paths(problem, strategies, seed, use_draft)
    }

    fn prefill_prefix(
        &mut self,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<PrefixHandle> {
        self.inner.prefill_prefix(problem, use_draft, want_scores)
    }

    fn prefix_scores(&mut self, handle: PrefixHandle) -> Result<Vec<f32>> {
        self.inner.prefix_scores(handle)
    }

    fn fork_paths(
        &mut self,
        handle: PrefixHandle,
        strategies: &[Option<usize>],
        seed: u64,
    ) -> Result<Vec<PathId>> {
        self.inner.fork_paths(handle, strategies, seed)
    }

    fn release_prefix(&mut self, handle: PrefixHandle) -> Result<()> {
        self.inner.release_prefix(handle)
    }

    fn prefix_bytes(&self, handle: PrefixHandle) -> u64 {
        self.inner.prefix_bytes(handle)
    }

    fn prefill_stats(&self) -> PrefillStats {
        self.inner.prefill_stats()
    }

    fn draft_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.note_step();
        self.inner.draft_step(paths)
    }

    fn score_step(&mut self, paths: &[PathId]) -> Result<Vec<u8>> {
        // always drive the inner substrate so its state stays identical
        // to a reference pool using the same wrapper
        let scores = self.inner.score_step(paths)?;
        if self.zero_scores {
            return Ok(vec![0; paths.len()]);
        }
        Ok(scores)
    }

    fn rewrite_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.inner.rewrite_step(paths)
    }

    fn accept_step(&mut self, paths: &[PathId]) -> Result<()> {
        self.inner.accept_step(paths)
    }

    fn target_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.note_step();
        self.inner.target_step(paths)
    }

    fn export_lane_state(&mut self, path: PathId) -> Result<LaneSnapshot> {
        self.inner.export_lane_state(path)
    }

    fn import_lane_state(&mut self, snapshot: LaneSnapshot) -> Result<PathId> {
        self.inner.import_lane_state(snapshot)
    }

    fn trace(&self, path: PathId) -> &[i32] {
        self.inner.trace(path)
    }

    fn close_path(&mut self, path: PathId) -> Result<PathStats> {
        self.inner.close_path(path)
    }

    fn parse_answer(&self, trace: &[i32]) -> Option<i64> {
        self.inner.parse_answer(trace)
    }

    fn clock_secs(&self) -> f64 {
        self.inner.clock_secs()
    }

    fn score_histogram(&self) -> ssr::util::stats::Histogram {
        self.inner.score_histogram()
    }
}

fn submit(
    handle: &PoolHandle,
    expr: &str,
    method: Method,
    seed: u64,
) -> mpsc::Receiver<anyhow::Result<Value>> {
    let (rtx, rrx) = mpsc::channel();
    handle
        .submit(SolveRequest {
            expr: expr.to_string(),
            method,
            seed,
            deadline_ms: 0,
            class: QosClass::default(),
            reply: rtx.into(),
        })
        .unwrap();
    rrx
}

fn answer_of(v: &Value) -> Option<i64> {
    v.get_i64("answer").ok()
}

/// Reference answers: the same jobs on one untouched shard.
fn single_shard_answers(
    jobs: &[(String, Method, u64)],
    backend_seed: u64,
) -> Vec<Option<i64>> {
    let cfg = SsrConfig::default();
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) =
        BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), move |_s| {
            Ok(Box::new(CalibratedBackend::for_suite("synth-math500", backend_seed)?)
                as Box<dyn Backend>)
        })
        .unwrap();
    let mut out = Vec::new();
    for (expr, m, seed) in jobs {
        let v = submit(&handle, expr, *m, *seed).recv().unwrap().unwrap();
        out.push(answer_of(&v));
    }
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    out
}

/// Two round-robin shards with per-step wall cost; the second shard's
/// Baseline job is mid-flight when `remove_shard(1)` fires. Returns
/// (drain seconds, answers in submit order, migrations).
fn run_drain(migration: bool) -> (f64, Vec<Option<i64>>, u64) {
    let step = Duration::from_millis(15);
    let (start_tx, start_rx) = mpsc::channel::<()>();
    let starts = Arc::new(Mutex::new(start_tx));
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    cfg.migration = migration;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |_s| {
            let inner = CalibratedBackend::for_suite("synth-math500", 0xD1A)?;
            let tx = starts.lock().unwrap().clone();
            Ok(Box::new(ThrottledBackend::new(inner, step, Some(tx))) as Box<dyn Backend>)
        },
    )
    .unwrap();
    // round-robin: job 0 -> shard 0, job 1 -> shard 1
    let r0 = submit(&handle, "17+25*3", Method::Baseline, 3);
    let r1 = submit(&handle, "4+5*6", Method::Baseline, 5);
    // both shards are inside their first (throttled) step
    start_rx.recv().unwrap();
    start_rx.recv().unwrap();
    let drain_s = handle.remove_shard(1).unwrap();
    let a0 = answer_of(&r0.recv().unwrap().unwrap());
    let a1 = answer_of(&r1.recv().unwrap().unwrap());
    assert_eq!(handle.shards(), 1);
    assert_eq!(handle.load_of(1), 0, "removed shard's gauge must read 0");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0);
    assert_eq!(m.requests, 2);
    (drain_s, vec![a0, a1], m.migrations)
}

#[test]
fn drain_via_migration_is_one_step_not_one_solve() {
    // ISSUE acceptance: remove_shard under load completes in O(one
    // step) with migration, O(one solve) without — and the migrated
    // run's answer is identical either way.
    let (drain_mig, answers_mig, migrations) = run_drain(true);
    let (drain_wait, answers_wait, migrations_off) = run_drain(false);
    assert!(migrations >= 1, "drain never migrated the in-flight run");
    assert_eq!(migrations_off, 0, "migration happened with the knob off");
    assert_eq!(answers_mig, answers_wait, "migration changed decisions");
    let jobs = vec![
        ("17+25*3".to_string(), Method::Baseline, 3),
        ("4+5*6".to_string(), Method::Baseline, 5),
    ];
    assert_eq!(
        answers_mig,
        single_shard_answers(&jobs, 0xD1A),
        "migrated answers diverge from the single-shard reference"
    );
    // a Baseline solve here is ~6+ throttled steps; the migrating
    // drain waits out at most the current step (plus bookkeeping)
    assert!(
        drain_mig < drain_wait,
        "migration did not shorten the drain: {drain_mig:.3}s vs {drain_wait:.3}s"
    );
    if drain_mig > drain_wait * 0.8 {
        eprintln!(
            "[migration test] WARNING: drain speedup small ({drain_mig:.3}s vs \
             {drain_wait:.3}s) — loaded CI machine?"
        );
    }
}

#[test]
fn idle_thief_receives_migrated_in_flight_runs() {
    // Affinity pins every job to one shard and the lane pool is big
    // enough that nothing ever queues — so the only way the second
    // shard can help is in-flight migration via a shed request.
    let step = Duration::from_millis(8);
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::Affinity;
    cfg.steal_threshold = 4;
    cfg.migration = true;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |_s| {
            let inner = CalibratedBackend::for_suite("synth-math500", 0x5ED)?;
            Ok(Box::new(ThrottledBackend::new(inner, step, None)) as Box<dyn Backend>)
        },
    )
    .unwrap();
    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    let jobs: Vec<(String, Method, u64)> =
        (0..4).map(|i| ("17+25*3".to_string(), m, i as u64)).collect();
    let replies: Vec<_> =
        jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
    let answers: Vec<Option<i64>> = replies
        .iter()
        .map(|r| answer_of(&r.recv().unwrap().unwrap()))
        .collect();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let mm = metrics.lock().unwrap();
    assert_eq!(mm.errors, 0);
    assert_eq!(mm.requests, 4);
    assert!(
        mm.migrations > 0,
        "idle thief never received an in-flight run (shed migration)"
    );
    assert!(mm.migration_bytes > 0);
    drop(mm);
    assert_eq!(
        answers,
        single_shard_answers(&jobs, 0x5ED),
        "shed-migrated runs changed decisions"
    );
}

#[test]
fn autoscaler_grows_under_burst_and_shrinks_when_idle() {
    // A burst far wider than one shard's lane pool: the policy must
    // scale up (bounded by max_shards, without flapping), the burst
    // must finish correctly, and the pool must shrink back to
    // min_shards once idle.
    let step = Duration::from_millis(6);
    let mut cfg = SsrConfig::default();
    cfg.shards = 1;
    cfg.min_shards = 1;
    cfg.migration = true;
    // stealing lets the hot-added shards pull the burst's queued jobs
    cfg.steal_threshold = 8;
    cfg.autoscale.enabled = true;
    cfg.autoscale.max_shards = 3;
    cfg.autoscale.scale_up_wait_s = 0.03;
    cfg.autoscale.scale_up_queue = 1.0;
    cfg.autoscale.scale_down_occupancy = 0.3;
    cfg.autoscale.interval_ms = 10;
    cfg.autoscale.cooldown_ms = 60;
    cfg.autoscale.hysteresis = 2;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg.clone(),
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |_s| {
            let inner = CalibratedBackend::for_suite("synth-math500", 0xA5C)?;
            Ok(Box::new(ThrottledBackend::new(inner, step, None)) as Box<dyn Backend>)
        },
    )
    .unwrap();
    let mut autoscaler = Autoscaler::spawn(handle.clone(), Arc::clone(&metrics), &cfg);

    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    let jobs: Vec<(String, Method, u64)> = (0..24)
        .map(|i| (format!("{}+{}*2", i % 7 + 2, i % 5 + 3), m, i as u64))
        .collect();
    let replies: Vec<_> =
        jobs.iter().map(|(e, mm, s)| submit(&handle, e, *mm, *s)).collect();
    let mut peak_shards = handle.shards();
    let answers: Vec<Option<i64>> = replies
        .iter()
        .map(|r| {
            peak_shards = peak_shards.max(handle.shards());
            answer_of(&r.recv().unwrap().unwrap())
        })
        .collect();
    peak_shards = peak_shards.max(handle.shards());

    // idle: the policy must shrink the pool back to min_shards
    let t0 = Instant::now();
    while handle.shards() > 1 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
        peak_shards = peak_shards.max(handle.shards());
    }
    let final_shards = handle.shards();
    autoscaler.stop();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }

    let mm = metrics.lock().unwrap();
    assert_eq!(mm.errors, 0);
    assert_eq!(mm.requests, 24);
    assert!(mm.scale_ups >= 1, "burst never scaled the pool up");
    assert!(peak_shards <= 3, "autoscaler exceeded max_shards: {peak_shards}");
    assert!(
        mm.scale_ups <= 4,
        "autoscaler flapped: {} scale-ups for one burst",
        mm.scale_ups
    );
    assert_eq!(final_shards, 1, "pool never shrank back to min_shards");
    assert!(mm.scale_downs >= 1);
    // equivalence holds across the scaling pool (placement-invariant
    // run seeds + migrated lanes carrying their state)
    drop(mm);
    assert_eq!(
        answers,
        single_shard_answers(&jobs, 0xA5C),
        "autoscaled pool changed decisions"
    );
}

#[test]
fn fixed_depth_runs_survive_shed_migration_unchanged() {
    // Satellite of the spec-depth ISSUE: `--spec-depth fixed:4` runs
    // that get shed-migrated mid-flight must still match the depth-1
    // single-shard reference — depth is clock-only, and the burst state
    // is never split across a migration boundary.
    let step = Duration::from_millis(8);
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::Affinity;
    cfg.steal_threshold = 4;
    cfg.migration = true;
    cfg.spec_depth = SpecDepth::Fixed(4);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |_s| {
            let inner = CalibratedBackend::for_suite("synth-math500", 0x5ED)?;
            Ok(Box::new(ThrottledBackend::new(inner, step, None)) as Box<dyn Backend>)
        },
    )
    .unwrap();
    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    let jobs: Vec<(String, Method, u64)> =
        (0..4).map(|i| ("17+25*3".to_string(), m, i as u64)).collect();
    let replies: Vec<_> =
        jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
    let answers: Vec<Option<i64>> = replies
        .iter()
        .map(|r| answer_of(&r.recv().unwrap().unwrap()))
        .collect();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let mm = metrics.lock().unwrap();
    assert_eq!(mm.errors, 0);
    assert!(mm.migrations > 0, "the affinity-pinned burst never shed a run");
    drop(mm);
    assert_eq!(
        answers,
        single_shard_answers(&jobs, 0x5ED),
        "fixed:4 shed-migrated runs diverge from the depth-1 reference"
    );
}

#[test]
fn gamma_collapse_migrates_runs_to_target_heavy_without_changing_decisions() {
    // Deterministic collapse: zeroed scores reject every speculative
    // proposal, so each Ssr run's gamma EWMA pins to 0. Runs placed on
    // the balanced shard must breach the collapse threshold and migrate
    // to the target-heavy shard (hysteresis permitting), with decisions
    // identical to a single-shard pool using the same zeroed wrapper.
    let build = |shards: usize, classes: Vec<ShardClass>| {
        let mut cfg = SsrConfig::default();
        cfg.shards = shards;
        cfg.placement = PlacePolicy::RoundRobin;
        cfg.migration = true;
        cfg.shard_classes = classes;
        cfg.spec_depth = SpecDepth::Adaptive { max: 4 };
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |_s| {
                let inner = CalibratedBackend::for_suite("synth-math500", 0xC011)?;
                Ok(Box::new(
                    ThrottledBackend::new(inner, Duration::ZERO, None).zero_scores(),
                ) as Box<dyn Backend>)
            },
        )
        .unwrap();
        (handle, joins, metrics)
    };
    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    let jobs: Vec<(String, Method, u64)> = (0..6)
        .map(|i| (format!("{}+{}*2", i % 7 + 2, i % 5 + 3), m, i as u64))
        .collect();

    let run = |shards: usize, classes: Vec<ShardClass>| -> (Vec<Option<i64>>, u64) {
        let (handle, joins, metrics) = build(shards, classes);
        let replies: Vec<_> =
            jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
        let answers: Vec<Option<i64>> = replies
            .iter()
            .map(|r| answer_of(&r.recv().unwrap().unwrap()))
            .collect();
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let mm = metrics.lock().unwrap();
        assert_eq!(mm.errors, 0);
        (answers, mm.gamma_migrations)
    };

    // round-robin: three runs land on the balanced shard, all collapsed
    let (answers, gamma_moves) =
        run(2, vec![ShardClass::Balanced, ShardClass::TargetHeavy]);
    let (reference, reference_moves) = run(1, Vec::new());
    assert_eq!(reference_moves, 0, "a classless pool performed a class move");
    assert!(
        gamma_moves >= 1,
        "no collapsed run migrated to the target-heavy shard"
    );
    assert_eq!(answers, reference, "gamma-driven migration changed decisions");
}
