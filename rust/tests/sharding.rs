//! Sharded execution layer, end to end on the calibrated backend (no
//! artifacts needed): placement policies, shared-tier semantics,
//! generation-counted handle safety, and the ISSUE acceptance that a
//! sharded run is vote/decision-equivalent to a single-shard run on the
//! same workload.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::Backend;
use ssr::config::{PlacePolicy, SsrConfig, StopRule};
use ssr::coordinator::engine::Method;
use ssr::coordinator::metrics::Metrics;
use ssr::coordinator::pool::{BackendPool, PoolHandle};
use ssr::coordinator::scheduler::SolveRequest;
use ssr::model::tokenizer;
use ssr::util::json::Value;

/// Spawn an N-shard pool; every shard's backend gets the SAME seed, so
/// the calibrated substrate's derived per-problem streams make results
/// independent of placement (DESIGN.md §10).
fn spawn(
    shards: usize,
    placement: PlacePolicy,
    backend_seed: u64,
) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
    let mut cfg = SsrConfig::default();
    cfg.shards = shards;
    cfg.placement = placement;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) =
        BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), move |_s| {
            Ok(Box::new(CalibratedBackend::for_suite("synth-math500", backend_seed)?)
                as Box<dyn Backend>)
        })
        .unwrap();
    (handle, joins, metrics)
}

fn submit(
    handle: &PoolHandle,
    expr: &str,
    method: Method,
    seed: u64,
) -> mpsc::Receiver<anyhow::Result<Value>> {
    let (rtx, rrx) = mpsc::channel();
    handle
        .submit(SolveRequest { expr: expr.to_string(), method, seed, reply: rtx })
        .unwrap();
    rrx
}

/// The mixed workload every equivalence comparison runs: distinct
/// prompts so token accounting is placement-independent too (a repeated
/// prompt pays its one-time fork billing on each shard that first
/// serves it, which is cost- but not decision-visible).
fn workload() -> Vec<(String, Method, u64)> {
    let mut jobs = Vec::new();
    for i in 0..10u64 {
        let method = match i % 3 {
            0 => Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
            1 => Method::Baseline,
            _ => Method::Parallel { n: 4, spm: true },
        };
        jobs.push((format!("{}+{}*{}", i + 2, i + 3, 2 + i % 3), method, i));
    }
    jobs
}

/// Run the workload through a pool and collect, per job, the reply
/// fields that must be placement-invariant.
fn run_workload(
    shards: usize,
    placement: PlacePolicy,
) -> Vec<BTreeMap<String, String>> {
    let (handle, joins, metrics) = spawn(shards, placement, 0xD15C);
    let replies: Vec<_> = workload()
        .into_iter()
        .map(|(expr, method, seed)| submit(&handle, &expr, method, seed))
        .collect();
    let out: Vec<BTreeMap<String, String>> = replies
        .iter()
        .map(|r| {
            let v = r.recv().unwrap().unwrap();
            ["answer", "correct", "gold", "method", "steps", "rewrites", "draft_tokens",
                "target_tokens"]
                .iter()
                .map(|k| (k.to_string(), format!("{:?}", v.get(k).unwrap())))
                .collect()
        })
        .collect();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(metrics.lock().unwrap().errors, 0);
    out
}

#[test]
fn sharded_run_is_decision_equivalent_to_single_shard() {
    // ISSUE acceptance: identical answers, vote-visible step counts and
    // token ledgers for 1 shard vs 2 shards vs 3 shards, across every
    // placement policy — the placement layer must be invisible to
    // decisions.
    let baseline = run_workload(1, PlacePolicy::LeastLoaded);
    for (shards, placement) in [
        (2, PlacePolicy::LeastLoaded),
        (2, PlacePolicy::Affinity),
        (2, PlacePolicy::RoundRobin),
        (3, PlacePolicy::LeastLoaded),
    ] {
        let sharded = run_workload(shards, placement);
        assert_eq!(
            baseline, sharded,
            "results diverge at shards={shards} placement={placement:?}"
        );
    }
}

#[test]
fn least_loaded_spreads_round_robin_rotates() {
    for placement in [PlacePolicy::LeastLoaded, PlacePolicy::RoundRobin] {
        let (handle, joins, metrics) = spawn(2, placement, 1);
        let replies: Vec<_> = (0..8)
            .map(|i| {
                submit(
                    &handle,
                    &format!("{}+{}", i + 1, i + 5),
                    Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                    i,
                )
            })
            .collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 8);
        assert_eq!(m.shard_requests.iter().sum::<u64>(), 8);
        assert!(
            m.shard_requests.iter().all(|&r| r >= 1),
            "{placement:?} starved a shard: {:?}",
            m.shard_requests
        );
    }
}

#[test]
fn shared_tier_admits_known_prompts_and_refills_once_per_shard() {
    // Round-robin the SAME prompt across 2 shards: one logical miss,
    // exactly one re-prefill on the second shard, hits thereafter.
    let (handle, joins, metrics) = spawn(2, PlacePolicy::RoundRobin, 2);
    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    for seed in 0..6u64 {
        let r = submit(&handle, "17+25*3", m, seed);
        assert!(r.recv().unwrap().is_ok());
    }
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let mm = metrics.lock().unwrap();
    assert_eq!(mm.requests, 6);
    assert_eq!(mm.prefix_misses, 1, "one logical miss for one prompt");
    assert_eq!(
        mm.prefix_shard_fills, 1,
        "a prompt must be re-prefilled at most once per extra shard"
    );
    assert_eq!(mm.prefix_hits, 5, "every acquisition after the miss is a tier hit");
}

#[test]
fn stale_prefix_handles_rejected_at_type_level() {
    // The SlotMap generation counter: a released handle stays dead even
    // after its slot is recycled — fork/score on it error instead of
    // silently reading the new occupant.
    let v = tokenizer::builtin_vocab();
    let p1 = ssr::workload::problems::problem_from_text(&v, "17+25*3").unwrap();
    let p2 = ssr::workload::problems::problem_from_text(&v, "4+5*6").unwrap();
    let mut b = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
    let h1 = b.prefill_prefix(&p1, true, true).unwrap();
    b.release_prefix(h1).unwrap();
    // slot is recycled by the NEXT prefix…
    let h2 = b.prefill_prefix(&p2, true, true).unwrap();
    assert_ne!(h1, h2);
    // …yet the stale handle cannot touch it
    assert!(b.fork_paths(h1, &[Some(0)], 1).is_err());
    assert!(b.prefix_scores(h1).is_err());
    assert_eq!(b.prefix_bytes(h1), 0);
    // and the live handle works
    let ids = b.fork_paths(h2, &[Some(0)], 1).unwrap();
    assert_eq!(ids.len(), 1);
}

#[test]
fn pool_survives_malformed_requests_across_shards() {
    let (handle, joins, metrics) = spawn(2, PlacePolicy::RoundRobin, 5);
    let bad = submit(&handle, "1+", Method::Baseline, 0);
    assert!(bad.recv().unwrap().is_err());
    let good: Vec<_> =
        (0..4).map(|i| submit(&handle, "2+3", Method::Baseline, i)).collect();
    for r in &good {
        assert!(r.recv().unwrap().is_ok());
    }
    // the failed parse returned its load estimate: gauges drain to zero
    assert_eq!(handle.load_of(0) + handle.load_of(1), 0);
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 1);
    assert_eq!(m.requests, 4);
}
