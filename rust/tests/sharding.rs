//! Sharded execution layer, end to end on the calibrated backend (no
//! artifacts needed): placement policies, shared-tier semantics,
//! generation-counted handle safety, the elastic shard lifecycle
//! (hot-add/remove, drain-while-serving, cross-shard work stealing,
//! concurrent prefill latch), and the ISSUE acceptance that a sharded
//! run is vote/decision-equivalent to a single-shard run on the same
//! workload — including after add/remove/steal.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::{
    Backend, BackendMeta, LaneSnapshot, PathId, PathStats, PrefillStats, PrefixHandle,
    StepOutcome,
};
use ssr::config::{PlacePolicy, ShardClass, SpecDepth, SsrConfig, StopRule};
use ssr::coordinator::admission::QosClass;
use ssr::coordinator::engine::Method;
use ssr::coordinator::metrics::Metrics;
use ssr::coordinator::pool::{BackendPool, PoolHandle};
use ssr::coordinator::prefix::SharedPrefixTier;
use ssr::coordinator::scheduler::SolveRequest;
use ssr::model::tokenizer;
use ssr::util::json::Value;
use ssr::workload::problems::problem_from_text;
use ssr::workload::Problem;

/// Spawn an N-shard pool; every shard's backend gets the SAME seed, so
/// the calibrated substrate's derived per-problem streams make results
/// independent of placement (DESIGN.md §10). `tweak` mutates the config
/// after the shard/placement fields are set (spec depth, shard classes,
/// ...).
fn spawn_with(
    shards: usize,
    placement: PlacePolicy,
    backend_seed: u64,
    tweak: impl FnOnce(&mut SsrConfig),
) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
    let mut cfg = SsrConfig::default();
    cfg.shards = shards;
    cfg.placement = placement;
    tweak(&mut cfg);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) =
        BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), move |_s| {
            Ok(Box::new(CalibratedBackend::for_suite("synth-math500", backend_seed)?)
                as Box<dyn Backend>)
        })
        .unwrap();
    (handle, joins, metrics)
}

fn spawn(
    shards: usize,
    placement: PlacePolicy,
    backend_seed: u64,
) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
    spawn_with(shards, placement, backend_seed, |_| {})
}

fn submit(
    handle: &PoolHandle,
    expr: &str,
    method: Method,
    seed: u64,
) -> mpsc::Receiver<anyhow::Result<Value>> {
    let (rtx, rrx) = mpsc::channel();
    handle
        .submit(SolveRequest {
            expr: expr.to_string(),
            method,
            seed,
            deadline_ms: 0,
            class: QosClass::default(),
            reply: rtx.into(),
        })
        .unwrap();
    rrx
}

/// The mixed workload every equivalence comparison runs: distinct
/// prompts so token accounting is placement-independent too (a repeated
/// prompt pays its one-time fork billing on each shard that first
/// serves it, which is cost- but not decision-visible).
fn workload() -> Vec<(String, Method, u64)> {
    let mut jobs = Vec::new();
    for i in 0..10u64 {
        let method = match i % 3 {
            0 => Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
            1 => Method::Baseline,
            _ => Method::Parallel { n: 4, spm: true },
        };
        jobs.push((format!("{}+{}*{}", i + 2, i + 3, 2 + i % 3), method, i));
    }
    jobs
}

/// Run the workload through a pool and collect, per job, the reply
/// fields that must be placement-invariant.
fn run_workload(
    shards: usize,
    placement: PlacePolicy,
) -> Vec<BTreeMap<String, String>> {
    run_workload_with(shards, placement, |_| {})
}

/// `run_workload` with a config tweak applied before spawn — the vector
/// the speculation-equivalence tests compare against the stock pool.
fn run_workload_with(
    shards: usize,
    placement: PlacePolicy,
    tweak: impl FnOnce(&mut SsrConfig),
) -> Vec<BTreeMap<String, String>> {
    let (handle, joins, metrics) = spawn_with(shards, placement, 0xD15C, tweak);
    let replies: Vec<_> = workload()
        .into_iter()
        .map(|(expr, method, seed)| submit(&handle, &expr, method, seed))
        .collect();
    let out: Vec<BTreeMap<String, String>> = replies
        .iter()
        .map(|r| {
            let v = r.recv().unwrap().unwrap();
            ["answer", "correct", "gold", "method", "steps", "rewrites", "draft_tokens",
                "target_tokens"]
                .iter()
                .map(|k| (k.to_string(), format!("{:?}", v.get(k).unwrap())))
                .collect()
        })
        .collect();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(metrics.lock().unwrap().errors, 0);
    out
}

#[test]
fn sharded_run_is_decision_equivalent_to_single_shard() {
    // ISSUE acceptance: identical answers, vote-visible step counts and
    // token ledgers for 1 shard vs 2 shards vs 3 shards, across every
    // placement policy — the placement layer must be invisible to
    // decisions.
    let baseline = run_workload(1, PlacePolicy::LeastLoaded);
    for (shards, placement) in [
        (2, PlacePolicy::LeastLoaded),
        (2, PlacePolicy::Affinity),
        (2, PlacePolicy::RoundRobin),
        (3, PlacePolicy::LeastLoaded),
    ] {
        let sharded = run_workload(shards, placement);
        assert_eq!(
            baseline, sharded,
            "results diverge at shards={shards} placement={placement:?}"
        );
    }
}

#[test]
fn fixed_depth_pools_are_decision_equivalent_to_depth_one() {
    // ISSUE acceptance: `--spec-depth fixed:<k>` reproduces today's
    // behavior bit-identically. Depth only reshapes the draft burst
    // inside a tick; every vote-visible field — answers, step counts,
    // rewrites, token ledgers — must match the stock (fixed:1) pool on
    // the same workload, sharded and single-shard alike.
    let baseline = run_workload(1, PlacePolicy::LeastLoaded);
    for k in [2usize, 4, 8] {
        for (shards, placement) in
            [(1, PlacePolicy::LeastLoaded), (2, PlacePolicy::RoundRobin), (3, PlacePolicy::Affinity)]
        {
            let deep = run_workload_with(shards, placement, |cfg| {
                cfg.spec_depth = SpecDepth::Fixed(k);
            });
            assert_eq!(
                baseline, deep,
                "fixed:{k} diverges at shards={shards} placement={placement:?}"
            );
        }
    }
}

#[test]
fn adaptive_depth_and_shard_classes_never_change_decisions() {
    // Adaptive speculation and heterogeneous shard classes are pure
    // cost/clock concerns: the controller widens or narrows the draft
    // burst and the rebalancer moves runs between classes, but every
    // decision-visible reply field stays bit-identical to the stock
    // homogeneous fixed:1 pool.
    let baseline = run_workload(1, PlacePolicy::LeastLoaded);
    let adaptive = run_workload_with(2, PlacePolicy::LeastLoaded, |cfg| {
        cfg.spec_depth = SpecDepth::Adaptive { max: 8 };
    });
    assert_eq!(baseline, adaptive, "adaptive depth changed decisions");
    let hetero = run_workload_with(3, PlacePolicy::LeastLoaded, |cfg| {
        cfg.spec_depth = SpecDepth::Adaptive { max: 8 };
        cfg.shard_classes =
            vec![ShardClass::DraftHeavy, ShardClass::Balanced, ShardClass::TargetHeavy];
    });
    assert_eq!(baseline, hetero, "shard classes leaked into decisions");
}

#[test]
fn least_loaded_spreads_round_robin_rotates() {
    for placement in [PlacePolicy::LeastLoaded, PlacePolicy::RoundRobin] {
        let (handle, joins, metrics) = spawn(2, placement, 1);
        let replies: Vec<_> = (0..8)
            .map(|i| {
                submit(
                    &handle,
                    &format!("{}+{}", i + 1, i + 5),
                    Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                    i,
                )
            })
            .collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 8);
        assert_eq!(m.total_shard_requests(), 8);
        assert!(
            m.shard_requests.values().all(|&r| r >= 1),
            "{placement:?} starved a shard: {:?}",
            m.shard_requests
        );
    }
}

#[test]
fn shared_tier_admits_known_prompts_and_refills_once_per_shard() {
    // Round-robin the SAME prompt across 2 shards: one logical miss,
    // exactly one re-prefill on the second shard, hits thereafter.
    let (handle, joins, metrics) = spawn(2, PlacePolicy::RoundRobin, 2);
    let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
    for seed in 0..6u64 {
        let r = submit(&handle, "17+25*3", m, seed);
        assert!(r.recv().unwrap().is_ok());
    }
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let mm = metrics.lock().unwrap();
    assert_eq!(mm.requests, 6);
    assert_eq!(mm.prefix_misses, 1, "one logical miss for one prompt");
    assert_eq!(
        mm.prefix_shard_fills, 1,
        "a prompt must be re-prefilled at most once per extra shard"
    );
    assert_eq!(mm.prefix_hits, 5, "every acquisition after the miss is a tier hit");
}

#[test]
fn stale_prefix_handles_rejected_at_type_level() {
    // The SlotMap generation counter: a released handle stays dead even
    // after its slot is recycled — fork/score on it error instead of
    // silently reading the new occupant.
    let v = tokenizer::builtin_vocab();
    let p1 = ssr::workload::problems::problem_from_text(&v, "17+25*3").unwrap();
    let p2 = ssr::workload::problems::problem_from_text(&v, "4+5*6").unwrap();
    let mut b = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
    let h1 = b.prefill_prefix(&p1, true, true).unwrap();
    b.release_prefix(h1).unwrap();
    // slot is recycled by the NEXT prefix…
    let h2 = b.prefill_prefix(&p2, true, true).unwrap();
    assert_ne!(h1, h2);
    // …yet the stale handle cannot touch it
    assert!(b.fork_paths(h1, &[Some(0)], 1).is_err());
    assert!(b.prefix_scores(h1).is_err());
    assert_eq!(b.prefix_bytes(h1), 0);
    // and the live handle works
    let ids = b.fork_paths(h2, &[Some(0)], 1).unwrap();
    assert_eq!(ids.len(), 1);
}

// ---------------------------------------------------------------------------
// Elastic lifecycle: stealing, drain-while-serving, concurrent prefill
// ---------------------------------------------------------------------------

/// Delegating backend wrapper with test gates: `prefill` mode signals
/// entry into `prefill_prefix` and blocks there until released (the
/// concurrent-prefill latch probe); `step` mode does the same for the
/// FIRST `target_step` (the drain-ordering probe). All other calls pass
/// straight through to the calibrated substrate.
struct GatedBackend {
    inner: CalibratedBackend,
    entered: mpsc::Sender<()>,
    prefill_gate: Option<mpsc::Receiver<()>>,
    step_gate: Option<mpsc::Receiver<()>>,
}

impl GatedBackend {
    fn prefill_gated(
        inner: CalibratedBackend,
        entered: mpsc::Sender<()>,
        gate: mpsc::Receiver<()>,
    ) -> Self {
        GatedBackend { inner, entered, prefill_gate: Some(gate), step_gate: None }
    }

    fn step_gated(
        inner: CalibratedBackend,
        entered: mpsc::Sender<()>,
        gate: mpsc::Receiver<()>,
    ) -> Self {
        GatedBackend { inner, entered, prefill_gate: None, step_gate: Some(gate) }
    }
}

impl Backend for GatedBackend {
    fn meta(&self) -> BackendMeta {
        self.inner.meta()
    }

    fn select_scores(&mut self, problem: &Problem) -> Result<Vec<f32>> {
        self.inner.select_scores(problem)
    }

    fn open_paths(
        &mut self,
        problem: &Problem,
        strategies: &[Option<usize>],
        seed: u64,
        use_draft: bool,
    ) -> Result<Vec<PathId>> {
        self.inner.open_paths(problem, strategies, seed, use_draft)
    }

    fn prefill_prefix(
        &mut self,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<PrefixHandle> {
        if let Some(gate) = self.prefill_gate.take() {
            let _ = self.entered.send(());
            let _ = gate.recv();
        }
        self.inner.prefill_prefix(problem, use_draft, want_scores)
    }

    fn prefix_scores(&mut self, handle: PrefixHandle) -> Result<Vec<f32>> {
        self.inner.prefix_scores(handle)
    }

    fn fork_paths(
        &mut self,
        handle: PrefixHandle,
        strategies: &[Option<usize>],
        seed: u64,
    ) -> Result<Vec<PathId>> {
        self.inner.fork_paths(handle, strategies, seed)
    }

    fn release_prefix(&mut self, handle: PrefixHandle) -> Result<()> {
        self.inner.release_prefix(handle)
    }

    fn prefix_bytes(&self, handle: PrefixHandle) -> u64 {
        self.inner.prefix_bytes(handle)
    }

    fn prefill_stats(&self) -> PrefillStats {
        self.inner.prefill_stats()
    }

    fn draft_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.inner.draft_step(paths)
    }

    fn score_step(&mut self, paths: &[PathId]) -> Result<Vec<u8>> {
        self.inner.score_step(paths)
    }

    fn rewrite_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.inner.rewrite_step(paths)
    }

    fn accept_step(&mut self, paths: &[PathId]) -> Result<()> {
        self.inner.accept_step(paths)
    }

    fn target_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        if let Some(gate) = self.step_gate.take() {
            let _ = self.entered.send(());
            let _ = gate.recv();
        }
        self.inner.target_step(paths)
    }

    fn export_lane_state(&mut self, path: PathId) -> Result<LaneSnapshot> {
        self.inner.export_lane_state(path)
    }

    fn import_lane_state(&mut self, snapshot: LaneSnapshot) -> Result<PathId> {
        self.inner.import_lane_state(snapshot)
    }

    fn trace(&self, path: PathId) -> &[i32] {
        self.inner.trace(path)
    }

    fn close_path(&mut self, path: PathId) -> Result<PathStats> {
        self.inner.close_path(path)
    }

    fn parse_answer(&self, trace: &[i32]) -> Option<i64> {
        self.inner.parse_answer(trace)
    }

    fn clock_secs(&self) -> f64 {
        self.inner.clock_secs()
    }

    fn score_histogram(&self) -> ssr::util::stats::Histogram {
        self.inner.score_histogram()
    }
}

/// Run a skewed workload (one hot prompt, affinity placement -> every
/// job lands on one shard) and collect the decision-visible reply
/// fields. Token ledgers are excluded on purpose: a repeated prompt
/// pays its one-time prefill per serving shard, which is cost- but not
/// decision-visible (DESIGN.md §10).
fn run_skewed(
    shards: usize,
    steal_threshold: usize,
) -> (Vec<BTreeMap<String, String>>, u64, BTreeMap<usize, u64>) {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let gate = Arc::new(Mutex::new(gate_rx));
    let mut cfg = SsrConfig::default();
    cfg.shards = shards;
    cfg.placement = PlacePolicy::Affinity;
    cfg.max_lanes = 5;
    cfg.steal_threshold = steal_threshold;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |_s| {
            let _ = gate.lock().unwrap().recv();
            Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 0xE1A)?)
                as Box<dyn Backend>)
        },
    )
    .unwrap();
    let m = Method::Ssr { n: 5, tau: 7, stop: StopRule::Full };
    // queue everything before any backend exists, then open the gates:
    // the victim's queue is full when the thief wakes up
    let replies: Vec<_> = (0..32).map(|i| submit(&handle, "17+25*3", m, i)).collect();
    for _ in 0..shards {
        gate_tx.send(()).unwrap();
    }
    let out: Vec<BTreeMap<String, String>> = replies
        .iter()
        .map(|r| {
            let v = r.recv().unwrap().unwrap();
            ["answer", "correct", "gold", "steps", "rewrites"]
                .iter()
                .map(|k| (k.to_string(), format!("{:?}", v.get(k).unwrap())))
                .collect()
        })
        .collect();
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let mm = metrics.lock().unwrap();
    assert_eq!(mm.errors, 0);
    (out, mm.steals, mm.shard_requests.clone())
}

#[test]
fn work_stealing_rebalances_skew_and_preserves_decisions() {
    let (base, steals_base, _) = run_skewed(1, 0);
    let (off, steals_off, req_off) = run_skewed(2, 0);
    let (on, steals_on, req_on) = run_skewed(2, 4);
    // stolen runs re-derive state from the placement-invariant run
    // seed, so every decision-visible field matches the single-shard
    // and no-steal runs (ISSUE acceptance)
    assert_eq!(base, off, "no-steal sharded run diverged from single shard");
    assert_eq!(base, on, "stolen runs changed decisions");
    assert_eq!(steals_base, 0);
    assert_eq!(steals_off, 0, "stealing happened with steal_threshold=0");
    assert!(steals_on > 0, "skewed load never triggered a steal");
    // without stealing, affinity starves the second shard...
    assert_eq!(req_off.values().filter(|&&r| r > 0).count(), 1, "{req_off:?}");
    // ...with stealing, both shards end up serving
    assert!(
        req_on.values().filter(|&&r| r > 0).count() == 2,
        "thief never served stolen work: {req_on:?}"
    );
}

#[test]
fn remove_shard_waits_for_inflight_and_pool_keeps_serving() {
    // shard 1's backend blocks inside its first target_step, so its
    // Baseline job is guaranteed mid-flight when the drain starts.
    // Migration is OFF here on purpose: this pins the PR-4 drain
    // semantics (wait out the in-flight solve); the O(one step)
    // migration drain is covered in tests/migration.rs.
    let (enter_tx, enter_rx) = mpsc::channel();
    let (go_tx, go_rx) = mpsc::channel();
    let gates = Arc::new(Mutex::new(Some((enter_tx, go_rx))));
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    cfg.migration = false;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = CalibratedBackend::for_suite("synth-math500", 4)?;
            if shard == 1 {
                let (etx, grx) = gates.lock().unwrap().take().expect("one gated shard");
                Ok(Box::new(GatedBackend::step_gated(inner, etx, grx)) as Box<dyn Backend>)
            } else {
                Ok(Box::new(inner) as Box<dyn Backend>)
            }
        },
    )
    .unwrap();
    let r0 = submit(&handle, "2+3", Method::Baseline, 0);
    let r1 = submit(&handle, "4+5", Method::Baseline, 1);
    enter_rx.recv().unwrap(); // shard 1 is now mid-step on its job
    let remover = {
        let h = handle.clone();
        std::thread::spawn(move || h.remove_shard(1).unwrap())
    };
    // the drain must not complete while shard 1's run is in flight
    std::thread::sleep(Duration::from_millis(50));
    assert!(!remover.is_finished(), "remove_shard returned before in-flight runs finished");
    // the surviving shard keeps serving mid-drain
    assert!(r0.recv().unwrap().is_ok());
    let r2 = submit(&handle, "6+7", Method::Baseline, 2);
    assert!(r2.recv().unwrap().is_ok());
    go_tx.send(()).unwrap();
    let drain_s = remover.join().unwrap();
    assert!(drain_s >= 0.0);
    assert!(r1.recv().unwrap().is_ok(), "the drained shard's in-flight job was lost");
    assert_eq!(handle.shards(), 1);
    assert_eq!(handle.load_of(0), 0);
    assert_eq!(handle.load_of(1), 0, "removed shard's gauge must read 0");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0);
    assert_eq!(m.requests, 3);
    assert_eq!(m.shards_removed, 1);
    assert!(m.drain_secs_max > 0.0, "gated drain must have measurable duration");
}

#[test]
fn add_and_remove_shards_preserve_decision_equivalence() {
    // the same workload solved on a static 1-shard pool and on a pool
    // that grows to 3 and shrinks back mid-stream must decide
    // identically (ISSUE acceptance: equivalence after add/remove)
    let jobs = workload();
    let solo: Vec<_> = {
        let (handle, joins, _m) = spawn(1, PlacePolicy::RoundRobin, 0xADD);
        let replies: Vec<_> = jobs
            .iter()
            .map(|(e, m, s)| submit(&handle, e, *m, *s))
            .collect();
        let out = replies.iter().map(|r| {
            let v = r.recv().unwrap().unwrap();
            (format!("{:?}", v.get("answer").unwrap()), v.get_i64("steps").unwrap())
        });
        let out: Vec<_> = out.collect();
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        out
    };
    let (handle, joins, metrics) = spawn(1, PlacePolicy::RoundRobin, 0xADD);
    let mut elastic = Vec::new();
    for (i, (e, m, s)) in jobs.iter().enumerate() {
        if i == 3 {
            handle.add_shard().unwrap();
            handle.add_shard().unwrap();
        }
        if i == 7 {
            let removable = handle.shards() > 1;
            assert!(removable);
            handle.remove_shard(1).unwrap();
        }
        let r = submit(&handle, e, *m, *s);
        let v = r.recv().unwrap().unwrap();
        elastic.push((format!("{:?}", v.get("answer").unwrap()), v.get_i64("steps").unwrap()));
    }
    assert_eq!(solo, elastic, "elastic lifecycle changed decisions");
    assert_eq!(handle.shards(), 2);
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!((m.shards_added, m.shards_removed), (2, 1));
    assert_eq!(m.errors, 0);
}

#[test]
fn tier_prefill_runs_outside_the_lock() {
    // shard 0 blocks INSIDE prefill_prefix; under the old
    // prefill-under-lock tier, shard 1's acquisition of a different
    // prompt would deadlock here instead of completing
    let v = tokenizer::builtin_vocab();
    let p0 = problem_from_text(&v, "17+25*3").unwrap();
    let p1 = problem_from_text(&v, "4+5*6").unwrap();
    let tier = Arc::new(SharedPrefixTier::new(8, 0));
    let (enter_tx, enter_rx) = mpsc::channel();
    let (go_tx, go_rx) = mpsc::channel();
    let filler = {
        let tier = Arc::clone(&tier);
        let p0 = p0.clone();
        std::thread::spawn(move || {
            let inner = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
            let mut b0 = GatedBackend::prefill_gated(inner, enter_tx, go_rx);
            let a = tier.acquire_for_shard(0, &mut b0, &p0, false, false).unwrap();
            (a.hit, b0.prefill_stats().prefixes)
        })
    };
    enter_rx.recv().unwrap(); // shard 0 is inside prefill, tier unlocked
    let mut b1 = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
    let a1 = tier.acquire_for_shard(1, &mut b1, &p1, false, false).unwrap();
    assert!(!a1.hit && a1.retained, "concurrent prefill on another shard must proceed");
    go_tx.send(()).unwrap();
    let (hit0, prefills0) = filler.join().unwrap();
    assert!(!hit0);
    assert_eq!(prefills0, 1);
    // steady state after the latch resolves: both shards hit
    let r1 = tier.acquire_for_shard(1, &mut b1, &p1, false, false).unwrap();
    assert!(r1.hit);
    let s = tier.stats();
    assert_eq!((s.misses, s.shard_fills), (2, 0));
}

#[test]
fn concurrent_shards_prefill_each_prompt_once_per_shard() {
    // two shard threads hammer the same prompt set through the latch:
    // each backend must prefill each prompt exactly once, and the tier
    // totals must be exact regardless of interleaving
    let v = tokenizer::builtin_vocab();
    let prompts: Vec<Problem> = (0..4)
        .map(|i| problem_from_text(&v, &format!("{}+{}*2", i + 3, i + 4)).unwrap())
        .collect();
    let tier = Arc::new(SharedPrefixTier::new(16, 0));
    let threads: Vec<_> = (0..2)
        .map(|shard| {
            let tier = Arc::clone(&tier);
            let prompts = prompts.clone();
            std::thread::spawn(move || {
                let mut b = CalibratedBackend::for_suite("synth-math500", 9).unwrap();
                for _round in 0..3 {
                    for p in &prompts {
                        let a = tier.acquire_for_shard(shard, &mut b, p, true, false).unwrap();
                        assert!(a.retained);
                    }
                }
                b.prefill_stats().prefixes
            })
        })
        .collect();
    let counts: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert_eq!(counts, vec![4, 4], "a shard prefilled a prompt more than once");
    let s = tier.stats();
    assert_eq!(s.misses, 4, "one logical miss per prompt");
    assert_eq!(s.shard_fills, 4, "one shard fill per prompt on the second shard");
    assert_eq!(s.hits, 20, "2 shards x 3 rounds x 4 prompts - 4 misses");
}

#[test]
fn pool_survives_malformed_requests_across_shards() {
    let (handle, joins, metrics) = spawn(2, PlacePolicy::RoundRobin, 5);
    let bad = submit(&handle, "1+", Method::Baseline, 0);
    assert!(bad.recv().unwrap().is_err());
    let good: Vec<_> =
        (0..4).map(|i| submit(&handle, "2+3", Method::Baseline, i)).collect();
    for r in &good {
        assert!(r.recv().unwrap().is_ok());
    }
    // the failed parse returned its load estimate: gauges drain to zero
    assert_eq!(handle.load_of(0) + handle.load_of(1), 0);
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 1);
    assert_eq!(m.requests, 4);
}
