//! Engine-level property tests over the calibrated backend: randomized
//! (method, config, problem) combinations must preserve the coordinator
//! invariants regardless of sampling.

use anyhow::ensure;
use ssr::backend::calibrated::CalibratedBackend;
use ssr::config::{Selection, SsrConfig, StopRule};
use ssr::coordinator::engine::{Engine, Method};
use ssr::model::tokenizer;
use ssr::util::prop::{self, gen};
use ssr::workload::suites;

fn random_method(rng: &mut ssr::util::rng::Rng) -> Method {
    match rng.below(4) {
        0 => Method::Baseline,
        1 => Method::Parallel { n: 1 + gen::index(rng, 5), spm: rng.chance(0.5) },
        2 => Method::SpecReason { tau: rng.below(10) as u8 },
        _ => Method::Ssr {
            n: 1 + gen::index(rng, 5),
            tau: rng.below(10) as u8,
            stop: [StopRule::Full, StopRule::Fast1, StopRule::Fast2][gen::index(rng, 3)],
        },
    }
}

#[test]
fn engine_invariants_hold_for_random_configurations() {
    let v = tokenizer::builtin_vocab();
    let suite = suites::generate(suites::spec("synth-livemath").unwrap(), &v);
    prop::check("engine invariants", 60, |rng| {
        let method = random_method(rng);
        let mut cfg = SsrConfig::default();
        cfg.max_steps = 4 + gen::index(rng, 12);
        cfg.selection = [
            Selection::ModelTopN,
            Selection::ModelSample,
            Selection::Random,
            Selection::Oracle,
        ][gen::index(rng, 4)];
        let problem = &suite.problems[gen::index(rng, suite.problems.len())];
        let seed = rng.next_u64();

        let mut backend = CalibratedBackend::for_suite("synth-livemath", seed)?;
        let mut engine = Engine::new(&mut backend, cfg.clone());
        let r = engine.run(problem, method, seed)?;

        // one vote per opened path
        let expected_paths = match method {
            Method::Baseline | Method::SpecReason { .. } => 1,
            Method::Parallel { n, .. } | Method::Ssr { n, .. } => n,
        };
        ensure!(
            r.votes.len() == expected_paths,
            "votes {} != paths {expected_paths}",
            r.votes.len()
        );

        // token/step accounting sanity
        ensure!(r.target_tokens > 0, "target did no work");
        ensure!(r.rewrites <= r.steps, "rewrites {} > steps {}", r.rewrites, r.steps);
        ensure!(
            r.steps as usize <= expected_paths * cfg.max_steps,
            "steps {} exceed cap", r.steps
        );
        if method.uses_draft() {
            ensure!(r.draft_tokens > 0, "speculative run without draft work");
            ensure!(r.score_tokens > 0, "speculative run without scoring");
        } else {
            ensure!(r.draft_tokens == 0, "non-speculative run used the draft");
            ensure!(r.rewrites == 0, "non-speculative run rewrote");
        }

        // tau = 0 accepts everything
        if let Method::Ssr { tau: 0, .. } | Method::SpecReason { tau: 0 } = method {
            ensure!(r.rewrites == 0, "tau=0 must not rewrite");
        }

        // every per-path score is on the 0..=9 scale, and the decision's
        // answer (if any) is one of the votes
        for v in &r.votes {
            ensure!(v.step_scores.iter().all(|&s| s <= 9));
        }
        if let Some(ans) = r.answer() {
            ensure!(
                r.votes.iter().any(|v| v.answer == Some(ans)),
                "aggregated answer {ans} not among votes"
            );
        }

        // SPM selection: distinct strategies within the pool
        let mut sel = r.selection.clone();
        sel.sort_unstable();
        sel.dedup();
        ensure!(sel.len() == r.selection.len(), "duplicate strategies selected");
        ensure!(sel.iter().all(|&s| s < 12), "strategy outside pool");

        // accounting clock is monotone
        ensure!(r.model_secs >= 0.0 && r.wall_secs >= 0.0);
        Ok(())
    });
}

#[test]
fn fast_modes_never_cost_more_tokens() {
    let v = tokenizer::builtin_vocab();
    let suite = suites::generate(suites::spec("synth-math500").unwrap(), &v);
    prop::check("fast modes cheaper", 25, |rng| {
        let problem = &suite.problems[gen::index(rng, suite.problems.len())];
        let seed = rng.next_u64();
        let mut cost = Vec::new();
        for stop in [StopRule::Fast1, StopRule::Fast2, StopRule::Full] {
            // fresh backend with same seed: identical path dynamics
            let mut b = CalibratedBackend::for_suite("synth-math500", 0xF00D)?;
            let mut engine = Engine::new(&mut b, SsrConfig::default());
            let r = engine.run(problem, Method::Ssr { n: 4, tau: 7, stop }, seed)?;
            cost.push(r.draft_tokens + r.target_tokens + r.score_tokens);
        }
        ensure!(cost[0] <= cost[2], "fast1 {} > full {}", cost[0], cost[2]);
        ensure!(cost[1] <= cost[2], "fast2 {} > full {}", cost[1], cost[2]);
        Ok(())
    });
}

#[test]
fn tau_monotone_in_rewrite_rate() {
    let v = tokenizer::builtin_vocab();
    let suite = suites::generate(suites::spec("synth-aime").unwrap(), &v);
    prop::check("R monotone in tau", 15, |rng| {
        let problem = &suite.problems[gen::index(rng, suite.problems.len())];
        let seed = rng.next_u64();
        let mut rates = Vec::new();
        for tau in [1u8, 5, 9] {
            let mut b = CalibratedBackend::for_suite("synth-aime", 0xAB)?;
            let mut engine = Engine::new(&mut b, SsrConfig::default());
            let r = engine.run(problem, Method::Ssr { n: 3, tau, stop: StopRule::Full }, seed)?;
            rates.push(r.rewrite_rate());
        }
        ensure!(
            rates[0] <= rates[1] + 0.35 && rates[1] <= rates[2] + 0.35,
            "rates not ~monotone: {rates:?}"
        );
        Ok(())
    });
}
