//! Integration tests across runtime + model + coordinator.
//!
//! PJRT tests require the `pjrt` feature AND `make artifacts` to have
//! run; they are compiled out / skip (with a note) otherwise so
//! `cargo test` stays green on a fresh checkout.

use std::path::PathBuf;

use ssr::model::tokenizer;
use ssr::workload::suites;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_vocab_matches_builtin() {
    let dir = require_artifacts!();
    let m = ssr::runtime::Manifest::load(&dir).unwrap();
    let b = tokenizer::builtin_vocab();
    assert_eq!(m.vocab.pad, b.pad);
    assert_eq!(m.vocab.strat0, b.strat0);
    assert_eq!(m.vocab.digit0, b.digit0);
    assert_eq!(m.vocab.eos, b.eos);
    assert_eq!(m.vocab.num_strategies, b.num_strategies);
}

#[test]
fn python_suites_match_rust_generator() {
    // The canonical suites are generated in python (artifact build); the
    // rust generator mirrors the same splitmix64 stream. Equality here
    // proves the two language implementations are bit-compatible.
    let dir = require_artifacts!();
    let m = ssr::runtime::Manifest::load(&dir).unwrap();
    for (name, file) in &m.suites {
        let loaded = suites::load(&dir, file, name).unwrap();
        let spec = suites::spec(name).unwrap();
        let generated = suites::generate(spec, &m.vocab);
        assert_eq!(loaded.problems.len(), generated.problems.len(), "{name}");
        for (a, b) in loaded.problems.iter().zip(&generated.problems) {
            assert_eq!(a.tokens, b.tokens, "{name} tokens diverge");
            assert_eq!(a.answer, b.answer, "{name} answers diverge");
            assert_eq!(a.difficulty, b.difficulty, "{name}");
        }
    }
}


#[cfg(feature = "pjrt")]
mod pjrt_tests {
    use super::{artifacts, tokenizer};

    use ssr::backend::pjrt::PjrtBackend;
    use ssr::backend::Backend;
    use ssr::config::{SsrConfig, StopRule};
    use ssr::coordinator::engine::{Engine, Method};
    use ssr::workload::problems;

    #[test]
    fn pjrt_baseline_generates_valid_trace() {
        let dir = require_artifacts!();
        let mut b = PjrtBackend::load(&dir).unwrap();
        b.temp = 0.0; // greedy: deterministic
        let vocab = b.manifest().vocab.clone();
        let problem = problems::problem_from_text(&vocab, "23+4+9").unwrap();
        let mut engine = Engine::new(&mut b, SsrConfig::default());
        let r = engine.run(&problem, Method::Baseline, 1).unwrap();
        assert_eq!(r.votes.len(), 1);
        assert_eq!(r.draft_tokens, 0);
        assert!(r.target_tokens > 10, "target did no work: {}", r.target_tokens);
        // trained target solves easy add-chains greedily
        assert_eq!(r.answer(), Some(36), "trained target should solve 23+4+9");
    }

    #[test]
    fn pjrt_ssr_full_cycle() {
        let dir = require_artifacts!();
        let mut b = PjrtBackend::load(&dir).unwrap();
        b.temp = 0.6;
        let vocab = b.manifest().vocab.clone();
        let problem = problems::problem_from_text(&vocab, "17+25*3").unwrap();
        let mut engine = Engine::new(&mut b, SsrConfig::default());
        let r = engine
            .run(&problem, Method::Ssr { n: 3, tau: 7, stop: StopRule::Full }, 11)
            .unwrap();
        assert_eq!(r.votes.len(), 3);
        assert_eq!(r.selection.len(), 3);
        assert!(r.draft_tokens > 0, "draft did no work");
        assert!(r.score_tokens > 0, "nothing was scored");
        assert!(r.steps >= 3, "suspiciously few steps: {}", r.steps);
        // every vote that produced an answer must be a parseable number
        for v in &r.votes {
            if let Some(a) = v.answer {
                assert!((0..=10_000).contains(&a), "absurd answer {a}");
            }
        }
    }

    #[test]
    fn pjrt_deterministic_under_greedy() {
        let dir = require_artifacts!();
        let vocab = tokenizer::builtin_vocab();
        let problem = problems::problem_from_text(&vocab, "12+34").unwrap();
        let run = |seed: u64| {
            let mut b = PjrtBackend::load(&dir).unwrap();
            b.temp = 0.0;
            let mut engine = Engine::new(&mut b, SsrConfig::default());
            engine.run(&problem, Method::Baseline, seed).unwrap().answer()
        };
        assert_eq!(run(1), run(2), "greedy baseline must not depend on seed");
    }

    #[test]
    fn pjrt_spec_reason_rewrites_when_tau_high() {
        let dir = require_artifacts!();
        let mut b = PjrtBackend::load(&dir).unwrap();
        b.temp = 0.7;
        let vocab = b.manifest().vocab.clone();
        let problem = problems::problem_from_text(&vocab, "(31+17)*2-5").unwrap();
        let mut engine = Engine::new(&mut b, SsrConfig::default());
        let r = engine.run(&problem, Method::SpecReason { tau: 9 }, 3).unwrap();
        // tau=9 accepts only near-certain steps; the 28%-accuracy draft
        // cannot be near-certain everywhere
        assert!(r.rewrites > 0, "tau=9 should trigger rewrites");
        let r0 = engine.run(&problem, Method::SpecReason { tau: 0 }, 3).unwrap();
        assert_eq!(r0.rewrites, 0, "tau=0 accepts everything");
    }

    #[test]
    fn pjrt_score_histogram_populates() {
        let dir = require_artifacts!();
        let mut b = PjrtBackend::load(&dir).unwrap();
        let vocab = b.manifest().vocab.clone();
        let problem = problems::problem_from_text(&vocab, "8+15+22").unwrap();
        {
            let mut engine = Engine::new(&mut b, SsrConfig::default());
            let _ = engine
                .run(&problem, Method::Ssr { n: 2, tau: 7, stop: StopRule::Full }, 5)
                .unwrap();
        }
        assert!(b.score_histogram().total() > 0);
    }

    #[test]
    fn step_grader_on_real_traces() {
        // The target's greedy traces on easy problems should have mostly
        // arithmetically-correct steps.
        let dir = require_artifacts!();
        let mut b = PjrtBackend::load(&dir).unwrap();
        b.temp = 0.0;
        let vocab = b.manifest().vocab.clone();
        let mut graded = 0;
        let mut total_correctness = 0.0;
        for expr in ["23+4+9", "12+7", "5+6+8"] {
            let problem = problems::problem_from_text(&vocab, expr).unwrap();
            let ids = b.open_paths(&problem, &[None], 1, false).unwrap();
            for _ in 0..10 {
                let o = b.target_step(&ids).unwrap();
                if o[0].terminal {
                    break;
                }
            }
            let trace = b.trace(ids[0]).to_vec();
            b.close_path(ids[0]).unwrap();
            if let Some(c) = tokenizer::step_correctness(&vocab, &trace) {
                graded += 1;
                total_correctness += c;
            }
        }
        assert!(graded >= 2, "traces had no gradable steps");
        assert!(
            total_correctness / graded as f64 > 0.5,
            "trained target's steps mostly wrong: {}",
            total_correctness / graded as f64
        );
    }
}
