//! TCP server protocol round-trip over the calibrated backend (no
//! artifacts needed): solve / stats / error handling / shutdown,
//! plus the fault-tolerance wire surface (DESIGN.md §13): per-request
//! deadlines with degraded replies, and oversized/malformed request
//! lines answered without dropping the connection.
//!
//! Also the overload surface (DESIGN.md §14): hostile `tenant`/`class`/
//! `deadline_ms` field types, structured `overloaded` replies when a
//! burst exceeds `--queue-cap` or a tenant's token bucket runs dry, and
//! the slow-loris idle-timeout guard.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::faulty::FaultInjector;
use ssr::backend::Backend;
use ssr::config::{FaultSpec, SsrConfig};
use ssr::coordinator::server::Server;
use ssr::model::tokenizer;
use ssr::util::json::Value;
use ssr::util::threadpool::ThreadPool;

fn request(stream: &mut TcpStream, line: &str) -> Value {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Value::parse(&reply).unwrap()
}

#[test]
fn solve_stats_shutdown_roundtrip() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();

    let handle = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });

    let mut stream = TcpStream::connect(&addr).unwrap();

    // solve with explicit method
    let r = request(
        &mut stream,
        r#"{"op":"solve","expr":"17+25*3","method":"ssr","paths":3,"seed":5}"#,
    );
    assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 92);
    assert!(r.get_i64("steps").unwrap() > 0);
    assert!(r.get_f64("latency_s").unwrap() >= 0.0);

    // baseline method
    let r = request(&mut stream, r#"{"op":"solve","expr":"5+6","method":"baseline"}"#);
    assert_eq!(r.get_i64("gold").unwrap(), 11);
    assert_eq!(r.get_i64("draft_tokens").unwrap(), 0);

    // malformed expression -> structured error, connection stays up
    let r = request(&mut stream, r#"{"op":"solve","expr":"1+"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().len() > 3);

    // unknown op -> error
    let r = request(&mut stream, r#"{"op":"dance"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());

    // garbage JSON -> error
    let r = request(&mut stream, "not json at all");
    assert!(!r.get("ok").unwrap().bool().unwrap());

    // stats reflect the two successful solves, including the scheduler's
    // occupancy/queue observability fields
    let r = request(&mut stream, r#"{"op":"stats"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap());
    assert_eq!(r.get_i64("requests").unwrap(), 2);
    assert!(r.get_f64("mean_latency_s").unwrap() > 0.0);
    assert!(r.get_i64("backend_calls").unwrap() > 0);
    assert!(r.get_f64("mean_batch_occupancy").unwrap() >= 1.0);
    assert!(r.get_f64("admission_wait_mean_s").unwrap() >= 0.0);
    assert!(r.get_i64("queue_depth_max").unwrap() >= 0);
    assert!(r.get_f64("model_secs").unwrap() > 0.0);
    // migration / autoscaler gauges are present (zero on a quiet
    // single-shard pool with the policy off)
    assert_eq!(r.get_i64("migrations").unwrap(), 0);
    assert_eq!(r.get_i64("migration_bytes").unwrap(), 0);
    assert_eq!(r.get_i64("scale_ups").unwrap(), 0);
    assert_eq!(r.get_i64("scale_downs").unwrap(), 0);

    // shutdown
    let r = request(&mut stream, r#"{"op":"shutdown"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap());
    handle.join().unwrap();
}

#[test]
fn deadline_expiry_returns_a_degraded_reply() {
    // Every step stalls 30ms (seeded injector, unlimited budget), the
    // wire deadline is 5ms: expiry is guaranteed by construction — the
    // deadline scan at the first post-stall step boundary force-stops
    // the run and finalizes from the votes so far. No timing race: the
    // test never assumes a sleep finishes "fast enough", only that a
    // 30ms stall cannot beat a 5ms deadline.
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let spec =
        FaultSpec { seed: 11, stall_rate: 1.0, stall_ms: 30, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, move |shard| {
        let inner = Box::new(CalibratedBackend::for_suite("synth-math500", 7)?);
        Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
            as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    let r = request(
        &mut s,
        r#"{"op":"solve","expr":"17+25*3","method":"baseline","seed":5,"deadline_ms":5}"#,
    );
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert!(
        r.get("degraded").unwrap().bool().unwrap(),
        "a 5ms deadline against 30ms step stalls must degrade: {r:?}"
    );

    // no deadline: the same request runs to completion, undegraded
    let r = request(&mut s, r#"{"op":"solve","expr":"17+25*3","method":"baseline","seed":5}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert!(!r.get("degraded").unwrap().bool().unwrap());

    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert!(r.get_i64("deadline_expirations").unwrap() >= 1);
    assert!(r.get_i64("degraded_replies").unwrap() >= 1);
    assert_eq!(r.get_i64("errors").unwrap(), 0, "degradation is not an error");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn oversized_lines_get_an_error_without_dropping_the_connection() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // a 2 MiB line: bounded read caps the buffer at 1 MiB, drains the
    // remainder, and answers with a structured error
    let big = vec![b'x'; 2 << 20];
    s.write_all(&big).unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let r = Value::parse(&reply).unwrap();
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().contains("exceeds"), "{r:?}");

    // the same connection still serves
    let r = request(&mut s, r#"{"op":"solve","expr":"3+4","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    // non-UTF-8 bytes: error reply, connection survives
    s.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
    s.flush().unwrap();
    let mut reply = String::new();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    reader.read_line(&mut reply).unwrap();
    let r = Value::parse(&reply).unwrap();
    assert!(!r.get("ok").unwrap().bool().unwrap());
    let r = request(&mut s, r#"{"op":"solve","expr":"2+2","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn concurrent_clients_interleave_through_the_scheduler() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 9)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(8);
        server.serve(listener, &pool).unwrap();
    });

    // 4 baseline clients + 4 multi-path ssr clients, all in flight at
    // once: every solve must come back correct and consistent
    let mut clients: Vec<_> = (0..8)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                let method = if i % 2 == 0 { "baseline" } else { "ssr" };
                let r = request(
                    &mut s,
                    &format!(
                        r#"{{"op":"solve","expr":"{}+{}","method":"{}","paths":3,"seed":{}}}"#,
                        i + 1,
                        i + 2,
                        method,
                        i
                    ),
                );
                assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
                assert_eq!(r.get_i64("gold").unwrap(), (2 * i + 3) as i64);
                assert!(r.get_f64("queue_wait_s").unwrap() >= 0.0);
            })
        })
        .collect();
    for c in clients.drain(..) {
        c.join().unwrap();
    }
    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("requests").unwrap(), 8);
    assert_eq!(r.get_i64("errors").unwrap(), 0);
    assert!(r.get_f64("mean_batch_occupancy").unwrap() >= 1.0);
    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn elastic_shard_ops_over_the_wire() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 13)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(4);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // hot-add a shard at runtime
    let r = request(&mut s, r#"{"op":"add_shard"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("shard").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 2);

    // the grown pool still solves
    let r = request(&mut s, r#"{"op":"solve","expr":"3+4","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    // drain the added shard while the listener stays up
    let r = request(&mut s, r#"{"op":"remove_shard","shard":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("drained").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 1);
    assert!(r.get_f64("drain_s").unwrap() >= 0.0);

    // min_shards floor -> structured error, connection stays up
    let r = request(&mut s, r#"{"op":"remove_shard","shard":0}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().contains("min_shards"));

    // lifecycle gauges surface in stats
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("shards_added").unwrap(), 1);
    assert_eq!(r.get_i64("shards_removed").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 1);
    assert_eq!(r.get_i64("requests").unwrap(), 1);
    assert!(r.get_f64("drain_max_s").unwrap() >= 0.0);

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn sharded_server_round_trip_and_shard_stats() {
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 11)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(8);
        server.serve(listener, &pool).unwrap();
    });

    let mut clients: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                let r = request(
                    &mut s,
                    &format!(
                        r#"{{"op":"solve","expr":"{}+{}*2","method":"ssr","paths":3,"seed":{}}}"#,
                        i + 1,
                        i + 2,
                        i
                    ),
                );
                assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
                assert_eq!(r.get_i64("gold").unwrap(), (i + 1 + (i + 2) * 2) as i64);
            })
        })
        .collect();
    for c in clients.drain(..) {
        c.join().unwrap();
    }
    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("requests").unwrap(), 6);
    assert_eq!(r.get_i64("errors").unwrap(), 0);
    assert_eq!(r.get_i64("shards").unwrap(), 2);
    let per_shard = r.get("shard_requests").unwrap().arr().unwrap();
    assert_eq!(per_shard.len(), 2);
    let total: i64 = per_shard.iter().map(|v| v.i64().unwrap()).sum();
    assert_eq!(total, 6, "shard request counts don't add up");
    assert!(r.get_f64("model_secs_makespan").unwrap() > 0.0);
    assert!(
        r.get_f64("model_secs").unwrap() >= r.get_f64("model_secs_makespan").unwrap() - 1e-9
    );
    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn hostile_field_types_get_errors_without_dropping_the_connection() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // wrong JSON types for the QoS fields are plain `error` replies
    // (malformed request, not excess load) and never drop the line
    for bad in [
        r#"{"op":"solve","expr":"1+2","deadline_ms":1.5}"#,
        r#"{"op":"solve","expr":"1+2","deadline_ms":{"ms":5}}"#,
        r#"{"op":"solve","expr":"1+2","tenant":7}"#,
        r#"{"op":"solve","expr":"1+2","tenant":{"id":1}}"#,
        r#"{"op":"solve","expr":"1+2","class":3}"#,
        r#"{"op":"solve","expr":"1+2","class":["interactive"]}"#,
    ] {
        let r = request(&mut s, bad);
        assert!(!r.get("ok").unwrap().bool().unwrap(), "{bad} -> {r:?}");
        assert!(r.get_str("error").unwrap().len() > 3, "{bad} -> {r:?}");
        assert!(r.get("err").is_err(), "type errors must not claim overload: {r:?}");
    }

    // unknown class value names the offender
    let r = request(&mut s, r#"{"op":"solve","expr":"1+2","class":"platinum"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().contains("unknown class"), "{r:?}");

    // a negative deadline is clamped to "no deadline", not an error
    let r = request(&mut s, r#"{"op":"solve","expr":"3+4","deadline_ms":-5}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    // well-formed tenant/class still solve on the same connection and
    // show up in the per-tenant / per-class stats gauges
    let r = request(
        &mut s,
        r#"{"op":"solve","expr":"2+3","tenant":"acme","class":"batch"}"#,
    );
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 5);

    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap());
    assert_eq!(r.get_i64("rejected").unwrap(), 0);
    assert_eq!(r.get_i64("shed").unwrap(), 0);
    let classes = r.get("class_requests").unwrap().arr().unwrap();
    assert_eq!(classes.len(), 3);
    assert_eq!(classes[0].i64().unwrap(), 1, "interactive (default class)");
    assert_eq!(classes[1].i64().unwrap(), 1, "batch");
    assert_eq!(r.get("tenant_requests").unwrap().get_i64("acme").unwrap(), 1);

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn tenant_token_bucket_replies_overloaded_with_retry_hint() {
    // burst 2, refill 0.5/s: on one connection the third request in a
    // row from the same tenant is deterministically out of tokens
    // (fast solves cannot refill 1.0 tokens), while another tenant's
    // fresh bucket still admits
    let mut cfg = SsrConfig::default();
    cfg.qos.tenant_rate = 0.5;
    cfg.qos.tenant_burst = 2.0;
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    for _ in 0..2 {
        let r = request(
            &mut s,
            r#"{"op":"solve","expr":"1+2","method":"baseline","tenant":"acme"}"#,
        );
        assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    }
    let r = request(
        &mut s,
        r#"{"op":"solve","expr":"1+2","method":"baseline","tenant":"acme"}"#,
    );
    assert!(!r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_str("err").unwrap(), "overloaded");
    assert_eq!(r.get_str("reason").unwrap(), "rate_limited");
    let hint = r.get_i64("retry_after_ms").unwrap();
    // one token at 0.5/s is at most 2s away
    assert!((10..=2000).contains(&hint), "retry_after_ms={hint}");

    // a different tenant has its own bucket and is still admitted
    let r = request(
        &mut s,
        r#"{"op":"solve","expr":"4+4","method":"baseline","tenant":"other"}"#,
    );
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");

    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("rejected").unwrap(), 1);
    assert_eq!(r.get_i64("retry_after_hints").unwrap(), 1);
    assert!(r.get_f64("retry_after_hint_mean_ms").unwrap() >= 10.0);
    assert_eq!(r.get("tenant_requests").unwrap().get_i64("acme").unwrap(), 2);
    assert_eq!(r.get("tenant_rejected").unwrap().get_i64("acme").unwrap(), 1);

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn queue_cap_burst_gets_structured_overloaded_replies() {
    // queue_cap 2 per class; every backend step stalls 500ms so the two
    // admitted batch solves are pinned in the system (their permits
    // held) while the rest of the burst arrives. Deterministic counts:
    // nothing can complete before the whole burst has been gated, so
    // exactly 2 of 5 admit and 3 reject with `queue_full`.
    let mut cfg = SsrConfig::default();
    cfg.qos.queue_cap = 2;
    let vocab = tokenizer::builtin_vocab();
    let spec =
        FaultSpec { seed: 3, stall_rate: 1.0, stall_ms: 500, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, move |shard| {
        let inner = Box::new(CalibratedBackend::for_suite("synth-math500", 7)?);
        Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
            as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(8);
        server.serve(listener, &pool).unwrap();
    });

    let barrier = std::sync::Arc::new(std::sync::Barrier::new(5));
    let clients: Vec<_> = (0..5)
        .map(|i| {
            let addr = addr.clone();
            let barrier = std::sync::Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                barrier.wait();
                // a 50ms deadline degrades the admitted runs at the
                // first post-stall step, keeping the test fast
                let line = format!(
                    r#"{{"op":"solve","expr":"1+{i}","method":"baseline",{}}}"#,
                    r#""class":"batch","deadline_ms":50"#,
                );
                let r = request(&mut s, &line);
                if r.get("ok").unwrap().bool().unwrap() {
                    return ("ok", 0);
                }
                assert_eq!(r.get_str("err").unwrap(), "overloaded", "{r:?}");
                assert_eq!(r.get_str("reason").unwrap(), "queue_full", "{r:?}");
                let hint = r.get_i64("retry_after_ms").unwrap();
                assert!((10..=30_000).contains(&hint), "retry_after_ms={hint}");
                // the connection survives the rejection: the same
                // stream still answers (a stats probe — a solve probe
                // would race the other rejected clients for the cap)
                let probe = request(&mut s, r#"{"op":"stats"}"#);
                assert!(probe.get("ok").unwrap().bool().unwrap(), "{probe:?}");
                ("overloaded", hint)
            })
        })
        .collect();
    let outcomes: Vec<(&str, i64)> =
        clients.into_iter().map(|c| c.join().unwrap()).collect();
    let admitted = outcomes.iter().filter(|(o, _)| *o == "ok").count();
    let rejected = outcomes.iter().filter(|(o, _)| *o == "overloaded").count();
    assert_eq!((admitted, rejected), (2, 3), "{outcomes:?}");

    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("rejected").unwrap(), 3);
    assert_eq!(r.get_i64("shed").unwrap(), 0);
    assert_eq!(r.get_i64("retry_after_hints").unwrap(), 3);
    // in-flight work is never dropped: both admitted runs replied
    let classes = r.get("class_requests").unwrap().arr().unwrap();
    assert_eq!(classes[1].i64().unwrap(), 2, "batch replies: {r:?}");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn slow_loris_connection_is_timed_out_with_a_structured_reply() {
    let mut cfg = SsrConfig::default();
    cfg.conn_idle_timeout_ms = 150;
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });

    // drip half a request and stop: the 150ms idle timeout must answer
    // with a structured error and close, not hold the handler forever
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"{\"op\":\"sol").unwrap();
    s.flush().unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let r = Value::parse(&reply).unwrap();
    assert!(!r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert!(r.get_str("error").unwrap().contains("idle timeout"), "{r:?}");
    // ...and then EOF: the server hung up on the loris
    let mut rest = Vec::new();
    assert_eq!(reader.read_to_end(&mut rest).unwrap(), 0);

    // the listener itself is unharmed
    let mut s2 = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s2, r#"{"op":"solve","expr":"9+1","method":"baseline"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    let _ = request(&mut s2, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

// ---------------------------------------------------------------------
// PROTOCOL.md surface: hello/versioning, error codes, request_id echo,
// multiplexing, the framed transport, streaming, and stats-doc drift.
// ---------------------------------------------------------------------

use ssr::config::Transport;
use ssr::coordinator::protocol;
use ssr::util::json;

fn start_default_server(
    cfg: SsrConfig,
    pool_threads: usize,
) -> (String, std::thread::JoinHandle<()>) {
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(pool_threads);
        server.serve(listener, &pool).unwrap();
    });
    (addr, srv)
}

/// Zero the wall-clock-only reply fields so deterministic replies can
/// be compared byte-for-byte.
fn normalize_clock_fields(v: &mut Value) {
    if let Value::Obj(map) = v {
        for key in ["latency_s", "queue_wait_s"] {
            if map.contains_key(key) {
                map.insert(key.to_string(), json::n(0.0));
            }
        }
    }
}

#[test]
fn hello_reports_version_and_unknown_ops_get_a_machine_code() {
    let (addr, srv) = start_default_server(SsrConfig::default(), 2);
    let mut s = TcpStream::connect(&addr).unwrap();

    let r = request(&mut s, r#"{"op":"hello"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("proto").unwrap(), 1);
    let features: Vec<&str> =
        r.get("features").unwrap().arr().unwrap().iter().map(|f| f.str().unwrap()).collect();
    assert!(features.contains(&"streaming") && features.contains(&"framed"), "{features:?}");

    // stats reports the protocol version too
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("proto").unwrap(), 1);

    // unknown op: legacy `error` string plus the machine-readable code,
    // with the client's request_id echoed
    let r = request(&mut s, r#"{"op":"dance","request_id":"rq-7"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert_eq!(r.get_str("code").unwrap(), "unsupported_op", "{r:?}");
    assert!(r.get_str("error").unwrap().contains("unknown op"), "{r:?}");
    assert_eq!(r.get_str("request_id").unwrap(), "rq-7");

    // the other structured codes on the jsonl compat shapes
    let r = request(&mut s, "not json at all");
    assert_eq!(r.get_str("code").unwrap(), "malformed", "{r:?}");
    let r = request(&mut s, r#"{"op":"solve","expr":"1+2","tenant":7}"#);
    assert_eq!(r.get_str("code").unwrap(), "malformed", "{r:?}");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn framed_transport_round_trip_with_envelope_errors() {
    let mut cfg = SsrConfig::default();
    cfg.transport = Transport::Framed;
    let (addr, srv) = start_default_server(cfg, 2);
    let mut s = TcpStream::connect(&addr).unwrap();

    let frame_request = |s: &mut TcpStream, payload: &str| -> Value {
        protocol::write_frame(s, payload).unwrap();
        Value::parse(&protocol::read_frame(s).unwrap()).unwrap()
    };

    let r = frame_request(&mut s, r#"{"op":"hello"}"#);
    assert_eq!(r.get_i64("proto").unwrap(), 1, "{r:?}");

    let r = frame_request(&mut s, r#"{"op":"solve","expr":"17+25*3","seed":5,"request_id":9}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 92);
    assert_eq!(r.get_i64("request_id").unwrap(), 9, "request_id echo");

    // malformed payload: the framed error envelope, not the legacy keys
    let r = frame_request(&mut s, "not json at all");
    assert!(!r.get("ok").unwrap().bool().unwrap());
    let err = r.get("error").unwrap();
    assert_eq!(err.get_str("code").unwrap(), "malformed", "{r:?}");
    assert!(err.get_str("message").unwrap().contains("parsing request"), "{r:?}");
    assert!(r.get("err").is_err(), "no legacy keys in framed mode: {r:?}");

    // a frame declaring a >1MiB payload: `oversized` envelope, the
    // declared bytes are skipped, and the connection keeps serving
    let declared = (1usize << 20) + 5;
    s.write_all(&(declared as u32).to_be_bytes()).unwrap();
    s.write_all(&vec![b'x'; declared]).unwrap();
    s.flush().unwrap();
    let r = Value::parse(&protocol::read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(r.get("error").unwrap().get_str("code").unwrap(), "oversized", "{r:?}");
    let r = frame_request(&mut s, r#"{"op":"solve","expr":"3+4","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    let r = frame_request(&mut s, r#"{"op":"shutdown"}"#);
    assert!(r.get("bye").unwrap().bool().unwrap());
    srv.join().unwrap();
}

#[test]
fn multiplexed_replies_return_out_of_order_with_request_id_echo() {
    // Every backend step stalls 30ms, so a solve pipelined ahead of a
    // stats on the SAME connection cannot reply first: the stats is
    // served inline by the event loop while the solve is still pending.
    // Deterministic by construction — the solve needs at least one
    // 30ms step, the stats needs none.
    let mut cfg = SsrConfig::default();
    cfg.transport = Transport::Framed;
    let vocab = tokenizer::builtin_vocab();
    let spec = FaultSpec { seed: 5, stall_rate: 1.0, stall_ms: 30, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, move |shard| {
        let inner = Box::new(CalibratedBackend::for_suite("synth-math500", 7)?);
        Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone())) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // pipeline both requests before reading anything; a 50ms deadline
    // keeps the stalled solve short (degraded replies are still replies)
    protocol::write_frame(
        &mut s,
        r#"{"op":"solve","expr":"17+25*3","method":"baseline","deadline_ms":50,"request_id":"slow"}"#,
    )
    .unwrap();
    protocol::write_frame(&mut s, r#"{"op":"stats","request_id":"fast"}"#).unwrap();
    s.flush().unwrap();

    let first = Value::parse(&protocol::read_frame(&mut s).unwrap()).unwrap();
    let second = Value::parse(&protocol::read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(first.get_str("request_id").unwrap(), "fast", "stats must overtake: {first:?}");
    assert!(first.get("requests").is_ok());
    assert_eq!(second.get_str("request_id").unwrap(), "slow");
    assert!(second.get("ok").unwrap().bool().unwrap(), "{second:?}");

    let _ = protocol::write_frame(&mut s, r#"{"op":"shutdown"}"#);
    let _ = protocol::read_frame(&mut s);
    srv.join().unwrap();
}

#[test]
fn streamed_terminal_reply_is_byte_identical_to_the_blocking_reply() {
    let (addr, srv) = start_default_server(SsrConfig::default(), 2);
    let mut s = TcpStream::connect(&addr).unwrap();

    // blocking reference reply
    let line = r#"{"op":"solve","expr":"17+25*3","method":"ssr","paths":3,"seed":5,"request_id":"rA"}"#;
    let mut blocking = request(&mut s, line);
    assert!(blocking.get("ok").unwrap().bool().unwrap(), "{blocking:?}");

    // the same request streamed: interim events, then the terminal
    let streamed_line = line.replace(r#""request_id":"rA""#, r#""request_id":"rA","stream":true"#);
    s.write_all(streamed_line.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut progress_events = 0usize;
    let mut first_votes = 0usize;
    let mut delta_sum = 0i64;
    let mut last_total = 0i64;
    let mut terminal = loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let v = Value::parse(&l).unwrap();
        match v.get("event") {
            Ok(ev) => {
                assert_eq!(v.get_str("request_id").unwrap(), "rA", "events carry the id");
                match ev.str().unwrap() {
                    "progress" => {
                        progress_events += 1;
                        assert!(v.get_i64("steps").unwrap() >= 0);
                        assert!(v.get_i64("lanes").unwrap() >= 1);
                        assert!(v.get_i64("spec_depth").unwrap() >= 0);
                    }
                    "token_delta" => {
                        // PROTOCOL.md golden properties: deltas are
                        // never 0, totals are strictly monotone, and a
                        // frame's total moves by at least its delta
                        // (exactly, when nothing was dropped between)
                        let delta = v.get_i64("tokens").unwrap();
                        let total = v.get_i64("total_tokens").unwrap();
                        assert!(delta >= 1, "zero-token delta frame: {v:?}");
                        assert!(total > last_total, "total_tokens not monotone: {v:?}");
                        assert!(total - last_total >= delta, "{v:?}");
                        delta_sum += delta;
                        last_total = total;
                    }
                    "first_vote" => {
                        first_votes += 1;
                        assert!(v.get_f64("elapsed_s").unwrap() >= 0.0);
                        assert!(v.get_i64("votes").unwrap() >= 1);
                    }
                    other => panic!("unknown event `{other}`"),
                }
            }
            Err(_) => break v,
        }
    };
    assert!(progress_events >= 1, "no progress events streamed");
    assert!(last_total >= 1, "no token_delta events streamed");
    assert_eq!(first_votes, 1, "first_vote fires exactly once per run");

    // byte-for-byte equality after zeroing the wall-clock-only fields
    normalize_clock_fields(&mut blocking);
    normalize_clock_fields(&mut terminal);
    assert_eq!(
        blocking.print(),
        terminal.print(),
        "the streamed terminal frame must equal the blocking reply"
    );

    // gauges: the stream retired, its events were counted, and the
    // first vote landed before the end-to-end reply
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("streams_active").unwrap(), 0);
    assert!(r.get_i64("stream_events").unwrap() >= 2, "{r:?}");
    assert_eq!(r.get_i64("first_votes").unwrap(), 1, "{r:?}");
    assert!(r.get_f64("time_to_first_vote_mean_s").unwrap() >= 0.0);
    // absent drops the received deltas sum exactly to the final total
    // (this is the only stream on the server, so the global drop gauge
    // is this stream's)
    if r.get_i64("stream_drops").unwrap() == 0 {
        assert_eq!(delta_sum, last_total, "token deltas must sum to the final total");
    } else {
        assert!(delta_sum <= last_total, "deltas overshot the total despite drops");
    }

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn slow_consumer_stream_buffer_drops_oldest_events() {
    // --stream-buffer 1: the step boundary that finishes the first path
    // pushes [progress, first_vote] as ONE batch into a capacity-1
    // ring, so at least one drop is guaranteed no matter how fast the
    // consumer drains — the accounting is deterministic, not a race.
    let mut cfg = SsrConfig::default();
    cfg.stream_buffer = 1;
    let (addr, srv) = start_default_server(cfg, 2);
    let mut s = TcpStream::connect(&addr).unwrap();

    s.write_all(
        br#"{"op":"solve","expr":"17+25*3","method":"ssr","paths":3,"seed":5,"stream":true}"#,
    )
    .unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let terminal = loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let v = Value::parse(&l).unwrap();
        if v.get("event").is_err() {
            break v;
        }
    };
    assert!(terminal.get("ok").unwrap().bool().unwrap(), "{terminal:?}");

    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert!(r.get_i64("stream_drops").unwrap() >= 1, "{r:?}");
    assert!(
        r.get_i64("stream_events").unwrap() > r.get_i64("stream_drops").unwrap(),
        "some events must still be delivered: {r:?}"
    );

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn stats_fields_match_the_protocol_doc() {
    // PROTOCOL.md's <!-- stats-fields --> block is the contract; this
    // test diffs it against a live `stats` reply in both directions so
    // neither the doc nor `Metrics::summary_json` can drift alone.
    let doc = include_str!("../../PROTOCOL.md");
    let begin = doc.find("<!-- stats-fields:begin -->").expect("begin marker");
    let end = doc.find("<!-- stats-fields:end -->").expect("end marker");
    let documented: Vec<String> = doc[begin..end]
        .lines()
        .filter_map(|l| l.trim().strip_prefix("- `"))
        .filter_map(|l| l.strip_suffix('`'))
        .map(|l| l.to_string())
        .collect();
    assert!(!documented.is_empty(), "no fields parsed from PROTOCOL.md");

    let (addr, srv) = start_default_server(SsrConfig::default(), 2);
    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    let Value::Obj(map) = &r else { panic!("stats is not an object: {r:?}") };
    let live: Vec<String> = map.keys().cloned().collect();

    let undocumented: Vec<&String> = live.iter().filter(|k| !documented.contains(k)).collect();
    let stale: Vec<&String> = documented.iter().filter(|k| !live.contains(k)).collect();
    assert!(
        undocumented.is_empty() && stale.is_empty(),
        "stats/doc drift — missing from PROTOCOL.md: {undocumented:?}; \
         documented but not served: {stale:?}"
    );
    // the doc list is sorted, like the wire object's keys
    let mut sorted = documented.clone();
    sorted.sort();
    assert_eq!(documented, sorted, "PROTOCOL.md stats fields must stay sorted");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}
