//! TCP server protocol round-trip over the calibrated backend (no
//! artifacts needed): solve / stats / error handling / shutdown,
//! plus the fault-tolerance wire surface (DESIGN.md §13): per-request
//! deadlines with degraded replies, and oversized/malformed request
//! lines answered without dropping the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::faulty::FaultInjector;
use ssr::backend::Backend;
use ssr::config::{FaultSpec, SsrConfig};
use ssr::coordinator::server::Server;
use ssr::model::tokenizer;
use ssr::util::json::Value;
use ssr::util::threadpool::ThreadPool;

fn request(stream: &mut TcpStream, line: &str) -> Value {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Value::parse(&reply).unwrap()
}

#[test]
fn solve_stats_shutdown_roundtrip() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();

    let handle = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });

    let mut stream = TcpStream::connect(&addr).unwrap();

    // solve with explicit method
    let r = request(
        &mut stream,
        r#"{"op":"solve","expr":"17+25*3","method":"ssr","paths":3,"seed":5}"#,
    );
    assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 92);
    assert!(r.get_i64("steps").unwrap() > 0);
    assert!(r.get_f64("latency_s").unwrap() >= 0.0);

    // baseline method
    let r = request(&mut stream, r#"{"op":"solve","expr":"5+6","method":"baseline"}"#);
    assert_eq!(r.get_i64("gold").unwrap(), 11);
    assert_eq!(r.get_i64("draft_tokens").unwrap(), 0);

    // malformed expression -> structured error, connection stays up
    let r = request(&mut stream, r#"{"op":"solve","expr":"1+"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().len() > 3);

    // unknown op -> error
    let r = request(&mut stream, r#"{"op":"dance"}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());

    // garbage JSON -> error
    let r = request(&mut stream, "not json at all");
    assert!(!r.get("ok").unwrap().bool().unwrap());

    // stats reflect the two successful solves, including the scheduler's
    // occupancy/queue observability fields
    let r = request(&mut stream, r#"{"op":"stats"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap());
    assert_eq!(r.get_i64("requests").unwrap(), 2);
    assert!(r.get_f64("mean_latency_s").unwrap() > 0.0);
    assert!(r.get_i64("backend_calls").unwrap() > 0);
    assert!(r.get_f64("mean_batch_occupancy").unwrap() >= 1.0);
    assert!(r.get_f64("admission_wait_mean_s").unwrap() >= 0.0);
    assert!(r.get_i64("queue_depth_max").unwrap() >= 0);
    assert!(r.get_f64("model_secs").unwrap() > 0.0);
    // migration / autoscaler gauges are present (zero on a quiet
    // single-shard pool with the policy off)
    assert_eq!(r.get_i64("migrations").unwrap(), 0);
    assert_eq!(r.get_i64("migration_bytes").unwrap(), 0);
    assert_eq!(r.get_i64("scale_ups").unwrap(), 0);
    assert_eq!(r.get_i64("scale_downs").unwrap(), 0);

    // shutdown
    let r = request(&mut stream, r#"{"op":"shutdown"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap());
    handle.join().unwrap();
}

#[test]
fn deadline_expiry_returns_a_degraded_reply() {
    // Every step stalls 30ms (seeded injector, unlimited budget), the
    // wire deadline is 5ms: expiry is guaranteed by construction — the
    // deadline scan at the first post-stall step boundary force-stops
    // the run and finalizes from the votes so far. No timing race: the
    // test never assumes a sleep finishes "fast enough", only that a
    // 30ms stall cannot beat a 5ms deadline.
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let spec =
        FaultSpec { seed: 11, stall_rate: 1.0, stall_ms: 30, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, move |shard| {
        let inner = Box::new(CalibratedBackend::for_suite("synth-math500", 7)?);
        Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
            as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    let r = request(
        &mut s,
        r#"{"op":"solve","expr":"17+25*3","method":"baseline","seed":5,"deadline_ms":5}"#,
    );
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert!(
        r.get("degraded").unwrap().bool().unwrap(),
        "a 5ms deadline against 30ms step stalls must degrade: {r:?}"
    );

    // no deadline: the same request runs to completion, undegraded
    let r = request(&mut s, r#"{"op":"solve","expr":"17+25*3","method":"baseline","seed":5}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert!(!r.get("degraded").unwrap().bool().unwrap());

    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert!(r.get_i64("deadline_expirations").unwrap() >= 1);
    assert!(r.get_i64("degraded_replies").unwrap() >= 1);
    assert_eq!(r.get_i64("errors").unwrap(), 0, "degradation is not an error");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn oversized_lines_get_an_error_without_dropping_the_connection() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // a 2 MiB line: bounded read caps the buffer at 1 MiB, drains the
    // remainder, and answers with a structured error
    let big = vec![b'x'; 2 << 20];
    s.write_all(&big).unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let r = Value::parse(&reply).unwrap();
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().contains("exceeds"), "{r:?}");

    // the same connection still serves
    let r = request(&mut s, r#"{"op":"solve","expr":"3+4","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    // non-UTF-8 bytes: error reply, connection survives
    s.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
    s.flush().unwrap();
    let mut reply = String::new();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    reader.read_line(&mut reply).unwrap();
    let r = Value::parse(&reply).unwrap();
    assert!(!r.get("ok").unwrap().bool().unwrap());
    let r = request(&mut s, r#"{"op":"solve","expr":"2+2","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn concurrent_clients_interleave_through_the_scheduler() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 9)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(8);
        server.serve(listener, &pool).unwrap();
    });

    // 4 baseline clients + 4 multi-path ssr clients, all in flight at
    // once: every solve must come back correct and consistent
    let mut clients: Vec<_> = (0..8)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                let method = if i % 2 == 0 { "baseline" } else { "ssr" };
                let r = request(
                    &mut s,
                    &format!(
                        r#"{{"op":"solve","expr":"{}+{}","method":"{}","paths":3,"seed":{}}}"#,
                        i + 1,
                        i + 2,
                        method,
                        i
                    ),
                );
                assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
                assert_eq!(r.get_i64("gold").unwrap(), (2 * i + 3) as i64);
                assert!(r.get_f64("queue_wait_s").unwrap() >= 0.0);
            })
        })
        .collect();
    for c in clients.drain(..) {
        c.join().unwrap();
    }
    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("requests").unwrap(), 8);
    assert_eq!(r.get_i64("errors").unwrap(), 0);
    assert!(r.get_f64("mean_batch_occupancy").unwrap() >= 1.0);
    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn elastic_shard_ops_over_the_wire() {
    let cfg = SsrConfig::default();
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 13)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(4);
        server.serve(listener, &pool).unwrap();
    });
    let mut s = TcpStream::connect(&addr).unwrap();

    // hot-add a shard at runtime
    let r = request(&mut s, r#"{"op":"add_shard"}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("shard").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 2);

    // the grown pool still solves
    let r = request(&mut s, r#"{"op":"solve","expr":"3+4","seed":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("gold").unwrap(), 7);

    // drain the added shard while the listener stays up
    let r = request(&mut s, r#"{"op":"remove_shard","shard":1}"#);
    assert!(r.get("ok").unwrap().bool().unwrap(), "{r:?}");
    assert_eq!(r.get_i64("drained").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 1);
    assert!(r.get_f64("drain_s").unwrap() >= 0.0);

    // min_shards floor -> structured error, connection stays up
    let r = request(&mut s, r#"{"op":"remove_shard","shard":0}"#);
    assert!(!r.get("ok").unwrap().bool().unwrap());
    assert!(r.get_str("error").unwrap().contains("min_shards"));

    // lifecycle gauges surface in stats
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("shards_added").unwrap(), 1);
    assert_eq!(r.get_i64("shards_removed").unwrap(), 1);
    assert_eq!(r.get_i64("shards_live").unwrap(), 1);
    assert_eq!(r.get_i64("requests").unwrap(), 1);
    assert!(r.get_f64("drain_max_s").unwrap() >= 0.0);

    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}

#[test]
fn sharded_server_round_trip_and_shard_stats() {
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    let vocab = tokenizer::builtin_vocab();
    let (server, listener) = Server::start("127.0.0.1", 0, cfg, vocab, |_shard| {
        Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 11)?) as Box<dyn Backend>)
    })
    .unwrap();
    let addr = server.addr.clone();
    let srv = std::thread::spawn(move || {
        let pool = ThreadPool::new(8);
        server.serve(listener, &pool).unwrap();
    });

    let mut clients: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                let r = request(
                    &mut s,
                    &format!(
                        r#"{{"op":"solve","expr":"{}+{}*2","method":"ssr","paths":3,"seed":{}}}"#,
                        i + 1,
                        i + 2,
                        i
                    ),
                );
                assert_eq!(r.get("ok").unwrap().bool().unwrap(), true, "{r:?}");
                assert_eq!(r.get_i64("gold").unwrap(), (i + 1 + (i + 2) * 2) as i64);
            })
        })
        .collect();
    for c in clients.drain(..) {
        c.join().unwrap();
    }
    let mut s = TcpStream::connect(&addr).unwrap();
    let r = request(&mut s, r#"{"op":"stats"}"#);
    assert_eq!(r.get_i64("requests").unwrap(), 6);
    assert_eq!(r.get_i64("errors").unwrap(), 0);
    assert_eq!(r.get_i64("shards").unwrap(), 2);
    let per_shard = r.get("shard_requests").unwrap().arr().unwrap();
    assert_eq!(per_shard.len(), 2);
    let total: i64 = per_shard.iter().map(|v| v.i64().unwrap()).sum();
    assert_eq!(total, 6, "shard request counts don't add up");
    assert!(r.get_f64("model_secs_makespan").unwrap() > 0.0);
    assert!(
        r.get_f64("model_secs").unwrap() >= r.get_f64("model_secs_makespan").unwrap() - 1e-9
    );
    let _ = request(&mut s, r#"{"op":"shutdown"}"#);
    srv.join().unwrap();
}
