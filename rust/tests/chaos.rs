//! Chaos suite: seeded fault schedules against the sharded pool
//! (DESIGN.md §13). Covers transient step errors absorbed by in-place
//! retries, a forced shard panic mid-solve with crash recovery via run
//! re-admission, a panic during migration recovered from the
//! step-boundary checkpoint, and poison-run quarantine after the
//! crash-retry budget.
//!
//! Determinism: every schedule is seeded (`FaultSpec.seed`) or forced
//! by an explicit shared counter/gate — correctness never depends on a
//! wall-clock-timing sleep. The only waits are event waits (channel
//! recv, condvar) and state polls with a liveness timeout.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::faulty::FaultInjector;
use ssr::backend::{
    Backend, BackendMeta, LaneSnapshot, PathId, PathStats, PrefillStats, PrefixHandle,
    StepOutcome,
};
use ssr::config::{FaultSpec, PlacePolicy, SpecDepth, SsrConfig};
use ssr::coordinator::admission::QosClass;
use ssr::coordinator::engine::Method;
use ssr::coordinator::metrics::Metrics;
use ssr::coordinator::pool::{BackendPool, PoolHandle};
use ssr::coordinator::scheduler::SolveRequest;
use ssr::model::tokenizer;
use ssr::util::json::Value;

const SUITE: &str = "synth-math500";

/// Delegating wrapper that runs a test-controlled hook before every
/// generation step (draft/target span). The hook may block (gate) or
/// panic (forced crash); decisions are untouched — the inner backend
/// drives them.
struct Hooked {
    inner: Box<dyn Backend>,
    on_step: Box<dyn FnMut() + Send>,
}

impl Backend for Hooked {
    fn meta(&self) -> BackendMeta {
        self.inner.meta()
    }

    fn select_scores(&mut self, problem: &ssr::workload::Problem) -> Result<Vec<f32>> {
        self.inner.select_scores(problem)
    }

    fn open_paths(
        &mut self,
        problem: &ssr::workload::Problem,
        strategies: &[Option<usize>],
        seed: u64,
        use_draft: bool,
    ) -> Result<Vec<PathId>> {
        self.inner.open_paths(problem, strategies, seed, use_draft)
    }

    fn prefill_prefix(
        &mut self,
        problem: &ssr::workload::Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<PrefixHandle> {
        self.inner.prefill_prefix(problem, use_draft, want_scores)
    }

    fn prefix_scores(&mut self, handle: PrefixHandle) -> Result<Vec<f32>> {
        self.inner.prefix_scores(handle)
    }

    fn fork_paths(
        &mut self,
        handle: PrefixHandle,
        strategies: &[Option<usize>],
        seed: u64,
    ) -> Result<Vec<PathId>> {
        self.inner.fork_paths(handle, strategies, seed)
    }

    fn release_prefix(&mut self, handle: PrefixHandle) -> Result<()> {
        self.inner.release_prefix(handle)
    }

    fn prefix_bytes(&self, handle: PrefixHandle) -> u64 {
        self.inner.prefix_bytes(handle)
    }

    fn prefill_stats(&self) -> PrefillStats {
        self.inner.prefill_stats()
    }

    fn draft_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        (self.on_step)();
        self.inner.draft_step(paths)
    }

    fn score_step(&mut self, paths: &[PathId]) -> Result<Vec<u8>> {
        self.inner.score_step(paths)
    }

    fn rewrite_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        self.inner.rewrite_step(paths)
    }

    fn accept_step(&mut self, paths: &[PathId]) -> Result<()> {
        self.inner.accept_step(paths)
    }

    fn target_step(&mut self, paths: &[PathId]) -> Result<Vec<StepOutcome>> {
        (self.on_step)();
        self.inner.target_step(paths)
    }

    fn export_lane_state(&mut self, path: PathId) -> Result<LaneSnapshot> {
        self.inner.export_lane_state(path)
    }

    fn import_lane_state(&mut self, snapshot: LaneSnapshot) -> Result<PathId> {
        self.inner.import_lane_state(snapshot)
    }

    fn trace(&self, path: PathId) -> &[i32] {
        self.inner.trace(path)
    }

    fn close_path(&mut self, path: PathId) -> Result<PathStats> {
        self.inner.close_path(path)
    }

    fn parse_answer(&self, trace: &[i32]) -> Option<i64> {
        self.inner.parse_answer(trace)
    }

    fn clock_secs(&self) -> f64 {
        self.inner.clock_secs()
    }

    fn score_histogram(&self) -> ssr::util::stats::Histogram {
        self.inner.score_histogram()
    }
}

fn submit(
    handle: &PoolHandle,
    expr: &str,
    method: Method,
    seed: u64,
) -> mpsc::Receiver<Result<Value>> {
    let (rtx, rrx) = mpsc::channel();
    handle
        .submit(SolveRequest {
            expr: expr.to_string(),
            method,
            seed,
            deadline_ms: 0,
            class: QosClass::default(),
            reply: rtx.into(),
        })
        .unwrap();
    rrx
}

fn answer_of(v: &Value) -> Option<i64> {
    v.get_i64("answer").ok()
}

/// Reference answers: the same jobs on one untouched fault-free shard.
fn fault_free_answers(jobs: &[(String, Method, u64)], backend_seed: u64) -> Vec<Option<i64>> {
    let cfg = SsrConfig::default();
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) =
        BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), move |_s| {
            Ok(Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?) as Box<dyn Backend>)
        })
        .unwrap();
    let mut out = Vec::new();
    for (expr, m, seed) in jobs {
        let v = submit(&handle, expr, *m, *seed).recv().unwrap().unwrap();
        out.push(answer_of(&v));
    }
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    out
}

fn mixed_jobs(n: usize) -> Vec<(String, Method, u64)> {
    (0..n)
        .map(|i| {
            let method = if i % 2 == 0 {
                Method::Baseline
            } else {
                Method::Ssr { n: 3, tau: 7, stop: ssr::config::StopRule::Full }
            };
            (format!("{}+{}*3", i % 7 + 2, i % 5 + 4), method, i as u64)
        })
        .collect()
}

#[test]
fn transient_faults_are_retried_without_changing_answers() {
    // Seeded 5% per-step transient errors, unlimited budget: every
    // injection is raised BEFORE the real step executes, so the
    // in-place retry replays the exact same decision sequence.
    let backend_seed = 0xFA01;
    let spec = FaultSpec { seed: 0xC4A0, transient_rate: 0.05, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
            Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
                as Box<dyn Backend>)
        },
    )
    .unwrap();

    let jobs = mixed_jobs(8);
    let replies: Vec<_> = jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
    let answers: Vec<Option<i64>> =
        replies.iter().map(|r| answer_of(&r.recv().unwrap().unwrap())).collect();
    assert_eq!(handle.shards(), 2);
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }

    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0, "a transient fault leaked to a client");
    assert_eq!(m.requests, 8);
    assert!(m.retries > 0, "the 5% schedule never injected a transient");
    assert_eq!(m.shard_crashes, 0);
    drop(m);
    assert_eq!(
        answers,
        fault_free_answers(&jobs, backend_seed),
        "transient retries changed decisions"
    );
}

#[test]
fn forced_shard_panic_recovers_in_flight_runs() {
    // ISSUE acceptance: a seeded 1% step-fault schedule PLUS one forced
    // shard panic mid-solve. Every request must still get a reply, the
    // answers must match a fault-free run (replay is seeded by the
    // placement-invariant run seed), and the pool must end with its
    // full healthy shard count and nonzero crash/recovery counters.
    let backend_seed = 0xFA02;
    let spec = FaultSpec { seed: 0xC4A2, transient_rate: 0.01, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    // pool-wide step-call counter: call #5 panics, exactly once
    let calls = Arc::new(AtomicU64::new(0));
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
            let faulty =
                Box::new(FaultInjector::new(inner, spec, shard, budget.clone()));
            let calls = Arc::clone(&calls);
            Ok(Box::new(Hooked {
                inner: faulty,
                on_step: Box::new(move || {
                    if calls.fetch_add(1, Ordering::SeqCst) + 1 == 5 {
                        panic!("chaos: forced shard panic on step call #5");
                    }
                }),
            }) as Box<dyn Backend>)
        },
    )
    .unwrap();

    let jobs = mixed_jobs(8);
    let replies: Vec<_> = jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
    let answers: Vec<Option<i64>> =
        replies.iter().map(|r| answer_of(&r.recv().unwrap().unwrap())).collect();
    // asserted BEFORE dropping the handle: the respawned shard's thread
    // is detached, so post-drop gauges race its teardown flush
    assert_eq!(handle.shards(), 2, "pool did not end at its healthy shard count");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }

    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0, "a crash leaked an error to a client");
    assert_eq!(m.requests, 8);
    assert_eq!(m.shard_crashes, 1, "the forced panic must crash exactly one shard");
    assert!(m.runs_recovered >= 1, "the dead shard's in-flight runs were not re-admitted");
    drop(m);
    assert_eq!(
        answers,
        fault_free_answers(&jobs, backend_seed),
        "recovered runs diverge from the fault-free reference"
    );
}

#[test]
fn fixed_depth_runs_recover_to_the_depth_one_reference() {
    // Spec-depth satellite: a forced shard panic with `--spec-depth
    // fixed:4` runs in flight. Crash recovery (checkpoint resume or
    // seeded replay) must land on the same answers as the fault-free
    // DEPTH-1 reference — depth is clock-only, and the recovery path
    // replays deterministically at any depth.
    let backend_seed = 0xFA08;
    let spec = FaultSpec { seed: 0xC4A8, transient_rate: 0.01, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let calls = Arc::new(AtomicU64::new(0));
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    cfg.spec_depth = SpecDepth::Fixed(4);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
            let faulty =
                Box::new(FaultInjector::new(inner, spec, shard, budget.clone()));
            let calls = Arc::clone(&calls);
            Ok(Box::new(Hooked {
                inner: faulty,
                on_step: Box::new(move || {
                    if calls.fetch_add(1, Ordering::SeqCst) + 1 == 7 {
                        panic!("chaos: forced shard panic on step call #7");
                    }
                }),
            }) as Box<dyn Backend>)
        },
    )
    .unwrap();

    let jobs = mixed_jobs(8);
    let replies: Vec<_> = jobs.iter().map(|(e, m, s)| submit(&handle, e, *m, *s)).collect();
    let answers: Vec<Option<i64>> =
        replies.iter().map(|r| answer_of(&r.recv().unwrap().unwrap())).collect();
    assert_eq!(handle.shards(), 2, "pool did not end at its healthy shard count");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }

    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0, "a crash leaked an error to a client");
    assert_eq!(m.requests, 8);
    assert_eq!(m.shard_crashes, 1, "the forced panic must crash exactly one shard");
    assert!(m.runs_recovered >= 1, "the dead shard's in-flight runs were not re-admitted");
    drop(m);
    // reference runs at the DEFAULT depth (fixed:1) and fault-free
    assert_eq!(
        answers,
        fault_free_answers(&jobs, backend_seed),
        "fixed:4 recovered runs diverge from the depth-1 fault-free reference"
    );
}

#[test]
fn panic_during_migration_recovers_from_checkpoint() {
    // Crash in the crash-recovery window: a drain migrates an in-flight
    // run to the survivor; the survivor's injector panics on the first
    // step after `import_lane_state` (resume_panic, budget 1). The
    // supervisor must re-admit the run from its step-boundary
    // checkpoint, bit-identically.
    let backend_seed = 0xFA03;
    let spec = FaultSpec { seed: 1, resume_panic: true, max_faults: 1, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    // gate: the first generation step parks until the drain is staged
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let (started_tx, started_rx) = mpsc::channel::<()>();
    // Sender is !Sync; the factory closure must be Sync
    let started_tx = Arc::new(Mutex::new(started_tx));
    let mut cfg = SsrConfig::default();
    cfg.shards = 2;
    cfg.placement = PlacePolicy::RoundRobin;
    cfg.migration = true;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
            let faulty =
                Box::new(FaultInjector::new(inner, spec, shard, budget.clone()));
            let gate = Arc::clone(&gate);
            let tx = started_tx.lock().unwrap().clone();
            Ok(Box::new(Hooked {
                inner: faulty,
                on_step: Box::new(move || {
                    let (lock, cv) = &*gate;
                    let mut open = lock.lock().unwrap();
                    if !*open {
                        let _ = tx.send(());
                        while !*open {
                            open = cv.wait(open).unwrap();
                        }
                    }
                }),
            }) as Box<dyn Backend>)
        },
    )
    .unwrap();

    // round-robin: the job lands on shard 0 and parks in its first step
    let job = ("17+25*3".to_string(), Method::Baseline, 3u64);
    let reply = submit(&handle, &job.0, job.1, job.2);
    started_rx.recv().unwrap();

    // drain shard 0 from another thread; it unpublishes the slot
    // immediately, then blocks until the shard migrates its run
    let h2 = handle.clone();
    let drainer = std::thread::spawn(move || h2.remove_shard(0).unwrap());
    let t0 = Instant::now();
    while handle.shards() > 1 {
        assert!(t0.elapsed() < Duration::from_secs(20), "drain never unpublished shard 0");
        std::thread::yield_now();
    }
    // open the gate: shard 0 finishes the step, observes the drain, and
    // migrates the run to shard 1 — whose injector then panics on the
    // first post-import step
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    drainer.join().unwrap();
    let v = reply.recv().unwrap().unwrap();
    assert!(v.get("ok").unwrap().bool().unwrap(), "{v:?}");
    let answer = answer_of(&v);
    assert_eq!(handle.shards(), 1, "crashed survivor was not respawned");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }

    let m = metrics.lock().unwrap();
    assert_eq!(m.errors, 0);
    assert!(m.migrations >= 1, "the drain never migrated the in-flight run");
    assert_eq!(m.shard_crashes, 1, "resume_panic must crash the importing shard once");
    assert!(m.runs_recovered >= 1, "the checkpointed run was not re-admitted");
    drop(m);
    assert_eq!(
        vec![answer],
        fault_free_answers(std::slice::from_ref(&job), backend_seed),
        "checkpoint recovery changed the decision sequence"
    );
}

#[test]
fn poison_run_is_quarantined_after_its_retry_budget() {
    // A run whose every step panics keeps killing shards; after
    // `recover_retries` re-admissions its placement-invariant seed
    // joins the quarantine list and the client gets a structured
    // error — and a resubmit is refused at admission, crash-free.
    let backend_seed = 0xFA04;
    let spec = FaultSpec { seed: 0xC4A4, panic_rate: 1.0, ..FaultSpec::default() };
    let budget = FaultInjector::shared_budget(&spec);
    let mut cfg = SsrConfig::default();
    cfg.shards = 1;
    cfg.recover_retries = 1;
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (handle, joins) = BackendPool::spawn(
        cfg,
        tokenizer::builtin_vocab(),
        Arc::clone(&metrics),
        move |shard| {
            let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
            Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
                as Box<dyn Backend>)
        },
    )
    .unwrap();

    let err = submit(&handle, "17+25*3", Method::Baseline, 3)
        .recv()
        .unwrap()
        .expect_err("a poison run must fail, not hang");
    assert!(
        format!("{err:#}").contains("quarantin"),
        "poison reply should say quarantined: {err:#}"
    );
    {
        let m = metrics.lock().unwrap();
        assert_eq!(m.shard_crashes, 2, "crash once, retry once, then quarantine");
        assert_eq!(m.quarantined, 1);
        assert_eq!(m.runs_recovered, 1);
        assert_eq!(m.runs_replayed, 1);
        assert_eq!(m.errors, 1);
    }

    // resubmit of the identical (expr, seed): refused at admission,
    // without costing another shard
    let err = submit(&handle, "17+25*3", Method::Baseline, 3)
        .recv()
        .unwrap()
        .expect_err("quarantined run must be refused at admission");
    assert!(format!("{err:#}").contains("quarantin"), "{err:#}");
    assert_eq!(handle.shards(), 1, "pool must stay serving on its respawned shard");
    drop(handle);
    for j in joins {
        j.join().unwrap();
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.shard_crashes, 2, "the quarantine check must fire before the backend");
    assert_eq!(m.errors, 2);
}

#[test]
fn streamed_runs_match_blocking_replies_under_faults() {
    // Streaming observes runs, it never steers them: with seeded
    // transient faults AND shard panics in play, a tapped run's
    // terminal reply must stay byte-identical to the untapped run's
    // (only the wall-clock fields differ), and the tap must still see
    // progress. Extends the chaos suite to the §16 streaming surface.
    use ssr::coordinator::{EventTap, ReplySink};

    let backend_seed = 0xFA05;
    let spec = FaultSpec {
        seed: 0xC4A5,
        transient_rate: 0.05,
        panic_rate: 0.002,
        ..FaultSpec::default()
    };
    let jobs = mixed_jobs(6);

    let run = |tapped: bool| -> (Vec<Value>, u64) {
        let budget = FaultInjector::shared_budget(&spec);
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::RoundRobin;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |shard| {
                let inner = Box::new(CalibratedBackend::for_suite(SUITE, backend_seed)?);
                Ok(Box::new(FaultInjector::new(inner, spec, shard, budget.clone()))
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        let mut taps = Vec::new();
        let replies: Vec<_> = jobs
            .iter()
            .map(|(expr, method, seed)| {
                let (rtx, rrx) = mpsc::channel();
                let tap = tapped.then(|| EventTap::new(64, None));
                taps.extend(tap.clone());
                handle
                    .submit(SolveRequest {
                        expr: expr.clone(),
                        method: *method,
                        seed: *seed,
                        deadline_ms: 0,
                        class: QosClass::default(),
                        reply: ReplySink::with_events(rtx, tap),
                    })
                    .unwrap();
                rrx
            })
            .collect();
        let mut terminals: Vec<Value> = replies
            .iter()
            .map(|r| r.recv().unwrap().expect("every run must reply under faults"))
            .collect();
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        // events were observed for every tapped run
        let mut events = 0u64;
        for tap in &taps {
            let drained = tap.drain();
            assert!(!drained.is_empty(), "a tapped run streamed no events");
            events += drained.len() as u64 + tap.dropped();
        }
        for t in &mut terminals {
            if let Value::Obj(map) = t {
                map.insert("latency_s".into(), ssr::util::json::n(0.0));
                map.insert("queue_wait_s".into(), ssr::util::json::n(0.0));
            }
        }
        (terminals, events)
    };

    let (blocking, _) = run(false);
    let (streamed, events) = run(true);
    assert!(events > 0);
    let blocking: Vec<String> = blocking.iter().map(|v| v.print()).collect();
    let streamed: Vec<String> = streamed.iter().map(|v| v.print()).collect();
    assert_eq!(blocking, streamed, "streaming taps changed a terminal reply under faults");
}
