//! SSR — Speculative Parallel Scaling Reasoning (test-time), a full-stack
//! reproduction of the paper's serving system.
//!
//! Three layers (see DESIGN.md):
//!   * L1/L2 live in `python/compile/` (Pallas kernels + JAX models),
//!     AOT-lowered to HLO text consumed here;
//!   * L3 — this crate — is the serving coordinator: the Selective
//!     Parallel Module ([`coordinator::spm`]), Step-level Speculative
//!     Decoding (the [`coordinator::engine`] step machine), answer
//!     aggregation, fast modes, baselines, cross-request continuous
//!     batching ([`coordinator::scheduler`] — serving & scheduling
//!     design notes live there), a TCP server, and the
//!     normalized-FLOPs accounting from the paper's Appendix B.
//!
//! The [`backend`] module is the seam between coordinator logic and model
//! substrate: the PJRT backend runs the real draft/target transformers
//! from `artifacts/`; the calibrated backend reproduces the paper's
//! published operating points through the *same* engine code.

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod model;
pub mod runtime;
pub mod util;
pub mod workload;
