//! Typed execution wrappers over the AOT entry points of one model
//! (draft or target): prefill / span / ingest, plus the KV-cache state
//! they thread through.
//!
//! Cache contract (mirrors `python/compile/model.py`):
//!   * `pos[b]` = number of valid cache entries for lane b;
//!   * `span` caches `cur` + all sampled tokens EXCEPT the last one —
//!     the caller must feed that token back (as the next span's `cur` or
//!     the next ingest's first token);
//!   * `ingest` caches every token in `toks[:len]`; lanes with `len = 0`
//!     are frozen (no cache/pos/score mutation).
//!
//! Methods accept up to `batch` logical lanes and pad internally to the
//! compiled batch variant; the coordinator's batcher chooses variants.

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::runtime::literals::{lit_i32, scalar_f32, scalar_i32, to_vec_f32, to_vec_i32};
use crate::runtime::{EntryKind, Manifest, ModelSpec, Runtime, Weights};

/// Device-shaped KV cache for a lane group (batch = compiled variant).
pub struct KvCache {
    pub k: Literal,
    pub v: Literal,
    pub batch: usize,
}

pub struct PrefillOut {
    /// per-lane logits at the last prompt position (next-token dist)
    pub next_logits: Vec<Vec<f32>>,
    pub cache: KvCache,
    /// per-lane valid cache length (= prompt length)
    pub pos: Vec<i32>,
}

pub struct SpanOut {
    /// per-lane sampled tokens, trimmed to `ntake` (delimiter included)
    pub toks: Vec<Vec<i32>>,
    /// lane hit a step delimiter within T_SPAN
    pub done: Vec<bool>,
    pub pos: Vec<i32>,
}

pub struct IngestOut {
    /// per-lane mean next-token log-prob over the ingested span
    pub mean_lp: Vec<f32>,
    /// per-lane count of scored predictions
    pub cnt: Vec<i32>,
    /// per-lane logits after the final ingested token
    pub last_logits: Vec<Vec<f32>>,
    pub pos: Vec<i32>,
}

pub struct ModelHandle {
    pub spec: ModelSpec,
    weights: Weights,
    t_span: usize,
    prefill_batches: Vec<usize>,
    step_batches: Vec<usize>,
}

impl ModelHandle {
    pub fn load(manifest: &Manifest, name: &str) -> Result<Self> {
        let spec = manifest.model(name)?.clone();
        let weights = Weights::load(&manifest.dir, &spec)?;
        Ok(ModelHandle {
            spec,
            weights,
            t_span: manifest.t_span,
            prefill_batches: manifest.prefill_batches.clone(),
            step_batches: manifest.step_batches.clone(),
        })
    }

    pub fn t_span(&self) -> usize {
        self.t_span
    }

    fn pick_batch(&self, kind: EntryKind, n: usize) -> Result<usize> {
        let list = match kind {
            EntryKind::Prefill => &self.prefill_batches,
            _ => &self.step_batches,
        };
        list.iter().copied().filter(|&b| b >= n).min().with_context(|| {
            format!("{n} lanes exceed every compiled {kind:?} batch variant {list:?}")
        })
    }

    fn entry_name(&self, kind: EntryKind, batch: usize) -> String {
        let k = match kind {
            EntryKind::Prefill => "prefill",
            EntryKind::Span => "span",
            EntryKind::Ingest => "ingest",
        };
        format!("{k}_{}_b{batch}", self.spec.name)
    }

    /// Weight literals followed by per-call args, as the HLO expects.
    fn args<'a>(&'a self, rest: &'a [&'a Literal]) -> Vec<&'a Literal> {
        let mut v: Vec<&Literal> = self.weights.literals.iter().collect();
        v.extend_from_slice(rest);
        v
    }

    /// Run prefill over `prompts` (<= largest compiled batch). Prompts are
    /// right-padded to S_MAX with PAD(0); per-lane `pos` = prompt length.
    pub fn prefill(&self, rt: &Runtime, prompts: &[Vec<i32>]) -> Result<PrefillOut> {
        let n = prompts.len();
        let b = self.pick_batch(EntryKind::Prefill, n)?;
        let s = self.spec.s_max;
        let vsz = self.spec.vocab;

        let mut tokens = vec![0i32; b * s];
        let mut lens = vec![1i32; b]; // padded lanes: length 1 (BOS-ish)
        for (i, p) in prompts.iter().enumerate() {
            if p.len() > s {
                bail!("prompt of {} tokens exceeds S_MAX={s}", p.len());
            }
            tokens[i * s..i * s + p.len()].copy_from_slice(p);
            lens[i] = p.len() as i32;
        }
        let tokens_l = lit_i32(&tokens, &[b, s])?;
        let lens_l = lit_i32(&lens, &[b])?;

        let name = self.entry_name(EntryKind::Prefill, b);
        let outs = rt.execute(&name, &self.args(&[&tokens_l, &lens_l]))?;
        let [logits, k, v] = take3(outs)?;

        let logits_v = to_vec_f32(&logits)?;
        let mut next_logits = Vec::with_capacity(n);
        for (i, p) in prompts.iter().enumerate() {
            let at = (i * s + p.len() - 1) * vsz;
            next_logits.push(logits_v[at..at + vsz].to_vec());
        }
        Ok(PrefillOut {
            next_logits,
            cache: KvCache { k, v, batch: b },
            pos: lens[..n].to_vec(),
        })
    }

    /// Speculatively draft one reasoning step per active lane.
    pub fn span(
        &self,
        rt: &Runtime,
        cache: &mut KvCache,
        pos: &[i32],
        cur: &[i32],
        temp: f32,
        seed: i32,
    ) -> Result<SpanOut> {
        let n = pos.len();
        let b = cache.batch;
        if n > b || cur.len() != n {
            bail!("span: {n} lanes vs cache batch {b} / cur {}", cur.len());
        }
        let pos_l = lit_i32(&pad_to(pos, b, 0), &[b])?;
        let cur_l = lit_i32(&pad_to(cur, b, 0), &[b])?;
        let temp_l = scalar_f32(temp);
        let seed_l = scalar_i32(seed);

        let name = self.entry_name(EntryKind::Span, b);
        let outs = rt.execute(
            &name,
            &self.args(&[&cache.k, &cache.v, &pos_l, &cur_l, &temp_l, &seed_l]),
        )?;
        let [toks, ntake, done, pos_out, k, v] = take6(outs)?;
        cache.k = k;
        cache.v = v;

        let toks_v = to_vec_i32(&toks)?;
        let ntake_v = to_vec_i32(&ntake)?;
        let done_v = to_vec_i32(&done)?;
        let pos_v = to_vec_i32(&pos_out)?;
        let t = self.t_span;
        let out_toks = (0..n)
            .map(|i| toks_v[i * t..i * t + ntake_v[i] as usize].to_vec())
            .collect();
        Ok(SpanOut {
            toks: out_toks,
            done: done_v[..n].iter().map(|&d| d != 0).collect(),
            pos: pos_v[..n].to_vec(),
        })
    }

    /// Teacher-force tokens into the cache; returns span scores.
    /// `toks[i].len()` may be 0 to freeze a lane. Rows longer than T_SPAN
    /// are processed in T_SPAN-sized chunks (multiple HLO calls); scores
    /// accumulate across chunks. (The log-prob of each chunk's first
    /// token given the previous chunk's last is skipped by the ingest
    /// kernel's skip-first semantics — a <1-token approximation per
    /// chunk, documented in DESIGN.md §9.)
    pub fn ingest(
        &self,
        rt: &Runtime,
        cache: &mut KvCache,
        pos: &[i32],
        toks: &[Vec<i32>],
    ) -> Result<IngestOut> {
        let n = pos.len();
        let b = cache.batch;
        if n > b || toks.len() != n {
            bail!("ingest: {n} lanes vs cache batch {b} / toks {}", toks.len());
        }
        let t = self.t_span;
        let vsz = self.spec.vocab;

        let mut offset = vec![0usize; n];
        let mut cur_pos: Vec<i32> = pad_to(pos, b, 0);
        let mut sum_acc = vec![0.0f32; n];
        let mut cnt_acc = vec![0i32; n];
        let mut last_logits: Vec<Vec<f32>> = vec![vec![0.0; vsz]; n];

        loop {
            let mut flat = vec![0i32; b * t];
            let mut lens = vec![0i32; b];
            let mut any = false;
            for (i, row) in toks.iter().enumerate() {
                let take = (row.len() - offset[i]).min(t);
                if take > 0 {
                    flat[i * t..i * t + take]
                        .copy_from_slice(&row[offset[i]..offset[i] + take]);
                    lens[i] = take as i32;
                    any = true;
                }
            }
            if !any {
                break;
            }
            let toks_l = lit_i32(&flat, &[b, t])?;
            let lens_l = lit_i32(&lens, &[b])?;
            let pos_l = lit_i32(&cur_pos, &[b])?;

            let name = self.entry_name(EntryKind::Ingest, b);
            let outs =
                rt.execute(&name, &self.args(&[&cache.k, &cache.v, &pos_l, &toks_l, &lens_l]))?;
            let [sum_lp, cnt, ll, pos_out, k, v] = take6(outs)?;
            cache.k = k;
            cache.v = v;

            let sum_v = to_vec_f32(&sum_lp)?;
            let cnt_v = to_vec_i32(&cnt)?;
            let ll_v = to_vec_f32(&ll)?;
            let pos_v = to_vec_i32(&pos_out)?;
            for i in 0..n {
                if lens[i] > 0 {
                    sum_acc[i] += sum_v[i];
                    cnt_acc[i] += cnt_v[i];
                    last_logits[i].copy_from_slice(&ll_v[i * vsz..(i + 1) * vsz]);
                    offset[i] += lens[i] as usize;
                }
            }
            cur_pos[..n].copy_from_slice(&pos_v[..n]);
        }

        Ok(IngestOut {
            mean_lp: (0..n).map(|i| sum_acc[i] / (cnt_acc[i].max(1) as f32)).collect(),
            cnt: cnt_acc,
            last_logits,
            pos: cur_pos[..n].to_vec(),
        })
    }

    /// FLOPs of one forward token (the paper's F_d / F_t).
    pub fn flops_per_token(&self) -> u64 {
        self.spec.flops_per_token
    }

    /// Slice a prefilled cache down to one lane and the first `s_len`
    /// positions: `[L, B, H, S_MAX, D] -> [L, 1, H, s_len, D]`. This is
    /// what a cached prompt prefix actually needs to retain — the
    /// prompt's own K/V rows — instead of the full padded prefill
    /// literal (which dominates host memory on long prompts; ROADMAP
    /// item, DESIGN.md §10). `fork_cache` re-pads to the compiled
    /// S_MAX on the way back out.
    pub fn slice_prefix(&self, src: &KvCache, lane: usize, s_len: usize) -> Result<KvCache> {
        let k = slice_lane_literal(&src.k, lane, s_len)?;
        let v = slice_lane_literal(&src.v, lane, s_len)?;
        Ok(KvCache { k, v, batch: 1 })
    }

    /// Fork a prefilled prompt prefix into a fresh lane-group cache:
    /// gather lane `src_lane`'s K/V rows and broadcast them across a
    /// `[L, B', H, S_MAX, D]` cache whose batch B' is the compiled
    /// prefill variant fitting `n` lanes — the device-layout op behind
    /// `PjrtBackend::fork_paths` (DESIGN.md §2). The source may be a
    /// sliced prefix (S < S_MAX): positions past the source length are
    /// zero-filled, which is exactly the garbage-past-the-frontier
    /// state the attention length mask already ignores. Host-side
    /// relayout: one gather + one upload per model, amortized over the
    /// whole lane group and every subsequent fork of the same prefix.
    pub fn fork_cache(&self, src: &KvCache, src_lane: usize, n: usize) -> Result<KvCache> {
        let b_new = self.pick_batch(EntryKind::Prefill, n)?;
        let k = broadcast_lane_literal(&src.k, src_lane, b_new, self.spec.s_max)?;
        let v = broadcast_lane_literal(&src.v, src_lane, b_new, self.spec.s_max)?;
        Ok(KvCache { k, v, batch: b_new })
    }
}

/// Slice one lane's first `s_len` positions out of a `[L, B, H, S, D]`
/// cache literal into a fresh `[L, 1, H, s_len, D]` literal.
fn slice_lane_literal(lit: &Literal, lane: usize, s_len: usize) -> Result<Literal> {
    let d = crate::runtime::literals::dims(lit)?;
    if d.len() != 5 {
        bail!("cache literal must be [L, B, H, S, D], got {d:?}");
    }
    let (l, b, h, s, dd) = (d[0], d[1], d[2], d[3], d[4]);
    if lane >= b {
        bail!("slice source lane {lane} out of batch {b}");
    }
    if s_len > s {
        bail!("slice length {s_len} exceeds cache S {s}");
    }
    let src = crate::runtime::literals::to_vec_f32(lit)?;
    let out = slice_lane(&src, l, b, h, s, dd, lane, s_len);
    crate::runtime::literals::lit_f32(&out, &[l, 1, h, s_len, dd])
}

/// Pure relayout behind [`slice_lane_literal`].
#[allow(clippy::too_many_arguments)]
fn slice_lane(
    src: &[f32],
    l: usize,
    b: usize,
    h: usize,
    s: usize,
    d: usize,
    lane: usize,
    s_len: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; l * h * s_len * d];
    for li in 0..l {
        for hi in 0..h {
            let src_off = (((li * b + lane) * h + hi) * s) * d;
            let dst_off = ((li * h + hi) * s_len) * d;
            out[dst_off..dst_off + s_len * d]
                .copy_from_slice(&src[src_off..src_off + s_len * d]);
        }
    }
    out
}

/// Broadcast one lane of a `[L, B, H, S, D]` cache literal into a fresh
/// `[L, B', H, s_out, D]` literal with every lane a copy of `lane`,
/// zero-padding positions S..s_out (sliced-prefix sources).
fn broadcast_lane_literal(
    lit: &Literal,
    lane: usize,
    b_new: usize,
    s_out: usize,
) -> Result<Literal> {
    let d = crate::runtime::literals::dims(lit)?;
    if d.len() != 5 {
        bail!("cache literal must be [L, B, H, S, D], got {d:?}");
    }
    let (l, b, h, s, dd) = (d[0], d[1], d[2], d[3], d[4]);
    if lane >= b {
        bail!("fork source lane {lane} out of batch {b}");
    }
    if s > s_out {
        bail!("source S {s} exceeds target S {s_out}");
    }
    let src = crate::runtime::literals::to_vec_f32(lit)?;
    let out = broadcast_lane(&src, l, b, h, s, dd, lane, b_new, s_out);
    crate::runtime::literals::lit_f32(&out, &[l, b_new, h, s_out, dd])
}

/// Pure relayout behind [`broadcast_lane_literal`].
#[allow(clippy::too_many_arguments)]
fn broadcast_lane(
    src: &[f32],
    l: usize,
    b: usize,
    h: usize,
    s: usize,
    d: usize,
    lane: usize,
    b_new: usize,
    s_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; l * b_new * h * s_out * d];
    for li in 0..l {
        for hi in 0..h {
            let src_off = (((li * b + lane) * h + hi) * s) * d;
            let row = &src[src_off..src_off + s * d];
            for bi in 0..b_new {
                let dst_off = (((li * b_new + bi) * h + hi) * s_out) * d;
                out[dst_off..dst_off + s * d].copy_from_slice(row);
            }
        }
    }
    out
}

fn pad_to(xs: &[i32], b: usize, fill: i32) -> Vec<i32> {
    let mut v = xs.to_vec();
    v.resize(b, fill);
    v
}

fn take3(mut outs: Vec<Literal>) -> Result<[Literal; 3]> {
    if outs.len() != 3 {
        bail!("expected 3 outputs, got {}", outs.len());
    }
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    Ok([a, b, c])
}

fn take6(mut outs: Vec<Literal>) -> Result<[Literal; 6]> {
    if outs.len() != 6 {
        bail!("expected 6 outputs, got {}", outs.len());
    }
    let f = outs.pop().unwrap();
    let e = outs.pop().unwrap();
    let d = outs.pop().unwrap();
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    Ok([a, b, c, d, e, f])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_to_extends_and_preserves() {
        assert_eq!(pad_to(&[1, 2], 4, 0), vec![1, 2, 0, 0]);
        assert_eq!(pad_to(&[1, 2, 3], 3, 9), vec![1, 2, 3]);
    }

    #[test]
    fn broadcast_lane_copies_source_row_everywhere() {
        // L=2, B=2, H=1, S=3, D=1; broadcast lane 1 into B'=3 at s_out=3
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let out = broadcast_lane(&src, 2, 2, 1, 3, 1, 1, 3, 3);
        assert_eq!(out.len(), 2 * 3 * 3);
        // layer 0: lane 1 of src is elements 3..6
        for bi in 0..3 {
            assert_eq!(&out[bi * 3..bi * 3 + 3], &src[3..6], "layer 0 lane {bi}");
        }
        // layer 1: lane 1 of src is elements 9..12
        for bi in 0..3 {
            assert_eq!(
                &out[(3 + bi) * 3..(3 + bi) * 3 + 3],
                &src[9..12],
                "layer 1 lane {bi}"
            );
        }
    }

    #[test]
    fn broadcast_lane_shrinks_too() {
        let src: Vec<f32> = (0..8).map(|x| x as f32).collect(); // L=1,B=4,H=1,S=2,D=1
        let out = broadcast_lane(&src, 1, 4, 1, 2, 1, 0, 1, 2);
        assert_eq!(out, vec![0.0, 1.0]);
    }

    #[test]
    fn broadcast_pads_sliced_source_with_zeros() {
        // a sliced prefix (S=2) forked into a compiled cache (s_out=4):
        // positions past the prompt are zero (masked garbage territory)
        let src: Vec<f32> = vec![1.0, 2.0]; // L=1,B=1,H=1,S=2,D=1
        let out = broadcast_lane(&src, 1, 1, 1, 2, 1, 0, 2, 4);
        assert_eq!(out, vec![1.0, 2.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn slice_then_broadcast_roundtrips_prompt_rows() {
        // L=1, B=2, H=2, S=3, D=1: slice lane 1 to s_len=2, broadcast
        // back to B'=1, s_out=3 — prompt rows identical, tail zeroed
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let sliced = slice_lane(&src, 1, 2, 2, 3, 1, 1, 2);
        // lane 1, head 0 holds positions [6,7,(8)]; head 1 holds [9,10,(11)]
        assert_eq!(sliced, vec![6.0, 7.0, 9.0, 10.0]);
        let back = broadcast_lane(&sliced, 1, 1, 2, 2, 1, 0, 1, 3);
        assert_eq!(back, vec![6.0, 7.0, 0.0, 9.0, 10.0, 0.0]);
    }
}
