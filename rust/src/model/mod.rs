//! Model layer: typed access to the AOT-compiled draft/target
//! transformers (handles + KV caches), the shared tokenizer/grammar, and
//! host-side sampling.

pub mod handle;
pub mod sampler;
pub mod tokenizer;

pub use handle::{IngestOut, KvCache, ModelHandle, PrefillOut, SpanOut};
