//! Model layer: typed access to the AOT-compiled draft/target
//! transformers (handles + KV caches), the shared tokenizer/grammar, and
//! host-side sampling.

#[cfg(feature = "pjrt")]
pub mod handle;
pub mod sampler;
pub mod tokenizer;

#[cfg(feature = "pjrt")]
pub use handle::{IngestOut, KvCache, ModelHandle, PrefillOut, SpanOut};
