//! Token-stream utilities over the manifest vocabulary: rendering,
//! text tokenization, answer/step parsing, and an exact evaluator for
//! step grading.
//!
//! The grammar mirrors `python/compile/corpus.py`:
//!   problem  := BOS Q <expr> SEP [<strategy>]
//!   trace    := (STEP <expr> EQ <number> SEP)* FIN <number> EOS
//! with `%` binding loosest (its compound left operand is always
//! parenthesized by the renderer, so standard precedence reads the same).

use anyhow::{bail, Result};

use crate::runtime::Vocab;

/// Render a non-negative integer as digit tokens (no leading zeros).
pub fn num_tokens(v: &Vocab, value: i64) -> Vec<i32> {
    assert!(value >= 0, "corpus values are non-negative");
    value.to_string().bytes().map(|b| v.digit0 + (b - b'0') as i32).collect()
}

/// Human-readable rendering of a token stream (debugging / server output).
pub fn detokenize(v: &Vocab, toks: &[i32]) -> String {
    toks.iter()
        .filter(|&&t| t != v.pad)
        .map(|t| v.names.get(t).map(|s| s.as_str()).unwrap_or("?").to_string())
        .collect()
}

/// Tokenize an expression string (`"(17+25)*3%4"`) into vocab ids.
/// Digits become individual digit tokens; whitespace is skipped.
pub fn tokenize_expr(v: &Vocab, text: &str) -> Result<Vec<i32>> {
    let mut out = Vec::new();
    for c in text.chars() {
        let t = match c {
            '0'..='9' => v.digit0 + (c as i32 - '0' as i32),
            '+' => v.plus,
            '-' => v.minus,
            '*' => v.mul,
            '(' => v.lparen,
            ')' => v.rparen,
            '%' => v.modulo,
            ' ' | '\t' => continue,
            _ => bail!("unsupported character `{c}` in expression"),
        };
        out.push(t);
    }
    if out.is_empty() {
        bail!("empty expression");
    }
    Ok(out)
}

/// Build the serving prompt: `BOS Q <expr> SEP [<strategy>]`.
pub fn prompt(v: &Vocab, expr: &[i32], strategy: Option<usize>) -> Vec<i32> {
    let mut p = Vec::with_capacity(expr.len() + 4);
    p.push(v.bos);
    p.push(v.q);
    p.extend_from_slice(expr);
    p.push(v.sep);
    if let Some(s) = strategy {
        p.push(v.strat0 + s as i32);
    }
    p
}

/// Extract the final answer from a trace ending `... FIN <digits> EOS`.
pub fn parse_answer(v: &Vocab, toks: &[i32]) -> Option<i64> {
    let fi = toks.iter().rposition(|&t| t == v.fin)?;
    let mut digits = Vec::new();
    for &t in &toks[fi + 1..] {
        if (v.digit0..v.digit0 + 10).contains(&t) {
            digits.push((t - v.digit0) as i64);
        } else {
            break;
        }
    }
    if digits.is_empty() || digits.len() > 9 {
        return None;
    }
    Some(digits.iter().fold(0, |acc, d| acc * 10 + d))
}

/// One parsed reasoning step: `STEP <lhs> EQ <claimed> SEP`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedStep {
    pub lhs: Vec<i32>,
    pub claimed: i64,
}

/// Split a full trace into its steps (ignoring the final answer segment).
pub fn parse_steps(v: &Vocab, toks: &[i32]) -> Vec<ParsedStep> {
    let mut steps = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i] != v.step {
            i += 1;
            continue;
        }
        let start = i + 1;
        let mut eq = None;
        let mut end = toks.len();
        for (j, &t) in toks.iter().enumerate().skip(start) {
            if t == v.eq && eq.is_none() {
                eq = Some(j);
            }
            if t == v.sep || t == v.eos {
                end = j;
                break;
            }
        }
        if let Some(eqi) = eq {
            let lhs = toks[start..eqi].to_vec();
            if let Some(claimed) = parse_number(v, &toks[eqi + 1..end]) {
                steps.push(ParsedStep { lhs, claimed });
            }
        }
        i = end + 1;
    }
    steps
}

fn parse_number(v: &Vocab, toks: &[i32]) -> Option<i64> {
    if toks.is_empty() || toks.len() > 9 {
        return None;
    }
    let mut acc = 0i64;
    for &t in toks {
        if !(v.digit0..v.digit0 + 10).contains(&t) {
            return None;
        }
        acc = acc * 10 + (t - v.digit0) as i64;
    }
    Some(acc)
}

/// Exact evaluator over rendered expression tokens (shunting-yard with the
/// corpus grammar: `%` loosest, then `+`/`-`, then `*`; parens). Used by
/// the step grader and the workload generator's cross-checks.
pub fn eval_expr(v: &Vocab, toks: &[i32]) -> Result<i64> {
    let mut ops: Vec<i32> = Vec::new();
    let mut vals: Vec<i64> = Vec::new();
    let prec = |t: i32| -> i32 {
        if t == v.modulo {
            0
        } else if t == v.plus || t == v.minus {
            1
        } else {
            2 // mul
        }
    };
    let apply = |vals: &mut Vec<i64>, op: i32| -> Result<()> {
        let b = vals.pop().ok_or_else(|| anyhow::anyhow!("missing rhs"))?;
        let a = vals.pop().ok_or_else(|| anyhow::anyhow!("missing lhs"))?;
        let r = if op == v.plus {
            a + b
        } else if op == v.minus {
            a - b
        } else if op == v.mul {
            a * b
        } else if op == v.modulo {
            if b == 0 {
                bail!("mod by zero");
            }
            a.rem_euclid(b)
        } else {
            bail!("unknown op token {op}")
        };
        vals.push(r);
        Ok(())
    };

    let mut i = 0;
    let mut expect_operand = true;
    while i < toks.len() {
        let t = toks[i];
        if (v.digit0..v.digit0 + 10).contains(&t) {
            let mut acc = 0i64;
            let mut n = 0;
            while i < toks.len() && (v.digit0..v.digit0 + 10).contains(&toks[i]) {
                acc = acc * 10 + (toks[i] - v.digit0) as i64;
                i += 1;
                n += 1;
                if n > 9 {
                    bail!("number too long");
                }
            }
            vals.push(acc);
            expect_operand = false;
            continue;
        } else if t == v.lparen {
            ops.push(t);
            expect_operand = true;
        } else if t == v.rparen {
            while let Some(&op) = ops.last() {
                if op == v.lparen {
                    break;
                }
                apply(&mut vals, ops.pop().unwrap())?;
            }
            if ops.pop() != Some(v.lparen) {
                bail!("unbalanced parens");
            }
            expect_operand = false;
        } else if t == v.plus || t == v.minus || t == v.mul || t == v.modulo {
            if expect_operand {
                bail!("operator in operand position");
            }
            while let Some(&op) = ops.last() {
                if op != v.lparen && prec(op) >= prec(t) {
                    apply(&mut vals, ops.pop().unwrap())?;
                } else {
                    break;
                }
            }
            ops.push(t);
            expect_operand = true;
        } else {
            bail!("unexpected token {t} in expression");
        }
        i += 1;
    }
    while let Some(op) = ops.pop() {
        if op == v.lparen {
            bail!("unbalanced parens");
        }
        apply(&mut vals, op)?;
    }
    if vals.len() != 1 {
        bail!("malformed expression");
    }
    Ok(vals[0])
}

/// Fraction of steps in a trace whose claimed value is arithmetically
/// correct (an analysis metric the paper's Fig. 5 discussion implies).
pub fn step_correctness(v: &Vocab, toks: &[i32]) -> Option<f64> {
    let steps = parse_steps(v, toks);
    if steps.is_empty() {
        return None;
    }
    let ok = steps
        .iter()
        .filter(|s| eval_expr(v, &s.lhs).map(|x| x == s.claimed).unwrap_or(false))
        .count();
    Some(ok as f64 / steps.len() as f64)
}


/// The corpus vocabulary layout (ids mirror `python/compile/corpus.py`).
/// Manifest-free paths (calibrated backend, tests, workload generation)
/// use this; artifact-backed paths read the manifest instead — an
/// integration test asserts the two agree.
pub fn builtin_vocab() -> Vocab {
    use std::collections::BTreeMap;
    let mut names = BTreeMap::new();
    for d in 0..10 {
        names.insert(7 + d, d.to_string());
    }
    for (id, s) in [
        (0, "<pad>"),
        (1, "<bos>"),
        (2, "Q"),
        (3, ";"),
        (4, "S"),
        (5, "F"),
        (6, "."),
        (17, "+"),
        (18, "-"),
        (19, "*"),
        (20, "("),
        (21, ")"),
        (22, "="),
        (23, "%"),
    ] {
        names.insert(id, s.to_string());
    }
    for s in 0..13 {
        names.insert(24 + s, format!("<{}>", (b'A' + s as u8) as char));
    }
    Vocab {
        size: 64,
        pad: 0,
        bos: 1,
        q: 2,
        sep: 3,
        step: 4,
        fin: 5,
        eos: 6,
        digit0: 7,
        plus: 17,
        minus: 18,
        mul: 19,
        lparen: 20,
        rparen: 21,
        eq: 22,
        modulo: 23,
        strat0: 24,
        num_strategies: 13,
        names,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    /// Vocab for tests (no artifacts needed).
    pub(crate) fn test_vocab() -> Vocab {
        super::builtin_vocab()
    }

    #[test]
    fn tokenize_eval_roundtrip() {
        let v = test_vocab();
        for (text, want) in [
            ("1+2", 3),
            ("17+25*3", 92),
            ("(17+25)*3", 126),
            ("10-3-2", 5),
            ("(2*5+26)%4", 0),
            ("100*3", 300),
        ] {
            let toks = tokenize_expr(&v, text).unwrap();
            assert_eq!(eval_expr(&v, &toks).unwrap(), want, "{text}");
            assert_eq!(detokenize(&v, &toks), text);
        }
    }

    #[test]
    fn eval_rejects_malformed() {
        let v = test_vocab();
        for bad in ["+1", "1+", "(1+2", "1)(", "1++2"] {
            let toks = tokenize_expr(&v, bad).unwrap();
            assert!(eval_expr(&v, &toks).is_err(), "{bad}");
        }
        assert!(tokenize_expr(&v, "1a2").is_err());
        assert!(tokenize_expr(&v, "").is_err());
    }

    #[test]
    fn prompt_layout() {
        let v = test_vocab();
        let expr = tokenize_expr(&v, "1+2").unwrap();
        let p = prompt(&v, &expr, Some(4));
        assert_eq!(p[0], v.bos);
        assert_eq!(p[1], v.q);
        assert_eq!(p[p.len() - 2], v.sep);
        assert_eq!(p[p.len() - 1], v.strat0 + 4);
        let p2 = prompt(&v, &expr, None);
        assert_eq!(p2.len(), p.len() - 1);
    }

    #[test]
    fn parse_answer_finds_last_fin() {
        let v = test_vocab();
        // S 1+2=3 ; F 36 .
        let mut toks = vec![v.step];
        toks.extend(tokenize_expr(&v, "1+2").unwrap());
        toks.push(v.eq);
        toks.extend(num_tokens(&v, 3));
        toks.push(v.sep);
        toks.push(v.fin);
        toks.extend(num_tokens(&v, 36));
        toks.push(v.eos);
        assert_eq!(parse_answer(&v, &toks), Some(36));
    }

    #[test]
    fn parse_answer_none_without_fin_or_digits() {
        let v = test_vocab();
        assert_eq!(parse_answer(&v, &[v.step, v.sep]), None);
        assert_eq!(parse_answer(&v, &[v.fin, v.eos]), None);
    }

    #[test]
    fn parse_steps_and_grade() {
        let v = test_vocab();
        // S 4*3=12 ; S 5+12=17 ; F 17 .   (all correct)
        let mut toks = Vec::new();
        for (lhs, val) in [("4*3", 12), ("5+12", 17)] {
            toks.push(v.step);
            toks.extend(tokenize_expr(&v, lhs).unwrap());
            toks.push(v.eq);
            toks.extend(num_tokens(&v, val));
            toks.push(v.sep);
        }
        toks.push(v.fin);
        toks.extend(num_tokens(&v, 17));
        toks.push(v.eos);
        let steps = parse_steps(&v, &toks);
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].claimed, 12);
        assert_eq!(step_correctness(&v, &toks), Some(1.0));

        // corrupt the second step's claimed value
        let bad: Vec<i32> = toks
            .iter()
            .map(|&t| if t == v.digit0 + 7 { v.digit0 + 8 } else { t })
            .collect();
        assert!(step_correctness(&v, &bad).unwrap() < 1.0);
    }

    #[test]
    fn num_tokens_no_leading_zeros() {
        let v = test_vocab();
        assert_eq!(num_tokens(&v, 0), vec![v.digit0]);
        assert_eq!(num_tokens(&v, 105), vec![v.digit0 + 1, v.digit0, v.digit0 + 5]);
    }

    #[test]
    fn rem_euclid_semantics() {
        let v = test_vocab();
        // our corpus never renders negatives, but the evaluator must not
        // return negative remainders if an intermediate dips below zero
        let toks = tokenize_expr(&v, "(1-3)%4").unwrap();
        assert_eq!(eval_expr(&v, &toks).unwrap(), 2);
    }
}
