//! Host-side sampling over logits rows — used for the first token after a
//! prefill, for strategy selection (SPM reads the target model's
//! distribution over strategy tokens), and by the calibrated backend.

use crate::util::rng::Rng;

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

/// Log-softmax (scoring paths re-derive per-token log-probs host-side).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|&x| (x - m).exp()).sum();
    let lz = z.ln() + m;
    logits.iter().map(|&x| x - lz).collect()
}

pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Greedy when `temp <= 0`, else temperature sampling.
pub fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    if temp <= 0.0 {
        return argmax(logits);
    }
    let scaled: Vec<f32> = logits.iter().map(|&x| x / temp).collect();
    let probs = softmax(&scaled);
    let x = rng.f64() as f32;
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if x < acc {
            return i;
        }
    }
    probs.len() - 1
}

/// Indices of the `n` largest logits, descending (deterministic
/// tie-break by index, so strategy selection is reproducible).
pub fn top_n(logits: &[f32], n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(n);
    idx
}

/// Sample `n` distinct indices without replacement, proportional to
/// softmax probabilities (the stochastic variant of strategy selection).
pub fn sample_n_distinct(logits: &[f32], n: usize, temp: f32, rng: &mut Rng) -> Vec<usize> {
    let mut remaining: Vec<usize> = (0..logits.len()).collect();
    let mut out = Vec::with_capacity(n);
    let t = temp.max(1e-3);
    while out.len() < n && !remaining.is_empty() {
        let weights: Vec<f64> = {
            let sub: Vec<f32> = remaining.iter().map(|&i| logits[i] / t).collect();
            softmax(&sub).iter().map(|&p| p as f64).collect()
        };
        let pick = rng.choice_weighted(&weights);
        out.push(remaining.remove(pick));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn log_softmax_consistent() {
        let l = [0.5f32, -1.0, 2.0];
        let ls = log_softmax(&l);
        let s = softmax(&l);
        for (a, b) in ls.iter().zip(&s) {
            assert!((a.exp() - b).abs() < 1e-6);
        }
    }

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.1, 5.0, 0.2], 0.0, &mut rng), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn sampling_respects_distribution() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 2.0];
        let mut counts = [0usize; 2];
        for _ in 0..5000 {
            counts[sample(&logits, 1.0, &mut rng)] += 1;
        }
        let frac = counts[1] as f64 / 5000.0;
        let expect = (2.0f64.exp()) / (1.0 + 2.0f64.exp());
        assert!((frac - expect).abs() < 0.03, "frac={frac} expect={expect}");
    }

    #[test]
    fn top_n_ordering_and_tiebreak() {
        assert_eq!(top_n(&[1.0, 3.0, 2.0, 3.0], 3), vec![1, 3, 2]);
        assert_eq!(top_n(&[1.0], 5), vec![0]);
    }

    #[test]
    fn sample_n_distinct_no_repeats() {
        let mut rng = Rng::new(3);
        let logits = vec![0.0f32; 13];
        for _ in 0..50 {
            let picks = sample_n_distinct(&logits, 5, 1.0, &mut rng);
            assert_eq!(picks.len(), 5);
            let mut s = picks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 5);
        }
    }
}
