//! pass@k accounting across trials (paper §4.1: 6 independent trials per
//! problem, pass@1 = exact match of the aggregated answer, pass@3 over
//! the pooled candidate answers).

use crate::coordinator::aggregation::{pass_at_k, PathVote};

/// Accumulates one problem's outcomes across trials.
#[derive(Debug, Clone, Default)]
pub struct ProblemTally {
    pub gold: i64,
    /// per-trial: (aggregated answer, all path votes)
    pub trials: Vec<(Option<i64>, Vec<PathVote>)>,
}

impl ProblemTally {
    pub fn new(gold: i64) -> Self {
        ProblemTally { gold, trials: Vec::new() }
    }

    pub fn add_trial(&mut self, answer: Option<i64>, votes: Vec<PathVote>) {
        self.trials.push((answer, votes));
    }

    /// Fraction of trials whose aggregated answer is exactly right.
    pub fn pass1(&self) -> f64 {
        if self.trials.is_empty() {
            return 0.0;
        }
        let hit = self.trials.iter().filter(|(a, _)| *a == Some(self.gold)).count();
        hit as f64 / self.trials.len() as f64
    }

    /// pass@3 per trial over that trial's pooled path votes; single-path
    /// methods pool votes from up to 3 consecutive trials (sampling-based
    /// candidates, as the paper's stochastic-decoding protocol implies).
    pub fn pass3(&self) -> f64 {
        if self.trials.is_empty() {
            return 0.0;
        }
        let multi_path = self.trials.iter().any(|(_, v)| v.len() >= 3);
        if multi_path {
            let hit = self
                .trials
                .iter()
                .filter(|(_, votes)| pass_at_k(votes, self.gold, 3))
                .count();
            hit as f64 / self.trials.len() as f64
        } else {
            // pool windows of 3 trials
            let mut hits = 0;
            let mut windows = 0;
            for chunk in self.trials.chunks(3) {
                let pooled: Vec<PathVote> =
                    chunk.iter().flat_map(|(_, v)| v.clone()).collect();
                if pass_at_k(&pooled, self.gold, 3) {
                    hits += 1;
                }
                windows += 1;
            }
            hits as f64 / windows as f64
        }
    }
}

/// Mean pass@1 / pass@3 over a set of problems.
pub fn summarize(tallies: &[ProblemTally]) -> (f64, f64) {
    if tallies.is_empty() {
        return (0.0, 0.0);
    }
    let p1 = tallies.iter().map(|t| t.pass1()).sum::<f64>() / tallies.len() as f64;
    let p3 = tallies.iter().map(|t| t.pass3()).sum::<f64>() / tallies.len() as f64;
    (p1, p3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vote(a: Option<i64>) -> PathVote {
        PathVote { answer: a, step_scores: vec![8] }
    }

    #[test]
    fn pass1_counts_aggregated_answers() {
        let mut t = ProblemTally::new(5);
        t.add_trial(Some(5), vec![vote(Some(5))]);
        t.add_trial(Some(4), vec![vote(Some(4))]);
        assert_eq!(t.pass1(), 0.5);
    }

    #[test]
    fn pass3_multi_path_within_trial() {
        let mut t = ProblemTally::new(9);
        // aggregated answer wrong, but gold among top-3 candidates
        t.add_trial(Some(1), vec![vote(Some(1)), vote(Some(1)), vote(Some(9))]);
        assert_eq!(t.pass1(), 0.0);
        assert_eq!(t.pass3(), 1.0);
    }

    #[test]
    fn pass3_single_path_pools_trials() {
        let mut t = ProblemTally::new(7);
        t.add_trial(Some(1), vec![vote(Some(1))]);
        t.add_trial(Some(7), vec![vote(Some(7))]);
        t.add_trial(Some(3), vec![vote(Some(3))]);
        // one window of 3 trials pooling {1,7,3} -> gold in top-3
        assert_eq!(t.pass3(), 1.0);
        assert!((t.pass1() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn pass3_at_least_pass1() {
        let mut t = ProblemTally::new(2);
        t.add_trial(Some(2), vec![vote(Some(2)), vote(Some(3)), vote(Some(2))]);
        t.add_trial(Some(3), vec![vote(Some(3)), vote(Some(3)), vote(Some(2))]);
        assert!(t.pass3() >= t.pass1());
    }

    #[test]
    fn summarize_means() {
        let mut a = ProblemTally::new(1);
        a.add_trial(Some(1), vec![vote(Some(1))]);
        let mut b = ProblemTally::new(2);
        b.add_trial(Some(9), vec![vote(Some(9))]);
        let (p1, _) = summarize(&[a, b]);
        assert_eq!(p1, 0.5);
        assert_eq!(summarize(&[]), (0.0, 0.0));
    }
}
