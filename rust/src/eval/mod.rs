//! Evaluation layer: pass@k scoring, experiment runners for every table
//! and figure in the paper's evaluation section, and text-table report
//! rendering (EXPERIMENTS.md records their output).

pub mod experiments;
pub mod passk;
pub mod report;
