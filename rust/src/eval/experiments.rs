//! Regenerators for every table and figure in the paper's evaluation
//! (§4, Appendix B/C). Each returns structured rows AND renders the
//! paper-shaped text output; `ssr exp <id>` and `benches/` drive them.
//!
//! Accuracy experiments run on the calibrated backend by default
//! (paper-scale operating points; see DESIGN.md §1) with `--backend
//! pjrt` switching to the real trained pair; mechanism experiments
//! (fig5 scores, gamma-measured, serving) use the real stack.

use anyhow::Result;

use crate::backend::Backend;
use crate::config::{SsrConfig, StopRule};
use crate::coordinator::engine::{Engine, Method};
use crate::coordinator::flops;
use crate::eval::passk::{summarize, ProblemTally};
use crate::eval::report;
use crate::util::stats::{mean, Histogram};
use crate::workload::{suites, Problem};

/// A backend factory: fresh backend per (suite, trial) so trials are
/// independent (fresh PRNG streams / fresh lane tables).
pub type Factory<'a> = &'a mut dyn FnMut(&str, u64) -> Result<Box<dyn Backend>>;

pub const SUITES: [&str; 3] = ["synth-aime", "synth-math500", "synth-livemath"];

/// Cap on problems per suite (keeps experiment wall-time sane; the
/// full-suite run is a CLI flag away).
#[derive(Debug, Clone, Copy)]
pub struct ExpOpts {
    pub trials: u64,
    pub max_problems: usize,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { trials: 6, max_problems: 60 }
    }
}

fn problems_for(suite: &str, opts: &ExpOpts) -> Result<Vec<Problem>> {
    let v = crate::workload::suites::generate(
        suites::spec(suite)?,
        &crate::model::tokenizer::builtin_vocab(),
    );
    Ok(v.problems.into_iter().take(opts.max_problems).collect())
}

/// One evaluated method on one suite.
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub suite: String,
    pub method: String,
    pub pass1: f64,
    pub pass3: f64,
    pub mean_time_s: f64,
    /// measured normalized FLOPs vs the measured baseline
    pub gamma: f64,
    pub rewrite_rate: f64,
    pub draft_tokens: u64,
    pub target_tokens: u64,
}

/// Run `method` over a suite; returns the row plus per-problem tallies.
pub fn run_method(
    factory: Factory,
    suite: &str,
    method: Method,
    cfg: &SsrConfig,
    opts: &ExpOpts,
    base_target_tokens: Option<f64>,
) -> Result<MethodRow> {
    let problems = problems_for(suite, opts)?;
    let mut tallies: Vec<ProblemTally> =
        problems.iter().map(|p| ProblemTally::new(p.answer)).collect();
    let mut times = Vec::new();
    let (mut steps, mut rewrites) = (0u64, 0u64);
    // the shared FLOPs ledger (flops::MeasuredGamma) is THE gamma
    // accounting: draft tokens at alpha, rewritten target tokens at 1,
    // scored-but-not-rewritten tokens tracked but never billed — so
    // this row, the stats plane and every BENCH_JSON scalar agree
    let alpha = factory(suite, 0)?.meta().alpha;
    let mut ledger = flops::MeasuredGamma::new(alpha);

    for trial in 0..opts.trials {
        let mut backend = factory(suite, 0xBEEF + trial)?;
        let mut engine = Engine::new(backend.as_mut(), cfg.clone());
        for (i, p) in problems.iter().enumerate() {
            let r = engine.run(p, method, trial * 6151 + i as u64)?;
            tallies[i].add_trial(r.answer(), r.votes.clone());
            times.push(r.model_secs);
            ledger.add_tokens(r.draft_tokens, r.target_tokens);
            ledger.add_score_tokens(r.score_tokens);
            steps += r.steps;
            rewrites += r.rewrites;
        }
    }

    let (pass1, pass3) = summarize(&tallies);
    let runs = (opts.trials as usize * problems.len()) as f64;
    let gamma = base_target_tokens.map(|b| ledger.gamma_per_run(runs, b)).unwrap_or(1.0);
    Ok(MethodRow {
        suite: suite.to_string(),
        method: method.name(),
        pass1,
        pass3,
        mean_time_s: mean(&times),
        gamma,
        rewrite_rate: if steps == 0 { 0.0 } else { rewrites as f64 / steps as f64 },
        draft_tokens: ledger.draft_tokens,
        target_tokens: ledger.target_tokens,
    })
}

/// Baseline cost per run, in target-token units (gamma denominator).
pub fn baseline_cost(
    factory: Factory,
    suite: &str,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<f64> {
    let row = run_method(factory, suite, Method::Baseline, cfg, opts, None)?;
    let runs = (opts.trials as usize * problems_for(suite, opts)?.len()) as f64;
    Ok(row.target_tokens as f64 / runs)
}

// ---------------------------------------------------------------------------
// Fig. 2 — accuracy vs number of parallel paths (saturation study).
// ---------------------------------------------------------------------------

/// One (suite, n, pass@1) point of the Fig. 2 saturation study.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub suite: String,
    pub n: usize,
    pub pass1: f64,
}

pub fn fig2(factory: Factory, cfg: &SsrConfig, opts: &ExpOpts) -> Result<(Vec<Fig2Point>, String)> {
    let mut rows = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let mut points = Vec::new();
        for n in 1..=10usize {
            let method =
                if n == 1 { Method::Baseline } else { Method::Parallel { n, spm: false } };
            let row = run_method(factory, suite, method, cfg, opts, None)?;
            points.push((n as f64, row.pass1));
            rows.push(Fig2Point { suite: suite.to_string(), n, pass1: row.pass1 });
        }
        out.push_str(&report::series(
            &format!("Fig.2 {suite}: pass@1 vs parallel paths"),
            "paths",
            "pass@1",
            &points,
        ));
        out.push('\n');
    }
    Ok((rows, out))
}

// ---------------------------------------------------------------------------
// Fig. 3 — accuracy vs computational efficiency (1/gamma), 5 settings.
// ---------------------------------------------------------------------------

pub fn fig3(factory: Factory, cfg: &SsrConfig, opts: &ExpOpts) -> Result<(Vec<MethodRow>, String)> {
    let mut rows = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let base = baseline_cost(factory, suite, cfg, opts)?;
        let methods = [
            Method::Baseline,
            Method::Parallel { n: 5, spm: false },
            Method::Parallel { n: 5, spm: true },
            Method::Ssr { n: 3, tau: cfg.tau, stop: StopRule::Full },
            Method::Ssr { n: 5, tau: cfg.tau, stop: StopRule::Full },
        ];
        let mut table_rows = Vec::new();
        for m in methods {
            let row = run_method(factory, suite, m, cfg, opts, Some(base))?;
            table_rows.push(vec![
                row.method.clone(),
                report::pct(row.pass1),
                report::f3(row.gamma),
                report::f3(1.0 / row.gamma.max(1e-9)),
                report::f2(row.rewrite_rate),
            ]);
            rows.push(row);
        }
        out.push_str(&report::table(
            &format!("Fig.3 {suite}: accuracy vs efficiency"),
            &["method", "pass@1", "gamma", "efficiency(1/g)", "R"],
            &table_rows,
        ));
        out.push('\n');
    }
    Ok((rows, out))
}

// ---------------------------------------------------------------------------
// Fig. 4 — SPM ablation: Baseline vs Parallel vs Parallel-SPM (N=5, no SSD).
// ---------------------------------------------------------------------------

pub fn fig4(factory: Factory, cfg: &SsrConfig, opts: &ExpOpts) -> Result<(Vec<MethodRow>, String)> {
    let mut rows = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let methods = [
            Method::Baseline,
            Method::Parallel { n: 5, spm: false },
            Method::Parallel { n: 5, spm: true },
        ];
        let mut table_rows = Vec::new();
        for m in methods {
            let row = run_method(factory, suite, m, cfg, opts, None)?;
            table_rows.push(vec![row.method.clone(), report::pct(row.pass1)]);
            rows.push(row);
        }
        out.push_str(&report::table(
            &format!("Fig.4 {suite}: SPM ablation (N=5, SSD off)"),
            &["method", "pass@1"],
            &table_rows,
        ));
        out.push('\n');
    }
    Ok((rows, out))
}

// ---------------------------------------------------------------------------
// Table 1 — baseline / spec-reason(7,9) / SSR-Fast-1/2 / SSR.
// ---------------------------------------------------------------------------

pub fn table1(
    factory: Factory,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<(Vec<MethodRow>, String)> {
    let mut rows = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let methods = [
            Method::Baseline,
            Method::SpecReason { tau: 7 },
            Method::SpecReason { tau: 9 },
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Fast1 },
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Fast2 },
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
        ];
        let mut table_rows = Vec::new();
        for m in methods {
            let row = run_method(factory, suite, m, cfg, opts, None)?;
            table_rows.push(vec![
                row.method.clone(),
                report::pct(row.pass1),
                report::pct(row.pass3),
                report::f2(row.mean_time_s),
            ]);
            rows.push(row);
        }
        out.push_str(&report::table(
            &format!("Table 1 {suite}"),
            &["method", "pass@1", "pass@3", "time(s)"],
            &table_rows,
        ));
        out.push('\n');
    }
    Ok((rows, out))
}

// ---------------------------------------------------------------------------
// Fig. 5 — step-score distribution + cumulative (tau justification).
// ---------------------------------------------------------------------------

pub fn fig5(factory: Factory, cfg: &SsrConfig, opts: &ExpOpts) -> Result<(Histogram, String)> {
    let mut hist = Histogram::new(10);
    for suite in SUITES {
        let mut backend = factory(suite, 0xF16_5)?;
        {
            let mut engine = Engine::new(backend.as_mut(), cfg.clone());
            let problems = problems_for(suite, opts)?;
            for (i, p) in problems.iter().take(opts.max_problems.min(25)).enumerate() {
                let _ = engine.run(
                    p,
                    Method::Ssr { n: 3, tau: cfg.tau, stop: StopRule::Full },
                    i as u64,
                )?;
            }
        }
        hist.merge(&backend.score_histogram());
    }
    let fr = hist.fractions();
    let cum = hist.cumulative();
    let mut rows = Vec::new();
    for s in 0..10 {
        rows.push(vec![
            s.to_string(),
            report::pct(fr[s]),
            report::pct(cum[s]),
        ]);
    }
    let mut out = report::table(
        "Fig.5 step-score distribution (0-9) with cumulative",
        &["score", "fraction %", "cumulative %"],
        &rows,
    );
    out.push_str(&format!(
        "\nfraction below tau=7: {}%  (paper: slightly over 20%)\n",
        report::pct(cum[6])
    ));
    Ok((hist, out))
}

// ---------------------------------------------------------------------------
// Appendix B — analytic gamma vs measured gamma.
// ---------------------------------------------------------------------------

/// One suite's analytic-vs-measured gamma point (Appendix B),
/// structured so `benches/gamma_model.rs` emits the SAME scalars this
/// table prints — both sides of every BENCH_JSON gamma number come
/// from [`flops::MeasuredGamma`], never a local recomputation.
#[derive(Debug, Clone)]
pub struct GammaRow {
    pub suite: String,
    pub alpha: f64,
    pub beta: f64,
    pub rewrite_rate: f64,
    /// Eq. 11 closed form at the measured (beta, R, alpha)
    pub analytic: f64,
    /// the shared token-ledger gamma (`MethodRow::gamma`)
    pub measured: f64,
}

pub fn gamma_check(
    factory: Factory,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<(Vec<GammaRow>, String)> {
    let mut rows = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let base = baseline_cost(factory, suite, cfg, opts)?;
        let ssr = run_method(
            factory,
            suite,
            Method::Ssr { n: 5, tau: cfg.tau, stop: StopRule::Full },
            cfg,
            opts,
            Some(base),
        )?;
        let alpha = factory(suite, 0)?.meta().alpha;
        let runs = (opts.trials as usize * problems_for(suite, opts)?.len()) as f64;
        // beta: tokens per path / baseline tokens
        let beta = (ssr.draft_tokens as f64 / runs / 5.0) / base;
        let analytic = flops::gamma_spec(5, beta, ssr.rewrite_rate, alpha);
        out.push_str(&report::table(
            &format!("Appendix B {suite}: analytic vs measured gamma (SSR-m5)"),
            &["quantity", "value"],
            &[
                vec!["alpha".into(), report::f3(alpha)],
                vec!["beta".into(), report::f3(beta)],
                vec!["R (step rate)".into(), report::f3(ssr.rewrite_rate)],
                vec!["gamma analytic (Eq.11)".into(), report::f3(analytic)],
                vec!["gamma measured".into(), report::f3(ssr.gamma)],
                vec![
                    "gamma parallel-5 (Eq.8)".into(),
                    report::f3(flops::gamma_parallel(5)),
                ],
            ],
        ));
        out.push('\n');
        rows.push(GammaRow {
            suite: suite.to_string(),
            alpha,
            beta,
            rewrite_rate: ssr.rewrite_rate,
            analytic,
            measured: ssr.gamma,
        });
    }
    Ok((rows, out))
}


// ---------------------------------------------------------------------------
// Ablations beyond the paper's figures (DESIGN.md §7): the rewrite
// threshold sweep behind Appendix C's tau = 7 choice, and the SPM
// selection-mode ablation (model-internal vs random vs oracle).
// ---------------------------------------------------------------------------

/// The taus the sweep visits (Appendix C grid).
pub const TAU_GRID: [u8; 5] = [0, 3, 5, 7, 9];

/// One (suite, tau) point of the rewrite-threshold sweep — structured
/// like [`Fig2Point`] so the bench tracker can watch the tau=7 plateau
/// as scalars instead of scraping tables.
#[derive(Debug, Clone)]
pub struct TauPoint {
    pub suite: String,
    pub tau: u8,
    pub pass1: f64,
    pub gamma: f64,
    pub rewrite_rate: f64,
    pub mean_time_s: f64,
}

/// Appendix-C-style threshold sweep: SSR-m3 accuracy and cost as tau
/// moves from accept-everything (0) to rewrite-almost-everything (9).
/// Returns structured points plus the rendered table.
pub fn tau_sweep(
    factory: Factory,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<(Vec<TauPoint>, String)> {
    let mut points = Vec::new();
    let mut out = String::new();
    for suite in ["synth-aime", "synth-livemath"] {
        let base = baseline_cost(factory, suite, cfg, opts)?;
        let mut rows = Vec::new();
        for tau in TAU_GRID {
            let row = run_method(
                factory,
                suite,
                Method::Ssr { n: 3, tau, stop: StopRule::Full },
                cfg,
                opts,
                Some(base),
            )?;
            rows.push(vec![
                tau.to_string(),
                report::pct(row.pass1),
                report::f3(row.gamma),
                report::f2(row.rewrite_rate),
                report::f2(row.mean_time_s),
            ]);
            points.push(TauPoint {
                suite: suite.to_string(),
                tau,
                pass1: row.pass1,
                gamma: row.gamma,
                rewrite_rate: row.rewrite_rate,
                mean_time_s: row.mean_time_s,
            });
        }
        out.push_str(&report::table(
            &format!("Appendix C {suite}: rewrite-threshold sweep (SSR-m3)"),
            &["tau", "pass@1", "gamma", "R", "time(s)"],
            &rows,
        ));
        out.push('\n');
    }
    Ok((points, out))
}

/// One (suite, selection-mode) point of the SPM selection ablation.
#[derive(Debug, Clone)]
pub struct SelectionPoint {
    pub suite: String,
    pub selection: String,
    pub pass1: f64,
}

/// SPM selection-mode ablation at N=5 (SSD off, isolating selection).
/// Returns structured points plus the rendered table.
pub fn selection_ablation(
    factory: Factory,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<(Vec<SelectionPoint>, String)> {
    use crate::config::Selection;
    let mut points = Vec::new();
    let mut out = String::new();
    for suite in SUITES {
        let mut rows = Vec::new();
        for (label, sel) in [
            ("random", Selection::Random),
            ("model-sample", Selection::ModelSample),
            ("model-top", Selection::ModelTopN),
            ("oracle", Selection::Oracle),
        ] {
            let mut cfg2 = cfg.clone();
            cfg2.selection = sel;
            let row = run_method(
                factory,
                suite,
                Method::Parallel { n: 5, spm: true },
                &cfg2,
                opts,
                None,
            )?;
            rows.push(vec![label.to_string(), report::pct(row.pass1)]);
            points.push(SelectionPoint {
                suite: suite.to_string(),
                selection: label.to_string(),
                pass1: row.pass1,
            });
        }
        out.push_str(&report::table(
            &format!("Selection ablation {suite} (Parallel-SPM, N=5)"),
            &["selection", "pass@1"],
            &rows,
        ));
        out.push('\n');
    }
    Ok((points, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;

    fn cal_factory() -> impl FnMut(&str, u64) -> Result<Box<dyn Backend>> {
        |suite: &str, seed: u64| {
            Ok(Box::new(CalibratedBackend::for_suite(suite, seed)?) as Box<dyn Backend>)
        }
    }

    fn small_opts() -> ExpOpts {
        ExpOpts { trials: 2, max_problems: 20 }
    }

    #[test]
    fn method_row_runs() {
        let mut f = cal_factory();
        let row = run_method(
            &mut f,
            "synth-aime",
            Method::Baseline,
            &SsrConfig::default(),
            &small_opts(),
            None,
        )
        .unwrap();
        assert!(row.pass1 >= 0.0 && row.pass1 <= 1.0);
        assert!(row.pass3 >= row.pass1 - 1e-9);
        assert!(row.target_tokens > 0);
        assert_eq!(row.draft_tokens, 0);
    }

    #[test]
    fn fig3_orderings_hold() {
        // The paper's qualitative claims on the calibrated substrate:
        // parallel-SPM most accurate; SSR cheaper than parallel; SSR more
        // accurate than baseline on livemath.
        let mut f = cal_factory();
        let opts = ExpOpts { trials: 3, max_problems: 40 };
        let (rows, _) = fig3(&mut f, &SsrConfig::default(), &opts).unwrap();
        let get = |suite: &str, m: &str| {
            rows.iter()
                .find(|r| r.suite == suite && r.method == m)
                .unwrap_or_else(|| panic!("{suite}/{m}"))
                .clone()
        };
        for suite in SUITES {
            let base = get(suite, "baseline");
            let par = get(suite, "parallel-5");
            let spm = get(suite, "parallel-spm-5");
            let ssr5 = get(suite, "ssr-m5");
            // accuracy ordering (allow small sampling noise)
            assert!(spm.pass1 >= par.pass1 - 0.05, "{suite}: spm vs par");
            assert!(par.pass1 >= base.pass1 - 0.03, "{suite}: par vs base");
            // cost ordering: gamma(parallel) ~5x baseline; SSR far cheaper
            assert!(par.gamma > 3.5, "{suite}: parallel gamma {}", par.gamma);
            assert!(ssr5.gamma < par.gamma * 0.6, "{suite}: ssr gamma {}", ssr5.gamma);
        }
        // headline: livemath SSR-m5 beats baseline accuracy at < baseline*1.2 cost
        let base = get("synth-livemath", "baseline");
        let ssr5 = get("synth-livemath", "ssr-m5");
        assert!(ssr5.pass1 > base.pass1 + 0.03, "livemath ssr {} base {}", ssr5.pass1, base.pass1);
    }

    #[test]
    fn fig5_histogram_below_tau_fraction() {
        let mut f = cal_factory();
        let (hist, text) = fig5(&mut f, &SsrConfig::default(), &small_opts()).unwrap();
        let cum = hist.cumulative();
        assert!(
            (0.08..0.45).contains(&cum[6]),
            "below-7 fraction {} out of range\n{text}",
            cum[6]
        );
    }

    #[test]
    fn tau_sweep_and_selection_emit_structured_rows() {
        let mut f = cal_factory();
        let opts = ExpOpts { trials: 1, max_problems: 8 };
        let (taus, text) = tau_sweep(&mut f, &SsrConfig::default(), &opts).unwrap();
        assert_eq!(taus.len(), 2 * TAU_GRID.len(), "2 suites x 5 taus");
        for p in &taus {
            assert!(TAU_GRID.contains(&p.tau));
            assert!((0.0..=1.0).contains(&p.pass1), "{p:?}");
            assert!(p.gamma > 0.0, "{p:?}");
        }
        assert!(text.contains("rewrite-threshold sweep"));

        let (sels, text) = selection_ablation(&mut f, &SsrConfig::default(), &opts).unwrap();
        assert_eq!(sels.len(), SUITES.len() * 4, "3 suites x 4 modes");
        assert!(sels.iter().any(|p| p.selection == "oracle"));
        assert!(text.contains("Selection ablation"));
    }

    #[test]
    fn gamma_check_renders() {
        let mut f = cal_factory();
        let opts = ExpOpts { trials: 1, max_problems: 10 };
        let (rows, out) = gamma_check(&mut f, &SsrConfig::default(), &opts).unwrap();
        assert!(out.contains("gamma analytic"));
        assert!(out.contains("alpha"));
        // the structured rows carry the same ledger gamma the table
        // prints (one per suite, all positive and paper-plausible)
        assert_eq!(rows.len(), SUITES.len());
        for r in &rows {
            assert!(r.measured > 0.0 && r.measured < 10.0, "{r:?}");
            assert!(r.analytic > 0.0, "{r:?}");
            assert!((0.0..=1.0).contains(&r.rewrite_rate), "{r:?}");
        }
    }
}
