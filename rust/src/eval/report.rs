//! Plain-text table / series rendering for experiment output (the same
//! rows the paper's tables and figure series report). Also JSON dumps
//! for downstream plotting.

use std::fmt::Write as _;

/// Render an aligned text table.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "## {title}");
    let line = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    let _ = writeln!(out, "{}", line(&hdr, &widths));
    let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        let _ = writeln!(out, "{}", line(row, &widths));
    }
    out
}

/// Render an (x, y) series as a small text plot plus the raw points —
/// used for the figure-shaped experiments (fig2, fig5 cumulative).
pub fn series(title: &str, xlabel: &str, ylabel: &str, points: &[(f64, f64)]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## {title}");
    let _ = writeln!(out, "{xlabel:>10}  {ylabel:>10}  ");
    let ymax = points.iter().map(|p| p.1).fold(f64::MIN, f64::max).max(1e-9);
    for &(x, y) in points {
        let bars = ((y / ymax) * 40.0).round() as usize;
        let _ = writeln!(out, "{x:>10.3}  {y:>10.4}  {}", "#".repeat(bars));
    }
    out
}

pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            "T",
            &["method", "pass@1"],
            &[
                vec!["baseline".into(), "38.89".into()],
                vec!["ssr".into(), "53.33".into()],
            ],
        );
        assert!(t.contains("## T"));
        assert!(t.contains("baseline"));
        let lines: Vec<&str> = t.lines().collect();
        // header and rows right-aligned to same width
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn series_renders_bars() {
        let s = series("acc vs n", "n", "acc", &[(1.0, 0.5), (2.0, 1.0)]);
        assert!(s.contains("####"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.5333), "53.33");
        assert_eq!(f2(1.188), "1.19");
        assert_eq!(f3(0.1234), "0.123");
    }
}
