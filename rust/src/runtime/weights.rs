//! Trained-parameter loading: flat little-endian f32 blob + JSON manifest
//! (written by `python/compile/train.py::save_weights`). Parameters become
//! one shaped [`Literal`] each, in the exact canonical order the HLO entry
//! points expect them as leading arguments.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::literals::lit_f32;
use super::manifest::ModelSpec;
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

pub struct Weights {
    pub model: String,
    pub entries: Vec<WeightEntry>,
    pub literals: Vec<Literal>,
    pub n_elems: usize,
}

impl Weights {
    pub fn load(dir: &Path, spec: &ModelSpec) -> Result<Self> {
        let jpath = dir.join(&spec.weights_json);
        let text = std::fs::read_to_string(&jpath)
            .with_context(|| format!("reading {jpath:?} — run `make artifacts` first"))?;
        let v = Value::parse(&text)?;
        let n_elems = v.get_usize("n_elems")?;

        let bpath = dir.join(&spec.weights_bin);
        let bytes = std::fs::read(&bpath).with_context(|| format!("reading {bpath:?}"))?;
        if bytes.len() != n_elems * 4 {
            bail!("{bpath:?}: {} bytes, manifest says {} f32s", bytes.len(), n_elems);
        }
        let blob: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut entries = Vec::new();
        let mut literals = Vec::new();
        for ent in v.get("params")?.arr()? {
            let name = ent.get_str("name")?.to_string();
            let shape: Vec<usize> = ent
                .get("shape")?
                .arr()?
                .iter()
                .map(|x| x.usize())
                .collect::<Result<Vec<_>>>()?;
            let offset = ent.get_usize("offset")?;
            let size = ent.get_usize("size")?;
            let n: usize = shape.iter().product::<usize>().max(1);
            if n != size {
                bail!("param {name}: shape {shape:?} product {n} != size {size}");
            }
            if offset + size > blob.len() {
                bail!("param {name}: range {offset}..{} out of blob", offset + size);
            }
            literals.push(lit_f32(&blob[offset..offset + size], &shape)?);
            entries.push(WeightEntry { name, shape, offset, size });
        }

        // Contiguity check: params must tile the blob exactly.
        let covered: usize = entries.iter().map(|e| e.size).sum();
        if covered != n_elems {
            bail!("params cover {covered} of {n_elems} blob elements");
        }

        Ok(Weights { model: spec.name.clone(), entries, literals, n_elems })
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    #[test]
    fn loads_trained_weights_when_built() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for spec in &m.models {
            let w = Weights::load(&dir, spec).unwrap();
            assert_eq!(w.len(), w.entries.len());
            assert!(!w.is_empty());
            // first param is the embedding table [V, d]
            assert_eq!(w.entries[0].name, "embed");
            assert_eq!(w.entries[0].shape, vec![spec.vocab, spec.d_model]);
            // total element count matches the model's advertised size
            assert_eq!(w.n_elems as u64, spec.n_params);
        }
    }
}
