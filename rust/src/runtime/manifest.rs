//! `artifacts/manifest.json` — the single source of truth emitted by
//! `python/compile/aot.py`: vocabulary ids, model dimensions, entry-point
//! registry, strategy metadata and suite files. Rust hard-codes none of
//! these; any L1/L2 change flows through here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Vocab {
    pub size: usize,
    pub pad: i32,
    pub bos: i32,
    pub q: i32,
    pub sep: i32,
    pub step: i32,
    pub fin: i32,
    pub eos: i32,
    pub digit0: i32,
    pub plus: i32,
    pub minus: i32,
    pub mul: i32,
    pub lparen: i32,
    pub rparen: i32,
    pub eq: i32,
    pub modulo: i32,
    pub strat0: i32,
    pub num_strategies: usize,
    pub names: BTreeMap<i32, String>,
}

impl Vocab {
    fn parse(v: &Value) -> Result<Self> {
        let names = v
            .get("names")?
            .obj()?
            .iter()
            .map(|(k, val)| Ok((k.parse::<i32>()?, val.str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Vocab {
            size: v.get_usize("size")?,
            pad: v.get_i64("pad")? as i32,
            bos: v.get_i64("bos")? as i32,
            q: v.get_i64("q")? as i32,
            sep: v.get_i64("sep")? as i32,
            step: v.get_i64("step")? as i32,
            fin: v.get_i64("fin")? as i32,
            eos: v.get_i64("eos")? as i32,
            digit0: v.get_i64("digit0")? as i32,
            plus: v.get_i64("plus")? as i32,
            minus: v.get_i64("minus")? as i32,
            mul: v.get_i64("mul")? as i32,
            lparen: v.get_i64("lparen")? as i32,
            rparen: v.get_i64("rparen")? as i32,
            eq: v.get_i64("eq")? as i32,
            modulo: v.get_i64("mod")? as i32,
            strat0: v.get_i64("strat0")? as i32,
            num_strategies: v.get_usize("num_strategies")?,
            names,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub s_max: usize,
    pub n_params: u64,
    pub flops_per_token: u64,
    pub weights_bin: String,
    pub weights_json: String,
}

impl ModelSpec {
    fn parse(v: &Value) -> Result<Self> {
        Ok(ModelSpec {
            name: v.get_str("name")?.to_string(),
            n_layers: v.get_usize("n_layers")?,
            d_model: v.get_usize("d_model")?,
            n_heads: v.get_usize("n_heads")?,
            d_head: v.get_usize("d_head")?,
            vocab: v.get_usize("vocab")?,
            s_max: v.get_usize("s_max")?,
            n_params: v.get_i64("n_params")? as u64,
            flops_per_token: v.get_i64("flops_per_token")? as u64,
            weights_bin: v.get_str("weights_bin")?.to_string(),
            weights_json: v.get_str("weights_json")?.to_string(),
        })
    }

    /// Shape of one KV cache literal: `[L, B, H, S, D]`.
    pub fn cache_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layers, batch, self.n_heads, self.s_max, self.d_head]
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    Prefill,
    Span,
    Ingest,
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub kind: EntryKind,
    pub model: String,
    pub batch: usize,
    pub file: String,
}

impl EntrySpec {
    fn parse(v: &Value) -> Result<Self> {
        let kind = match v.get_str("kind")? {
            "prefill" => EntryKind::Prefill,
            "span" => EntryKind::Span,
            "ingest" => EntryKind::Ingest,
            k => bail!("unknown entry kind `{k}`"),
        };
        Ok(EntrySpec {
            name: v.get_str("name")?.to_string(),
            kind,
            model: v.get_str("model")?.to_string(),
            batch: v.get_usize("batch")?,
            file: v.get_str("file")?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct StrategyMeta {
    pub names: Vec<String>,
    /// strategy index -> decomposition style index
    pub styles: Vec<usize>,
    pub style_names: Vec<String>,
    /// style index -> per-family aptitude in [0,1]
    pub aptitude: Vec<Vec<f64>>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub t_span: usize,
    pub vocab: Vocab,
    pub models: Vec<ModelSpec>,
    pub entries: Vec<EntrySpec>,
    pub prefill_batches: Vec<usize>,
    pub step_batches: Vec<usize>,
    pub alpha: f64,
    pub strategies: StrategyMeta,
    pub families: Vec<String>,
    pub suites: Vec<(String, String)>, // (name, file)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let models = v
            .get("models")?
            .arr()?
            .iter()
            .map(ModelSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let entries = v
            .get("entries")?
            .arr()?
            .iter()
            .map(EntrySpec::parse)
            .collect::<Result<Vec<_>>>()?;

        let strat = v.get("strategies")?;
        let aptitude_obj = strat.get("aptitude")?.obj()?;
        let mut aptitude = vec![Vec::new(); aptitude_obj.len()];
        for (style, row) in aptitude_obj {
            let idx: usize = style.parse()?;
            aptitude[idx] =
                row.arr()?.iter().map(|x| x.f64()).collect::<Result<Vec<_>>>()?;
        }
        let strategies = StrategyMeta {
            names: str_vec(strat.get("names")?)?,
            styles: strat
                .get("styles")?
                .arr()?
                .iter()
                .map(|x| x.usize())
                .collect::<Result<Vec<_>>>()?,
            style_names: str_vec(strat.get("style_names")?)?,
            aptitude,
        };

        let suites = v
            .get("suites")?
            .arr()?
            .iter()
            .map(|s| Ok((s.get_str("name")?.to_string(), s.get_str("file")?.to_string())))
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            t_span: v.get_usize("t_span")?,
            vocab: Vocab::parse(v.get("vocab")?)?,
            models,
            entries,
            prefill_batches: usize_vec(v.get("prefill_batches")?)?,
            step_batches: usize_vec(v.get("step_batches")?)?,
            alpha: v.get_f64("alpha")?,
            strategies,
            families: str_vec(v.get("families")?)?,
            suites,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model `{name}` not in manifest"))
    }

    /// Entry-point name for (kind, model, batch); the variant must exist.
    pub fn entry(&self, kind: EntryKind, model: &str, batch: usize) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.model == model && e.batch == batch)
            .with_context(|| format!("no entry {kind:?}/{model}/b{batch} in manifest"))
    }

    /// Smallest compiled batch variant that fits `n` paths.
    pub fn fit_batch(&self, kind: EntryKind, n: usize) -> Result<usize> {
        let list = match kind {
            EntryKind::Prefill => &self.prefill_batches,
            _ => &self.step_batches,
        };
        list.iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .or_else(|| list.iter().copied().max())
            .with_context(|| format!("no batch variants for {kind:?}"))
    }
}

fn str_vec(v: &Value) -> Result<Vec<String>> {
    v.arr()?.iter().map(|x| Ok(x.str()?.to_string())).collect()
}

fn usize_vec(v: &Value) -> Result<Vec<usize>> {
    v.arr()?.iter().map(|x| x.usize()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 2);
        assert!(m.alpha > 0.0 && m.alpha < 1.0);
        assert!(m.vocab.num_strategies >= 12);
        let t = m.model("target").unwrap();
        assert_eq!(t.d_model % t.n_heads, 0);
        // every entry's file exists
        for e in &m.entries {
            assert!(dir.join(&e.file).exists(), "{} missing", e.file);
        }
        // batch fitting picks the smallest variant that fits
        let b = m.fit_batch(EntryKind::Span, 3).unwrap();
        assert!(b >= 3);
        assert!(m.step_batches.contains(&b));
    }

    #[test]
    fn fit_batch_clamps_to_largest() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let max = *m.step_batches.iter().max().unwrap();
        assert_eq!(m.fit_batch(EntryKind::Span, 999).unwrap(), max);
    }
}
