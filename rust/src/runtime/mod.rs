//! Runtime layer: PJRT client + artifact/weight loading.
//!
//! `python/compile/aot.py` lowers the L2 models (with their L1 Pallas
//! kernels) to HLO text under `artifacts/`; this module loads, compiles
//! and executes them. Python is never on the request path.

pub mod client;
pub mod literals;
pub mod manifest;
pub mod weights;

pub use client::{Runtime, RuntimeStats};
pub use manifest::{EntryKind, EntrySpec, Manifest, ModelSpec, Vocab};
pub use weights::Weights;
