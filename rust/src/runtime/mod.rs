//! Runtime layer: PJRT client + artifact/weight loading.
//!
//! `python/compile/aot.py` lowers the L2 models (with their L1 Pallas
//! kernels) to HLO text under `artifacts/`; this module loads, compiles
//! and executes them. Python is never on the request path.

// The PJRT execution layer needs the `xla` crate, which the default
// (calibrated-only) build does not link; `manifest` is dependency-free
// and always available (suites, vocab, strategy metadata).
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod literals;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod weights;

#[cfg(feature = "pjrt")]
pub use client::{Runtime, RuntimeStats};
pub use manifest::{EntryKind, EntrySpec, Manifest, ModelSpec, Vocab};
#[cfg(feature = "pjrt")]
pub use weights::Weights;
