//! PJRT runtime: loads HLO-text artifacts, compiles them once, executes
//! them from the serving hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits serialized protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see DESIGN.md §2).
//!
//! Executables are compiled lazily and cached by entry-point name — the
//! manifest registers ~20 (entry x batch) variants and a typical run
//! touches a handful.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Cumulative runtime counters (read by metrics / EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    /// host->device + device->host literal traffic, bytes
    pub transfer_bytes: u64,
}

/// Owns the PJRT client and the executable cache. Not `Send` (PJRT
/// wrapper types are raw pointers) — the coordinator runs all model
/// execution on one dedicated thread, which also matches the single-core
/// testbed.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    exes: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch cached) the artifact `<name>.hlo.txt`.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += dt;
        }
        log::debug!("compiled {name} in {dt:.2}s");
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry point with literal inputs; returns the untupled
    /// output literals. (xla_extension's default ExecuteOptions returns
    /// one tuple buffer — we decompose on host; see DESIGN.md §9.)
    pub fn execute(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        self.ensure_compiled(name)?;
        let in_bytes: usize = args.iter().map(|l| l.size_bytes()).sum();
        let t0 = Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("ensured above");
        // &Literal: Borrow<Literal> — no deep copies on the hot path
        // (weights alone are several MB per call).
        let mut outs = exe.execute(args).with_context(|| format!("executing {name}"))?;
        let buffer = outs
            .pop()
            .and_then(|mut replica| replica.pop())
            .context("no output buffer")?;
        let mut tuple = buffer.to_literal_sync().context("fetching output literal")?;
        let parts = tuple.decompose_tuple().context("decomposing output tuple")?;
        let out_bytes: usize = parts.iter().map(|l| l.size_bytes()).sum();
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += dt;
            s.transfer_bytes += (in_bytes + out_bytes) as u64;
        }
        Ok(parts)
    }

    /// Compile an artifact ahead of first use (serving warmup).
    pub fn precompile(&self, name: &str) -> Result<()> {
        self.ensure_compiled(name)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literals::{lit_f32, to_vec_f32};

    /// End-to-end PJRT sanity without artifacts: build a computation with
    /// the XlaBuilder and run it through the same client.
    #[test]
    fn pjrt_builder_roundtrip() {
        let client = PjRtClient::cpu().unwrap();
        let b = xla::XlaBuilder::new("t");
        let p = b.parameter_s(0, &xla::Shape::array::<f32>(vec![2]), "p").unwrap();
        let comp = (p.clone() + p).unwrap().build().unwrap();
        let exe = client.compile(&comp).unwrap();
        let x = lit_f32(&[1.5, 2.5], &[2]).unwrap();
        let out = exe.execute::<Literal>(&[x]).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(to_vec_f32(&out).unwrap(), vec![3.0, 5.0]);
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let rt = Runtime::new(Path::new("/nonexistent-artifacts")).unwrap();
        let err = match rt.execute("nope", &[]) {
            Ok(_) => panic!("expected error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("nope"), "{err}");
    }
}
