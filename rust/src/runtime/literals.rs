//! Conversions between rust buffers and XLA [`Literal`]s.
//!
//! The HLO entry points exchange f32/i32 tensors; these helpers keep the
//! unsafe-ish byte plumbing (`create_from_shape_and_untyped_data`) in one
//! audited place.

use anyhow::{bail, Context, Result};
use xla::{ArrayElement, ElementType, Literal, PrimitiveType};

/// Build an f32 literal with the given dims from a host slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements for dims {dims:?} (need {n})", data.len());
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .context("create f32 literal")
}

/// Build an i32 literal with the given dims from a host slice.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements for dims {dims:?} (need {n})", data.len());
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .context("create i32 literal")
}

pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Extract a typed host vector, converting the element type if needed
/// (jax emits S32 for `done` flags but U8/PRED for raw bools).
pub fn to_vec<T: ArrayElement>(lit: &Literal) -> Result<Vec<T>> {
    match lit.to_vec::<T>() {
        Ok(v) => Ok(v),
        Err(_) => {
            let conv = lit.convert(T::TY.primitive_type()).context("convert literal")?;
            conv.to_vec::<T>().context("to_vec after convert")
        }
    }
}

pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    to_vec::<f32>(lit)
}

pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
    to_vec::<i32>(lit)
}

/// Dims of an array literal.
pub fn dims(lit: &Literal) -> Result<Vec<usize>> {
    Ok(lit.array_shape()?.dims().iter().map(|&d| d as usize).collect())
}

/// True if the literal is an f32 array with the expected dims.
pub fn expect_f32(lit: &Literal, expect: &[usize]) -> Result<()> {
    let d = dims(lit)?;
    if d != expect {
        bail!("shape mismatch: got {d:?}, want {expect:?}");
    }
    if lit.primitive_type()? != PrimitiveType::F32 {
        bail!("dtype mismatch: got {:?}, want F32", lit.primitive_type()?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(dims(&lit).unwrap(), vec![2, 3]);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        expect_f32(&lit, &[2, 3]).unwrap();
        assert!(expect_f32(&lit, &[3, 2]).is_err());
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![7i32, -1, 0];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(to_vec_i32(&lit).unwrap(), data);
    }

    #[test]
    fn wrong_element_count_rejected() {
        assert!(lit_f32(&[1.0], &[2, 2]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2]).is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(scalar_f32(2.5).get_first_element::<f32>().unwrap(), 2.5);
        assert_eq!(scalar_i32(-3).get_first_element::<i32>().unwrap(), -3);
    }

    #[test]
    fn convert_path_i32_to_f32() {
        let lit = lit_i32(&[1, 2], &[2]).unwrap();
        let v = to_vec_f32(&lit).unwrap();
        assert_eq!(v, vec![1.0, 2.0]);
    }
}
