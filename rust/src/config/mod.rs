//! Layered configuration: built-in defaults -> optional JSON config file
//! (`--config path.json`) -> CLI overrides. All knobs of the SSR engine
//! and server live here so experiments are reproducible from a single
//! artifact.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::cli::Args;
use crate::util::json::Value;

/// How the Selective Parallel Module picks strategies (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// top-n of the target model's strategy distribution (paper default)
    ModelTopN,
    /// sample n distinct strategies from that distribution
    ModelSample,
    /// uniform-random n strategies (ablation)
    Random,
    /// ground-truth aptitude ranking (upper bound for the ablation)
    Oracle,
}

impl Selection {
    pub fn parse(s: &str) -> Result<Selection> {
        Ok(match s {
            "model-top" | "model" => Selection::ModelTopN,
            "model-sample" => Selection::ModelSample,
            "random" => Selection::Random,
            "oracle" => Selection::Oracle,
            _ => bail!("unknown selection mode `{s}`"),
        })
    }
}

/// Early-exit modes (paper §3.2 "Fast Modes").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopRule {
    /// run every path to completion (full SSR)
    Full,
    /// stop all paths once any one finishes with an answer
    Fast1,
    /// stop once two paths agree on an answer
    Fast2,
}

impl StopRule {
    pub fn parse(s: &str) -> Result<StopRule> {
        Ok(match s {
            "full" => StopRule::Full,
            "fast1" | "fast-1" => StopRule::Fast1,
            "fast2" | "fast-2" => StopRule::Fast2,
            _ => bail!("unknown stop rule `{s}`"),
        })
    }
}

/// How the cross-request scheduler orders the admission queue
/// (`coordinator::scheduler`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// strict arrival order — no starvation, the default
    Fifo,
    /// admit the job needing the fewest lanes first — maximizes batch
    /// occupancy under mixed loads, but can starve wide requests
    SmallestFirst,
}

impl AdmitPolicy {
    pub fn parse(s: &str) -> Result<AdmitPolicy> {
        Ok(match s {
            "fifo" => AdmitPolicy::Fifo,
            "smallest" | "smallest-first" => AdmitPolicy::SmallestFirst,
            _ => bail!("unknown admission policy `{s}` (fifo|smallest-first)"),
        })
    }
}

/// How the pool routes a request to a backend shard
/// (`coordinator::pool`, DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacePolicy {
    /// argmin over outstanding-lane gauges — balances mixed loads
    LeastLoaded,
    /// hash(expr) mod shards — repeats of a prompt land on the shard
    /// holding its prefilled prefix (max tier hits, skew-sensitive)
    Affinity,
    /// strict rotation (load-blind baseline)
    RoundRobin,
}

impl PlacePolicy {
    pub fn parse(s: &str) -> Result<PlacePolicy> {
        Ok(match s {
            "least-loaded" | "least" => PlacePolicy::LeastLoaded,
            "affinity" => PlacePolicy::Affinity,
            "round-robin" | "rr" => PlacePolicy::RoundRobin,
            _ => bail!("unknown placement policy `{s}` (least-loaded|affinity|round-robin)"),
        })
    }
}

/// Wire transport the serving front end speaks (PROTOCOL.md). Both
/// carry the same JSON payloads; only the framing differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// newline-delimited JSON — the legacy compat mode (and default
    /// for one release): one request per line, legacy error shapes
    #[default]
    Jsonl,
    /// 4-byte big-endian length prefix + JSON payload: multiplexing,
    /// streaming, and the structured error envelope
    Framed,
}

impl Transport {
    pub fn parse(s: &str) -> Result<Transport> {
        Ok(match s {
            "jsonl" | "json-lines" => Transport::Jsonl,
            "framed" => Transport::Framed,
            _ => bail!("unknown transport `{s}` (framed|jsonl)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Transport::Jsonl => "jsonl",
            Transport::Framed => "framed",
        }
    }
}

/// Per-run speculation-depth policy (DESIGN.md §15). Depth is how many
/// draft/score micro-cycles a lane may run between engine barriers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecDepth {
    /// burst exactly k cycles per tick; `fixed:1` is the legacy
    /// lockstep draft/score/rewrite tick and the default. Any k is
    /// decision-identical to k=1 (bursts replay the exact per-lane op
    /// order, and fast-stop runs always tick at depth 1 so their early
    /// stop keeps per-step granularity) — only the clock model differs
    Fixed(usize),
    /// bounded per-run controller in the engine: widens depth while the
    /// run's gamma EWMA stays high, narrows as it drops, and falls back
    /// to target-only generation once gamma collapses below break-even
    Adaptive {
        /// hard ceiling on controller depth
        max: usize,
    },
}

impl SpecDepth {
    pub fn parse(s: &str) -> Result<SpecDepth> {
        if s == "adaptive" {
            return Ok(SpecDepth::Adaptive { max: 8 });
        }
        if let Some(rest) = s.strip_prefix("adaptive:") {
            let max: usize =
                rest.parse().map_err(|_| anyhow::anyhow!("bad adaptive depth `{s}`"))?;
            return Ok(SpecDepth::Adaptive { max });
        }
        if let Some(rest) = s.strip_prefix("fixed:") {
            let k: usize =
                rest.parse().map_err(|_| anyhow::anyhow!("bad fixed depth `{s}`"))?;
            return Ok(SpecDepth::Fixed(k));
        }
        bail!("unknown spec depth `{s}` (fixed:<k>|adaptive|adaptive:<max>)")
    }

    /// Canonical display form (round-trips through `parse`).
    pub fn label(&self) -> String {
        match self {
            SpecDepth::Fixed(k) => format!("fixed:{k}"),
            SpecDepth::Adaptive { max } => format!("adaptive:{max}"),
        }
    }
}

impl Default for SpecDepth {
    fn default() -> Self {
        SpecDepth::Fixed(1)
    }
}

/// Heterogeneous shard classes (DESIGN.md §15): cost/capacity profiles
/// only — a class never changes decision streams, so placement stays
/// equivalence-safe. `draft_heavy` shards run drafts cheap and wide,
/// `target_heavy` shards run target passes cheap; `balanced` is the
/// uniform legacy profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ShardClass {
    DraftHeavy,
    Balanced,
    TargetHeavy,
}

impl ShardClass {
    pub fn parse(s: &str) -> Result<ShardClass> {
        Ok(match s {
            "draft_heavy" | "draft-heavy" | "draft" => ShardClass::DraftHeavy,
            "balanced" => ShardClass::Balanced,
            "target_heavy" | "target-heavy" | "target" => ShardClass::TargetHeavy,
            _ => bail!("unknown shard class `{s}` (draft_heavy|balanced|target_heavy)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardClass::DraftHeavy => "draft_heavy",
            ShardClass::Balanced => "balanced",
            ShardClass::TargetHeavy => "target_heavy",
        }
    }

    /// Virtual-clock cost multipliers `(draft, target)` applied to a
    /// shard's backend at spawn. Clock-only: decisions are unaffected.
    pub fn cost_profile(&self) -> (f64, f64) {
        match self {
            ShardClass::DraftHeavy => (0.5, 1.3),
            ShardClass::Balanced => (1.0, 1.0),
            ShardClass::TargetHeavy => (1.6, 0.7),
        }
    }

    /// Lane-capacity multiplier over `max_lanes` for this class —
    /// draft-heavy shards trade per-lane target speed for width.
    pub fn lane_factor(&self) -> usize {
        match self {
            ShardClass::DraftHeavy => 2,
            ShardClass::Balanced | ShardClass::TargetHeavy => 1,
        }
    }

    /// Whether this class can serve target-dominated work at sane cost;
    /// the pool never drains its last healthy target-capable shard.
    pub fn target_capable(&self) -> bool {
        !matches!(self, ShardClass::DraftHeavy)
    }

    /// Parse a comma-separated class pattern (`--shard-classes`).
    pub fn parse_list(s: &str) -> Result<Vec<ShardClass>> {
        s.split(',').map(|p| ShardClass::parse(p.trim())).collect()
    }
}

/// Eviction policy of the shared prefix tier (`--prefix-evict`,
/// DESIGN.md §17). Cost/clock-only: the policy changes which prompts
/// stay cached, never any run's decision stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictPolicy {
    /// least-recently-used logical entry goes first (the historical
    /// behaviour and the default)
    #[default]
    Lru,
    /// minimum retention value goes first: prompt-prefill recompute
    /// cost (`flops.rs` closed form) scaled by the entry's observed
    /// refork frequency, recency as the tie-break
    Cost,
}

impl EvictPolicy {
    pub fn parse(s: &str) -> Result<EvictPolicy> {
        Ok(match s {
            "lru" => EvictPolicy::Lru,
            "cost" => EvictPolicy::Cost,
            _ => bail!("unknown eviction policy `{s}` (lru|cost)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::Cost => "cost",
        }
    }
}

/// Shared-prefix prefill & prefix-reuse cache knobs (DESIGN.md §2, §10,
/// §17).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixCacheCfg {
    /// open lane groups by prefilling the problem prompt once and
    /// forking lanes from it (off = legacy per-lane prefill, kept for
    /// ablation and equivalence testing)
    pub enabled: bool,
    /// max prefilled prompts kept alive across requests (0 = no
    /// cross-request cache; within-request sharing still applies)
    pub capacity: usize,
    /// byte budget over retained prefix state (`Backend::prefix_bytes`,
    /// summed across shards in the shared tier; 0 = entry cap only)
    pub max_bytes: u64,
    /// hot-tier eviction policy (`--prefix-evict lru|cost`)
    pub evict: EvictPolicy,
    /// persistent spill tier directory (`--prefix-spill-dir`): evicted
    /// and drained entries are demoted here and promoted back on miss;
    /// survives restarts. None = evict-and-forget (the default). Must
    /// be an absolute path (validated up front)
    pub spill_dir: Option<PathBuf>,
    /// live-payload byte budget of the spill tier
    /// (`--prefix-spill-bytes`; 0 = unbounded)
    pub spill_bytes: u64,
}

impl Default for PrefixCacheCfg {
    fn default() -> Self {
        // 1 GiB default budget: irrelevant for the calibrated substrate
        // (entries are ~100 bytes) but caps PJRT prompt K/V retention
        PrefixCacheCfg {
            enabled: true,
            capacity: 256,
            max_bytes: 1 << 30,
            evict: EvictPolicy::Lru,
            spill_dir: None,
            spill_bytes: 0,
        }
    }
}

impl PrefixCacheCfg {
    fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.obj()? {
            match k.as_str() {
                "enabled" => self.enabled = val.bool()?,
                "capacity" => self.capacity = val.usize()?,
                "max_bytes" => {
                    let b = val.i64()?;
                    if b < 0 {
                        bail!("prefix_cache.max_bytes must be >= 0, got {b}");
                    }
                    self.max_bytes = b as u64;
                }
                "evict" => self.evict = EvictPolicy::parse(val.str()?)?,
                "spill_dir" => self.spill_dir = Some(PathBuf::from(val.str()?)),
                "spill_bytes" => {
                    let b = val.i64()?;
                    if b < 0 {
                        bail!("prefix_cache.spill_bytes must be >= 0, got {b}");
                    }
                    self.spill_bytes = b as u64;
                }
                other => bail!("unknown prefix_cache key `{other}`"),
            }
        }
        Ok(())
    }
}

/// Queue-driven autoscaler knobs (`coordinator::autoscaler`,
/// DESIGN.md §12). The policy samples queue depth and head-of-line
/// admission wait into EWMAs and calls `add_shard` / `remove_shard`
/// within `[min_shards, max_shards]`, with hysteresis (consecutive
/// breaches required) and a cooldown between applied events so a
/// bursty load cannot make the pool flap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleCfg {
    /// run the policy loop (off = manual add_shard/remove_shard only)
    pub enabled: bool,
    /// hard ceiling on live shards the policy may reach
    pub max_shards: usize,
    /// scale up when the head-of-line admission-wait EWMA exceeds this
    pub scale_up_wait_s: f64,
    /// ...or when the queued-jobs-per-live-shard EWMA exceeds this
    pub scale_up_queue: f64,
    /// scale down when the lane-occupancy EWMA (outstanding lanes /
    /// (shards x max_lanes)) stays below this fraction with empty queues
    pub scale_down_occupancy: f64,
    /// policy evaluation period
    pub interval_ms: u64,
    /// minimum gap between applied scale events
    pub cooldown_ms: u64,
    /// consecutive breached evaluations required before acting
    pub hysteresis: u32,
}

impl Default for AutoscaleCfg {
    fn default() -> Self {
        AutoscaleCfg {
            enabled: false,
            max_shards: 8,
            scale_up_wait_s: 0.25,
            scale_up_queue: 2.0,
            scale_down_occupancy: 0.25,
            interval_ms: 50,
            cooldown_ms: 500,
            hysteresis: 3,
        }
    }
}

impl AutoscaleCfg {
    fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.obj()? {
            match k.as_str() {
                "enabled" => self.enabled = val.bool()?,
                "max_shards" => self.max_shards = val.usize()?,
                "scale_up_wait_s" => self.scale_up_wait_s = val.f64()?,
                "scale_up_queue" => self.scale_up_queue = val.f64()?,
                "scale_down_occupancy" => self.scale_down_occupancy = val.f64()?,
                "interval_ms" => self.interval_ms = val.i64()? as u64,
                "cooldown_ms" => self.cooldown_ms = val.i64()? as u64,
                "hysteresis" => self.hysteresis = val.i64()? as u32,
                other => bail!("unknown autoscale key `{other}`"),
            }
        }
        Ok(())
    }
}

/// Deterministic fault-injection schedule (`backend::faulty`,
/// DESIGN.md §13). All rates are per *step call* probabilities drawn
/// from a splitmix64 stream seeded by `seed` (mixed with the shard id),
/// and every injected fault consumes one unit of a pool-wide budget
/// (`max_faults`), so chaos schedules are reproducible down to the
/// individual call. Inactive (all-zero) by default; enable via the
/// `fault` config block or the `--fault-spec '<json>'` flag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// seed of the injection schedule stream
    pub seed: u64,
    /// probability a step call raises a retryable transient error
    pub transient_rate: f64,
    /// probability a step call raises a lane-fatal error (the affected
    /// runs fail with a structured reply; the shard survives)
    pub lane_fatal_rate: f64,
    /// probability a step call panics the shard thread (exercises
    /// supervision, respawn, and run re-admission)
    pub panic_rate: f64,
    /// probability a step call stalls for `stall_ms` (deadline drills)
    pub stall_rate: f64,
    /// stall duration in milliseconds
    pub stall_ms: u64,
    /// panic on the first step call after an `import_lane_state` —
    /// targets the crash-during-migration / crash-during-recovery window
    pub resume_panic: bool,
    /// pool-wide cap on injected faults (shared across shards and
    /// respawns); `u64::MAX` = unbounded
    pub max_faults: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 0,
            transient_rate: 0.0,
            lane_fatal_rate: 0.0,
            panic_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 0,
            resume_panic: false,
            max_faults: u64::MAX,
        }
    }
}

impl FaultSpec {
    /// Whether any fault can ever fire — gates the `FaultInjector` wrap.
    pub fn is_active(&self) -> bool {
        self.transient_rate > 0.0
            || self.lane_fatal_rate > 0.0
            || self.panic_rate > 0.0
            || self.stall_rate > 0.0
            || self.resume_panic
    }

    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.obj()? {
            match k.as_str() {
                "seed" => self.seed = val.i64()? as u64,
                "transient_rate" => self.transient_rate = val.f64()?,
                "lane_fatal_rate" => self.lane_fatal_rate = val.f64()?,
                "panic_rate" => self.panic_rate = val.f64()?,
                "stall_rate" => self.stall_rate = val.f64()?,
                "stall_ms" => self.stall_ms = val.i64()? as u64,
                "resume_panic" => self.resume_panic = val.bool()?,
                "max_faults" => self.max_faults = val.i64()? as u64,
                other => bail!("unknown fault key `{other}`"),
            }
        }
        Ok(())
    }
}

/// Per-tenant token-bucket override (`qos.tenants` / `--tenants '<json>'`).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantOverride {
    pub name: String,
    /// sustained admits per second (0 = unlimited for this tenant)
    pub rate: f64,
    /// bucket capacity (max burst admitted at once)
    pub burst: f64,
}

/// Overload-protection and QoS knobs (`coordinator::admission`,
/// DESIGN.md §14). Requests carry optional `tenant` and `class` wire
/// fields; admission gates intake with per-tenant token buckets,
/// per-class bounded queues with weighted dequeue, fair-share lane
/// quotas, and SLO-driven shedding of low-priority classes. Rejected
/// requests get a structured `overloaded` reply with `retry_after_ms`
/// — in-flight work is never dropped, only new intake is shed.
#[derive(Debug, Clone, PartialEq)]
pub struct QosCfg {
    /// master switch: off = legacy unbounded intake (every request
    /// admitted; class still recorded for metrics)
    pub enabled: bool,
    /// default per-tenant sustained admit rate in requests/second
    /// (0 = no rate limit)
    pub tenant_rate: f64,
    /// default per-tenant bucket capacity (burst size)
    pub tenant_burst: f64,
    /// per-tenant overrides of (rate, burst)
    pub tenants: Vec<TenantOverride>,
    /// per-class bound on requests in the system (queued + in flight);
    /// a full class rejects new intake with `retry_after_ms`
    /// (0 = unbounded)
    pub queue_cap: usize,
    /// weighted-round-robin dequeue credits for
    /// [interactive, batch, best_effort] — each class is guaranteed
    /// weight/total of admissions while its queue is non-empty, so
    /// neither batch nor interactive can starve the other
    pub weights: [u64; 3],
    /// interactive p99 latency SLO in milliseconds: when breached,
    /// best_effort intake is shed first (batch past 2x); also a
    /// scale-up pressure signal for the autoscaler (0 = off)
    pub slo_ms: u64,
    /// max cumulative shard-seconds (`model_secs`) the autoscaler may
    /// spend before scale-ups are vetoed (0 = unlimited)
    pub cost_ceiling_s: f64,
    /// fair-share lane quota: one tenant may hold at most this
    /// fraction of total lane capacity (shards x max_lanes) in flight
    pub lane_share: f64,
    /// cardinality bound on tracked tenants (token buckets + gauges);
    /// beyond it, the least-recently-used idle bucket is recycled
    pub max_tenants: usize,
}

impl Default for QosCfg {
    fn default() -> Self {
        QosCfg {
            enabled: true,
            tenant_rate: 0.0,
            tenant_burst: 16.0,
            tenants: Vec::new(),
            queue_cap: 256,
            weights: [4, 2, 1],
            slo_ms: 0,
            cost_ceiling_s: 0.0,
            lane_share: 0.5,
            max_tenants: 256,
        }
    }
}

impl QosCfg {
    /// Effective (rate, burst) for a tenant name.
    pub fn bucket_for(&self, tenant: &str) -> (f64, f64) {
        for t in &self.tenants {
            if t.name == tenant {
                return (t.rate, t.burst);
            }
        }
        (self.tenant_rate, self.tenant_burst)
    }

    fn parse_tenants(&mut self, v: &Value) -> Result<()> {
        self.tenants.clear();
        for (name, spec) in v.obj()? {
            let mut rate = self.tenant_rate;
            let mut burst = self.tenant_burst;
            for (k, val) in spec.obj()? {
                match k.as_str() {
                    "rate" => rate = val.f64()?,
                    "burst" => burst = val.f64()?,
                    other => bail!("unknown tenant override key `{other}`"),
                }
            }
            self.tenants.push(TenantOverride { name: name.clone(), rate, burst });
        }
        Ok(())
    }

    fn parse_weights(&mut self, s: &str) -> Result<()> {
        let parts: Vec<&str> = s.split(',').collect();
        if parts.len() != 3 {
            bail!("class weights must be `interactive,batch,best_effort`, got `{s}`");
        }
        for (i, p) in parts.iter().enumerate() {
            self.weights[i] = p
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad class weight `{p}` in `{s}`"))?;
        }
        Ok(())
    }

    fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.obj()? {
            match k.as_str() {
                "enabled" => self.enabled = val.bool()?,
                "tenant_rate" => self.tenant_rate = val.f64()?,
                "tenant_burst" => self.tenant_burst = val.f64()?,
                "tenants" => self.parse_tenants(val)?,
                "queue_cap" => self.queue_cap = val.usize()?,
                "weights" => {
                    let a = val.arr()?;
                    if a.len() != 3 {
                        bail!("qos.weights must have 3 entries, got {}", a.len());
                    }
                    for (i, x) in a.iter().enumerate() {
                        self.weights[i] = x.i64()? as u64;
                    }
                }
                "slo_ms" => self.slo_ms = val.i64()? as u64,
                "cost_ceiling_s" => self.cost_ceiling_s = val.f64()?,
                "lane_share" => self.lane_share = val.f64()?,
                "max_tenants" => self.max_tenants = val.usize()?,
                other => bail!("unknown qos key `{other}`"),
            }
        }
        Ok(())
    }
}

/// Path-style flags are rejected up front unless non-empty and
/// absolute — a relative spill dir or trace path would silently depend
/// on the server's CWD and surface as a confusing I/O error at first
/// use instead of at startup.
fn validate_path_flag(name: &str, p: &Path) -> Result<()> {
    if p.as_os_str().is_empty() {
        bail!("{name} must not be empty");
    }
    if !p.is_absolute() {
        bail!("{name} must be an absolute path, got `{}`", p.display());
    }
    Ok(())
}

fn parse_bool(s: &str) -> Result<bool> {
    Ok(match s {
        "on" | "true" | "1" | "yes" => true,
        "off" | "false" | "0" | "no" => false,
        _ => bail!("expected on|off, got `{s}`"),
    })
}

#[derive(Debug, Clone)]
pub struct SsrConfig {
    pub artifacts_dir: PathBuf,
    /// n — selected parallel paths (paper: 3 or 5)
    pub n_paths: usize,
    /// K — strategy pool size
    pub pool_size: usize,
    /// rewrite threshold tau in 0..=9 (paper: 7)
    pub tau: u8,
    /// sampling temperature for step generation
    pub temp: f32,
    /// max reasoning steps per path before force-finish
    pub max_steps: usize,
    pub stop_rule: StopRule,
    pub selection: Selection,
    pub seed: u64,
    /// scheduler lane pool: max reasoning paths in flight across all
    /// concurrent problems OF ONE SHARD (total capacity = shards x
    /// max_lanes)
    pub max_lanes: usize,
    /// admission-queue ordering of each shard's scheduler
    pub admission: AdmitPolicy,
    /// backend shards: scheduler threads each owning one backend
    /// (`coordinator::pool`); throughput scales with this. The pool is
    /// elastic at runtime (`PoolHandle::add_shard` / `remove_shard`);
    /// this is the spawn-time count
    pub shards: usize,
    /// how requests are routed to shards
    pub placement: PlacePolicy,
    /// cross-shard work stealing: a shard whose occupancy stays below
    /// this many lanes for a full tick (and whose own queue is empty)
    /// pulls queued-but-unstarted requests from the most-loaded shard.
    /// 0 disables stealing (the default — placement-only routing)
    pub steal_threshold: usize,
    /// `remove_shard` refuses to drain the pool below this many live
    /// shards
    pub min_shards: usize,
    /// live run migration: a draining shard detaches its in-flight runs
    /// at the next step boundary and re-homes them on the survivors
    /// (drain = O(one step)), and loaded shards shed whole runs to
    /// idle thieves' shed requests. Off = PR-4 semantics (drains wait
    /// out their in-flight solves; stealing moves queued jobs only)
    pub migration: bool,
    /// per-run speculation-depth policy: `fixed:1` (legacy lockstep,
    /// default), `fixed:<k>` bursts, or `adaptive[:<max>]` — the
    /// engine's gamma-EWMA controller (DESIGN.md §15)
    pub spec_depth: SpecDepth,
    /// heterogeneous shard-class pattern, assigned cyclically by shard
    /// id (`class = pattern[id % len]`, hot-added shards included).
    /// Empty = every shard `balanced` (the legacy uniform pool)
    pub shard_classes: Vec<ShardClass>,
    /// queue-driven autoscaler policy (off by default)
    pub autoscale: AutoscaleCfg,
    /// shared-prefix prefill + cross-request prefix cache / shared tier
    pub prefix: PrefixCacheCfg,
    /// default per-request deadline in milliseconds, enforced at step
    /// boundaries; on expiry the run finalizes from the votes collected
    /// so far and replies `degraded:true`. 0 = no deadline. Overridable
    /// per request via the `deadline_ms` wire field (DESIGN.md §13)
    pub deadline_ms: u64,
    /// per-run crash-recovery retry budget: how many times a run lost
    /// to a shard crash is re-admitted before it is quarantined and
    /// failed with a structured reply (DESIGN.md §13)
    pub recover_retries: u32,
    /// LRU bound on the poison-run quarantine list — an adversarial
    /// client replaying unique poison (expr, seed) pairs cannot grow
    /// coordinator memory unboundedly; evictions are counted in stats
    pub quarantine_cap: usize,
    /// per-connection read/idle timeout in milliseconds: a client that
    /// opens a socket and never completes a line cannot pin a handler
    /// thread forever (0 = no timeout)
    pub conn_idle_timeout_ms: u64,
    /// wire transport the server speaks (`--transport framed|jsonl`,
    /// PROTOCOL.md); jsonl is the compat default for one release
    pub transport: Transport,
    /// per-streamed-solve event ring capacity (`--stream-buffer`): a
    /// consumer more than this many step boundaries behind loses the
    /// oldest events (counted in `stream_drops`), never shard time
    pub stream_buffer: usize,
    /// overload protection: admission control, priority QoS, bounded
    /// backpressure, and graceful shedding (DESIGN.md §14)
    pub qos: QosCfg,
    /// deterministic fault-injection schedule (inactive by default)
    pub fault: FaultSpec,
    /// record every admitted solve to this file (`--trace-record`;
    /// versioned JSONL, `workload::trace`) for later deterministic
    /// replay. None = recording off. Must be an absolute path
    /// (validated up front)
    pub trace_record: Option<PathBuf>,
}

impl Default for SsrConfig {
    fn default() -> Self {
        SsrConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            n_paths: 5,
            pool_size: 12,
            tau: 7,
            temp: 0.7,
            max_steps: 14,
            stop_rule: StopRule::Full,
            selection: Selection::ModelTopN,
            seed: 42,
            max_lanes: 32,
            admission: AdmitPolicy::Fifo,
            shards: 1,
            placement: PlacePolicy::LeastLoaded,
            steal_threshold: 0,
            min_shards: 1,
            migration: true,
            spec_depth: SpecDepth::default(),
            shard_classes: Vec::new(),
            autoscale: AutoscaleCfg::default(),
            prefix: PrefixCacheCfg::default(),
            deadline_ms: 0,
            recover_retries: 2,
            quarantine_cap: 1024,
            conn_idle_timeout_ms: 30_000,
            transport: Transport::default(),
            stream_buffer: 64,
            qos: QosCfg::default(),
            fault: FaultSpec::default(),
            trace_record: None,
        }
    }
}

impl SsrConfig {
    /// Apply a JSON config object (unknown keys rejected).
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.obj()? {
            match k.as_str() {
                "artifacts_dir" => self.artifacts_dir = PathBuf::from(val.str()?),
                "n_paths" => self.n_paths = val.usize()?,
                "pool_size" => self.pool_size = val.usize()?,
                "tau" => self.tau = val.i64()? as u8,
                "temp" => self.temp = val.f64()? as f32,
                "max_steps" => self.max_steps = val.usize()?,
                "stop_rule" => self.stop_rule = StopRule::parse(val.str()?)?,
                "selection" => self.selection = Selection::parse(val.str()?)?,
                "seed" => self.seed = val.i64()? as u64,
                "max_lanes" => self.max_lanes = val.usize()?,
                "admission" => self.admission = AdmitPolicy::parse(val.str()?)?,
                "shards" => self.shards = val.usize()?,
                "placement" => self.placement = PlacePolicy::parse(val.str()?)?,
                "steal_threshold" => self.steal_threshold = val.usize()?,
                "min_shards" => self.min_shards = val.usize()?,
                "migration" => self.migration = val.bool()?,
                "spec_depth" => self.spec_depth = SpecDepth::parse(val.str()?)?,
                "shard_classes" => {
                    self.shard_classes = val
                        .arr()?
                        .iter()
                        .map(|x| ShardClass::parse(x.str()?))
                        .collect::<Result<Vec<_>>>()?;
                }
                "autoscale" => self.autoscale.apply_json(val)?,
                "prefix_cache" => self.prefix.apply_json(val)?,
                "deadline_ms" => self.deadline_ms = val.i64()? as u64,
                "recover_retries" => self.recover_retries = val.i64()? as u32,
                "quarantine_cap" => self.quarantine_cap = val.usize()?,
                "conn_idle_timeout_ms" => self.conn_idle_timeout_ms = val.i64()? as u64,
                "transport" => self.transport = Transport::parse(val.str()?)?,
                "stream_buffer" => self.stream_buffer = val.usize()?,
                "qos" => self.qos.apply_json(val)?,
                "fault" => self.fault.apply_json(val)?,
                "trace_record" => self.trace_record = Some(PathBuf::from(val.str()?)),
                other => bail!("unknown config key `{other}`"),
            }
        }
        self.validate()
    }

    /// Apply CLI overrides (flags shared across subcommands).
    pub fn apply_args(&mut self, args: &mut Args) -> Result<()> {
        if let Some(p) = args.opt("config") {
            let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
            let v = Value::parse(&text)?;
            self.apply_json(&v)?;
        }
        if let Some(d) = args.opt("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.n_paths = args.opt_usize("paths", self.n_paths)?;
        self.tau = args.opt_u64("tau", self.tau as u64)? as u8;
        self.temp = args.opt_f64("temp", self.temp as f64)? as f32;
        self.max_steps = args.opt_usize("max-steps", self.max_steps)?;
        if let Some(s) = args.opt("stop") {
            self.stop_rule = StopRule::parse(s)?;
        }
        if let Some(s) = args.opt("selection") {
            self.selection = Selection::parse(s)?;
        }
        self.seed = args.opt_u64("seed", self.seed)?;
        self.max_lanes = args.opt_usize("max-lanes", self.max_lanes)?;
        if let Some(s) = args.opt("admission") {
            self.admission = AdmitPolicy::parse(s)?;
        }
        self.shards = args.opt_usize("shards", self.shards)?;
        if let Some(s) = args.opt("placement") {
            self.placement = PlacePolicy::parse(s)?;
        }
        self.steal_threshold = args.opt_usize("steal-threshold", self.steal_threshold)?;
        self.min_shards = args.opt_usize("min-shards", self.min_shards)?;
        if let Some(s) = args.opt("migrate") {
            self.migration = parse_bool(s)?;
        }
        if let Some(s) = args.opt("spec-depth") {
            self.spec_depth = SpecDepth::parse(s)?;
        }
        if let Some(s) = args.opt("shard-classes") {
            self.shard_classes = ShardClass::parse_list(s)?;
        }
        if let Some(s) = args.opt("autoscale") {
            self.autoscale.enabled = parse_bool(s)?;
        }
        self.autoscale.max_shards = args.opt_usize("max-shards", self.autoscale.max_shards)?;
        self.autoscale.scale_up_wait_s =
            args.opt_f64("scale-up-wait", self.autoscale.scale_up_wait_s)?;
        self.autoscale.scale_up_queue =
            args.opt_f64("scale-up-queue", self.autoscale.scale_up_queue)?;
        self.autoscale.scale_down_occupancy =
            args.opt_f64("scale-down-occupancy", self.autoscale.scale_down_occupancy)?;
        self.autoscale.interval_ms =
            args.opt_u64("scale-interval-ms", self.autoscale.interval_ms)?;
        self.autoscale.cooldown_ms =
            args.opt_u64("scale-cooldown-ms", self.autoscale.cooldown_ms)?;
        if let Some(s) = args.opt("prefix-reuse") {
            self.prefix.enabled = parse_bool(s)?;
        }
        self.prefix.capacity = args.opt_usize("prefix-cache-cap", self.prefix.capacity)?;
        self.prefix.max_bytes = args.opt_u64("prefix-cache-bytes", self.prefix.max_bytes)?;
        if let Some(s) = args.opt("prefix-evict") {
            self.prefix.evict = EvictPolicy::parse(s)?;
        }
        if let Some(d) = args.opt("prefix-spill-dir") {
            self.prefix.spill_dir = Some(PathBuf::from(d));
        }
        self.prefix.spill_bytes = args.opt_u64("prefix-spill-bytes", self.prefix.spill_bytes)?;
        if let Some(p) = args.opt("trace-record") {
            self.trace_record = Some(PathBuf::from(p));
        }
        self.deadline_ms = args.opt_u64("deadline-ms", self.deadline_ms)?;
        self.recover_retries = args.opt_u64("recover-retries", self.recover_retries as u64)? as u32;
        self.quarantine_cap = args.opt_usize("quarantine-cap", self.quarantine_cap)?;
        self.conn_idle_timeout_ms =
            args.opt_u64("conn-idle-timeout-ms", self.conn_idle_timeout_ms)?;
        if let Some(s) = args.opt("transport") {
            self.transport = Transport::parse(s)?;
        }
        self.stream_buffer = args.opt_usize("stream-buffer", self.stream_buffer)?;
        if let Some(s) = args.opt("qos") {
            self.qos.enabled = parse_bool(s)?;
        }
        self.qos.tenant_rate = args.opt_f64("tenant-rate", self.qos.tenant_rate)?;
        self.qos.tenant_burst = args.opt_f64("tenant-burst", self.qos.tenant_burst)?;
        if let Some(s) = args.opt("tenants") {
            let v = Value::parse(s).with_context(|| format!("parsing --tenants `{s}`"))?;
            self.qos.parse_tenants(&v)?;
        }
        self.qos.queue_cap = args.opt_usize("queue-cap", self.qos.queue_cap)?;
        if let Some(s) = args.opt("class-weights") {
            self.qos.parse_weights(s)?;
        }
        self.qos.slo_ms = args.opt_u64("slo-ms", self.qos.slo_ms)?;
        self.qos.cost_ceiling_s = args.opt_f64("cost-ceiling", self.qos.cost_ceiling_s)?;
        if let Some(s) = args.opt("fault-spec") {
            let v = Value::parse(s).with_context(|| format!("parsing --fault-spec `{s}`"))?;
            self.fault.apply_json(&v)?;
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_paths == 0 || self.n_paths > 16 {
            bail!("n_paths must be in 1..=16, got {}", self.n_paths);
        }
        if self.tau > 9 {
            bail!("tau must be in 0..=9, got {}", self.tau);
        }
        if self.pool_size == 0 || self.pool_size > 12 {
            bail!("pool_size must be in 1..=12");
        }
        if self.max_steps == 0 || self.max_steps > 64 {
            bail!("max_steps must be in 1..=64");
        }
        if self.max_lanes == 0 || self.max_lanes > 1024 {
            bail!("max_lanes must be in 1..=1024, got {}", self.max_lanes);
        }
        if self.shards == 0 || self.shards > 64 {
            bail!("shards must be in 1..=64, got {}", self.shards);
        }
        if self.steal_threshold > 1024 {
            bail!("steal_threshold must be <= 1024, got {}", self.steal_threshold);
        }
        if self.min_shards == 0 || self.min_shards > 64 {
            bail!("min_shards must be in 1..=64, got {}", self.min_shards);
        }
        if self.min_shards > self.shards {
            bail!(
                "min_shards ({}) must not exceed shards ({}): the pool would start \
                 permanently below its own removal floor",
                self.min_shards,
                self.shards
            );
        }
        match self.spec_depth {
            SpecDepth::Fixed(k) if k == 0 || k > 16 => {
                bail!("spec_depth fixed:<k> must have k in 1..=16, got {k}");
            }
            SpecDepth::Adaptive { max } if max < 2 || max > 16 => {
                bail!("spec_depth adaptive:<max> must have max in 2..=16, got {max}");
            }
            _ => {}
        }
        if self.shard_classes.len() > 64 {
            bail!("shard_classes pattern must have <= 64 entries, got {}", self.shard_classes.len());
        }
        if !self.shard_classes.is_empty()
            && !self.shard_classes.iter().any(|c| c.target_capable())
        {
            bail!(
                "shard_classes must include at least one target-capable class \
                 (balanced or target_heavy): a pure draft_heavy pool cannot serve \
                 gamma-collapsed or non-speculative work at sane cost"
            );
        }
        let a = &self.autoscale;
        if a.max_shards == 0 || a.max_shards > 64 {
            bail!("autoscale.max_shards must be in 1..=64, got {}", a.max_shards);
        }
        if a.max_shards < self.min_shards {
            bail!(
                "autoscale.max_shards ({}) must be >= min_shards ({})",
                a.max_shards,
                self.min_shards
            );
        }
        if a.enabled && self.shards > a.max_shards {
            bail!(
                "shards ({}) must not exceed autoscale.max_shards ({}): the pool would \
                 start above the policy's hard ceiling and scale-down cannot be forced",
                self.shards,
                a.max_shards
            );
        }
        if !(0.0..=1.0).contains(&a.scale_down_occupancy) {
            bail!(
                "autoscale.scale_down_occupancy must be in [0, 1], got {}",
                a.scale_down_occupancy
            );
        }
        if a.scale_up_wait_s < 0.0 || a.scale_up_queue < 0.0 {
            bail!("autoscale scale-up thresholds must be >= 0");
        }
        if a.interval_ms == 0 {
            bail!("autoscale.interval_ms must be > 0");
        }
        if a.hysteresis == 0 {
            bail!("autoscale.hysteresis must be >= 1");
        }
        // bound keeps the cache's O(capacity) LRU eviction scan cheap
        if self.prefix.capacity > 4096 {
            bail!("prefix_cache.capacity must be <= 4096, got {}", self.prefix.capacity);
        }
        // path-style flags fail at validation time with a structured
        // error, not at first spill/record attempt deep in a shard
        // thread. (`artifacts_dir` is exempt: its relative default is
        // resolved against the repo root by `locate_artifacts`.)
        if let Some(d) = &self.prefix.spill_dir {
            validate_path_flag("prefix_cache.spill_dir (--prefix-spill-dir)", d)?;
        }
        if let Some(p) = &self.trace_record {
            validate_path_flag("trace_record (--trace-record)", p)?;
        }
        if self.recover_retries > 16 {
            bail!("recover_retries must be <= 16, got {}", self.recover_retries);
        }
        if self.quarantine_cap == 0 || self.quarantine_cap > 1 << 20 {
            bail!("quarantine_cap must be in 1..=1048576, got {}", self.quarantine_cap);
        }
        if self.conn_idle_timeout_ms > 86_400_000 {
            bail!(
                "conn_idle_timeout_ms must be <= 86400000 (one day), got {}",
                self.conn_idle_timeout_ms
            );
        }
        if self.stream_buffer == 0 || self.stream_buffer > 4096 {
            bail!("stream_buffer must be in 1..=4096, got {}", self.stream_buffer);
        }
        let q = &self.qos;
        for (name, x) in [
            ("tenant_rate", q.tenant_rate),
            ("tenant_burst", q.tenant_burst),
            ("cost_ceiling_s", q.cost_ceiling_s),
        ] {
            if !x.is_finite() || x < 0.0 {
                bail!("qos.{name} must be a finite number >= 0, got {x}");
            }
        }
        for t in &q.tenants {
            if !t.rate.is_finite() || t.rate < 0.0 || !t.burst.is_finite() || t.burst < 0.0 {
                bail!("qos tenant `{}` rate/burst must be finite and >= 0", t.name);
            }
            if t.rate > 0.0 && t.burst < 1.0 {
                bail!("qos tenant `{}`: burst must be >= 1 when rate limited", t.name);
            }
        }
        if q.tenant_rate > 0.0 && q.tenant_burst < 1.0 {
            bail!("qos.tenant_burst must be >= 1 when tenant_rate > 0");
        }
        if q.queue_cap > 1 << 16 {
            bail!("qos.queue_cap must be <= 65536, got {}", q.queue_cap);
        }
        if q.weights.iter().sum::<u64>() == 0 {
            bail!("qos.weights must not all be zero");
        }
        if q.weights.iter().any(|&w| w > 1024) {
            bail!("qos.weights entries must be <= 1024, got {:?}", q.weights);
        }
        if q.slo_ms > 3_600_000 {
            bail!("qos.slo_ms must be <= 3600000, got {}", q.slo_ms);
        }
        if !(0.0..=1.0).contains(&q.lane_share) || q.lane_share == 0.0 {
            bail!("qos.lane_share must be in (0, 1], got {}", q.lane_share);
        }
        if q.max_tenants == 0 || q.max_tenants > 4096 {
            bail!("qos.max_tenants must be in 1..=4096, got {}", q.max_tenants);
        }
        let f = &self.fault;
        for (name, rate) in [
            ("transient_rate", f.transient_rate),
            ("lane_fatal_rate", f.lane_fatal_rate),
            ("panic_rate", f.panic_rate),
            ("stall_rate", f.stall_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("fault.{name} must be in [0, 1], got {rate}");
            }
        }
        if f.stall_ms > 60_000 {
            bail!("fault.stall_ms must be <= 60000, got {}", f.stall_ms);
        }
        Ok(())
    }

    /// Class of a shard id under the configured pattern. Cyclic over the
    /// pattern so hot-added shards (monotonic ids) keep a stable class;
    /// an empty pattern is the uniform legacy pool.
    pub fn class_of(&self, shard_id: usize) -> ShardClass {
        if self.shard_classes.is_empty() {
            ShardClass::Balanced
        } else {
            self.shard_classes[shard_id % self.shard_classes.len()]
        }
    }

    /// Default artifacts location relative to the repo root.
    pub fn locate_artifacts(dir: &Path) -> PathBuf {
        if dir.is_absolute() || dir.exists() {
            dir.to_path_buf()
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_settings() {
        let c = SsrConfig::default();
        assert_eq!(c.n_paths, 5);
        assert_eq!(c.tau, 7);
        assert_eq!(c.pool_size, 12);
        c.validate().unwrap();
    }

    #[test]
    fn json_overrides() {
        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"n_paths": 3, "tau": 9, "stop_rule": "fast2"}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.n_paths, 3);
        assert_eq!(c.tau, 9);
        assert_eq!(c.stop_rule, StopRule::Fast2);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(c.apply_json(&v).is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"tau": 12}"#).unwrap()).is_err());
        c.tau = 7;
        assert!(c.apply_json(&Value::parse(r#"{"n_paths": 0}"#).unwrap()).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = SsrConfig::default();
        let argv: Vec<String> =
            ["run", "--paths", "3", "--tau", "9", "--selection", "oracle"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut args = Args::parse(&argv).unwrap();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.n_paths, 3);
        assert_eq!(c.tau, 9);
        assert_eq!(c.selection, Selection::Oracle);
    }

    #[test]
    fn selection_and_stop_parsers() {
        assert!(Selection::parse("nope").is_err());
        assert_eq!(StopRule::parse("fast-1").unwrap(), StopRule::Fast1);
    }

    #[test]
    fn scheduler_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.max_lanes, 32);
        assert_eq!(c.admission, AdmitPolicy::Fifo);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"max_lanes": 8, "admission": "smallest-first"}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.max_lanes, 8);
        assert_eq!(c.admission, AdmitPolicy::SmallestFirst);

        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"max_lanes": 0}"#).unwrap()).is_err());
        c.max_lanes = 32;
        assert!(c.apply_json(&Value::parse(r#"{"admission": "widest"}"#).unwrap()).is_err());

        let argv: Vec<String> = ["serve", "--max-lanes", "16", "--admission", "smallest"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.max_lanes, 16);
        assert_eq!(c.admission, AdmitPolicy::SmallestFirst);
    }

    #[test]
    fn shard_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.shards, 1);
        assert_eq!(c.placement, PlacePolicy::LeastLoaded);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"shards": 4, "placement": "affinity"}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.placement, PlacePolicy::Affinity);

        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"shards": 0}"#).unwrap()).is_err());
        c.shards = 1;
        assert!(c.apply_json(&Value::parse(r#"{"shards": 100}"#).unwrap()).is_err());
        c.shards = 1;
        assert!(c.apply_json(&Value::parse(r#"{"placement": "widest"}"#).unwrap()).is_err());

        let argv: Vec<String> = ["serve", "--shards", "2", "--placement", "rr"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.shards, 2);
        assert_eq!(c.placement, PlacePolicy::RoundRobin);

        assert_eq!(PlacePolicy::parse("least").unwrap(), PlacePolicy::LeastLoaded);
        assert!(PlacePolicy::parse("nope").is_err());
    }

    #[test]
    fn elastic_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.steal_threshold, 0, "stealing is opt-in");
        assert_eq!(c.min_shards, 1);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"shards": 2, "steal_threshold": 4, "min_shards": 2}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.steal_threshold, 4);
        assert_eq!(c.min_shards, 2);

        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"min_shards": 0}"#).unwrap()).is_err());
        c.min_shards = 1;
        assert!(c.apply_json(&Value::parse(r#"{"steal_threshold": 2000}"#).unwrap()).is_err());
        c.steal_threshold = 0;
        // a removal floor above the spawn count can never be satisfied
        assert!(c.apply_json(&Value::parse(r#"{"min_shards": 4}"#).unwrap()).is_err());

        let argv: Vec<String> =
            ["serve", "--shards", "2", "--steal-threshold", "8", "--min-shards", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.steal_threshold, 8);
        assert_eq!(c.min_shards, 2);
    }

    #[test]
    fn migration_and_autoscale_knobs() {
        let c = SsrConfig::default();
        assert!(c.migration, "migration is the default drain/steal mode");
        assert!(!c.autoscale.enabled, "autoscaling is opt-in");
        assert_eq!(c.autoscale.max_shards, 8);

        let mut c = SsrConfig::default();
        let v = Value::parse(
            r#"{"migration": false, "autoscale": {"enabled": true, "max_shards": 4,
                "scale_up_wait_s": 0.1, "scale_up_queue": 3.5,
                "scale_down_occupancy": 0.5, "interval_ms": 10,
                "cooldown_ms": 100, "hysteresis": 2}}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert!(!c.migration);
        assert!(c.autoscale.enabled);
        assert_eq!(c.autoscale.max_shards, 4);
        assert!((c.autoscale.scale_up_wait_s - 0.1).abs() < 1e-12);
        assert!((c.autoscale.scale_up_queue - 3.5).abs() < 1e-12);
        assert!((c.autoscale.scale_down_occupancy - 0.5).abs() < 1e-12);
        assert_eq!(c.autoscale.interval_ms, 10);
        assert_eq!(c.autoscale.cooldown_ms, 100);
        assert_eq!(c.autoscale.hysteresis, 2);

        // invalid values rejected
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"autoscale": {"max_shards": 0}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(
                &Value::parse(r#"{"autoscale": {"scale_down_occupancy": 1.5}}"#).unwrap()
            )
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"autoscale": {"hysteresis": 0}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"autoscale": {"bogus": 1}}"#).unwrap())
            .is_err());
        // the ceiling cannot sit below the removal floor
        let mut c = SsrConfig::default();
        c.shards = 4;
        c.min_shards = 4;
        assert!(c
            .apply_json(&Value::parse(r#"{"autoscale": {"max_shards": 2}}"#).unwrap())
            .is_err());
        // ...and an enabled policy cannot start above its own ceiling
        let mut c = SsrConfig::default();
        c.shards = 6;
        assert!(c
            .apply_json(
                &Value::parse(r#"{"autoscale": {"enabled": true, "max_shards": 4}}"#)
                    .unwrap()
            )
            .is_err());

        let argv: Vec<String> = [
            "serve",
            "--autoscale",
            "on",
            "--migrate",
            "off",
            "--max-shards",
            "6",
            "--scale-up-wait",
            "0.05",
            "--scale-down-occupancy",
            "0.3",
            "--scale-interval-ms",
            "20",
            "--scale-cooldown-ms",
            "200",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert!(c.autoscale.enabled);
        assert!(!c.migration);
        assert_eq!(c.autoscale.max_shards, 6);
        assert!((c.autoscale.scale_up_wait_s - 0.05).abs() < 1e-12);
        assert!((c.autoscale.scale_down_occupancy - 0.3).abs() < 1e-12);
        assert_eq!(c.autoscale.interval_ms, 20);
        assert_eq!(c.autoscale.cooldown_ms, 200);
    }

    #[test]
    fn prefix_byte_budget_knob() {
        let c = SsrConfig::default();
        assert_eq!(c.prefix.max_bytes, 1 << 30);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"prefix_cache": {"max_bytes": 4096}}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.prefix.max_bytes, 4096);

        // a negative budget must be rejected, not wrapped into u64::MAX
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"prefix_cache": {"max_bytes": -1}}"#).unwrap())
            .is_err());

        let argv: Vec<String> = ["serve", "--prefix-cache-bytes", "1024"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.prefix.max_bytes, 1024);
    }

    #[test]
    fn prefix_cache_knobs() {
        let c = SsrConfig::default();
        assert!(c.prefix.enabled, "prefix reuse is the default serving path");
        assert_eq!(c.prefix.capacity, 256);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"prefix_cache": {"enabled": false, "capacity": 8}}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert!(!c.prefix.enabled);
        assert_eq!(c.prefix.capacity, 8);

        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"prefix_cache": {"bogus": 1}}"#).unwrap())
            .is_err());

        let argv: Vec<String> =
            ["serve", "--prefix-reuse", "off", "--prefix-cache-cap", "4"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert!(!c.prefix.enabled);
        assert_eq!(c.prefix.capacity, 4);

        assert!(parse_bool("on").unwrap());
        assert!(!parse_bool("false").unwrap());
        assert!(parse_bool("maybe").is_err());
    }

    #[test]
    fn spill_and_trace_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.prefix.evict, EvictPolicy::Lru, "lru stays the default policy");
        assert!(c.prefix.spill_dir.is_none(), "spill tier is opt-in");
        assert_eq!(c.prefix.spill_bytes, 0);
        assert!(c.trace_record.is_none(), "trace recording is opt-in");

        assert_eq!(EvictPolicy::parse("cost").unwrap(), EvictPolicy::Cost);
        assert!(EvictPolicy::parse("mru").is_err());
        assert_eq!(EvictPolicy::Cost.name(), "cost");
        assert_eq!(EvictPolicy::Lru.name(), "lru");

        let mut c = SsrConfig::default();
        let v = Value::parse(
            r#"{"prefix_cache": {"evict": "cost", "spill_dir": "/tmp/ssr-spill",
                "spill_bytes": 4096}, "trace_record": "/tmp/ssr.trace"}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.prefix.evict, EvictPolicy::Cost);
        assert_eq!(c.prefix.spill_dir.as_deref(), Some(Path::new("/tmp/ssr-spill")));
        assert_eq!(c.prefix.spill_bytes, 4096);
        assert_eq!(c.trace_record.as_deref(), Some(Path::new("/tmp/ssr.trace")));

        let argv: Vec<String> = [
            "serve",
            "--prefix-evict",
            "cost",
            "--prefix-spill-dir",
            "/tmp/s",
            "--prefix-spill-bytes",
            "1024",
            "--trace-record",
            "/tmp/t.trace",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.prefix.evict, EvictPolicy::Cost);
        assert_eq!(c.prefix.spill_dir.as_deref(), Some(Path::new("/tmp/s")));
        assert_eq!(c.prefix.spill_bytes, 1024);
        assert_eq!(c.trace_record.as_deref(), Some(Path::new("/tmp/t.trace")));
    }

    #[test]
    fn path_flags_are_validated_up_front() {
        // empty and relative paths fail at config validation with a
        // structured error, not at the first spill/record attempt
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"prefix_cache": {"spill_dir": ""}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"prefix_cache": {"spill_dir": "rel/dir"}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"trace_record": "rel.trace"}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"prefix_cache": {"spill_bytes": -1}}"#).unwrap())
            .is_err());
        // the historical relative artifacts_dir default stays valid —
        // it is resolved against the repo root, not the CWD
        SsrConfig::default().validate().unwrap();
    }

    #[test]
    fn qos_knobs() {
        let c = SsrConfig::default();
        assert!(c.qos.enabled, "admission control is the default intake path");
        assert_eq!(c.qos.queue_cap, 256);
        assert_eq!(c.qos.weights, [4, 2, 1]);
        assert_eq!(c.qos.slo_ms, 0, "SLO shedding is opt-in");
        assert_eq!(c.qos.tenant_rate, 0.0, "rate limiting is opt-in");

        let mut c = SsrConfig::default();
        let v = Value::parse(
            r#"{"qos": {"enabled": true, "tenant_rate": 2.5, "tenant_burst": 4,
                "tenants": {"hot": {"rate": 10, "burst": 20}},
                "queue_cap": 32, "weights": [8, 3, 1], "slo_ms": 500,
                "cost_ceiling_s": 120.5, "lane_share": 0.25, "max_tenants": 64}}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert!((c.qos.tenant_rate - 2.5).abs() < 1e-12);
        assert_eq!(c.qos.queue_cap, 32);
        assert_eq!(c.qos.weights, [8, 3, 1]);
        assert_eq!(c.qos.slo_ms, 500);
        assert!((c.qos.cost_ceiling_s - 120.5).abs() < 1e-12);
        assert_eq!(c.qos.bucket_for("hot"), (10.0, 20.0));
        assert_eq!(c.qos.bucket_for("cold"), (2.5, 4.0), "default applies to others");

        // invalid values rejected
        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"qos": {"bogus": 1}}"#).unwrap()).is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"qos": {"tenant_rate": -1}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"qos": {"weights": [0, 0, 0]}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"qos": {"weights": [1, 2]}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"qos": {"lane_share": 1.5}}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"qos": {"queue_cap": 100000}}"#).unwrap())
            .is_err());
        // a rate-limited tenant with a sub-1 burst could never admit
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(
                &Value::parse(r#"{"qos": {"tenant_rate": 5, "tenant_burst": 0.5}}"#).unwrap()
            )
            .is_err());

        let argv: Vec<String> = [
            "serve",
            "--qos",
            "on",
            "--tenant-rate",
            "3",
            "--tenant-burst",
            "6",
            "--tenants",
            r#"{"vip": {"rate": 100, "burst": 200}}"#,
            "--queue-cap",
            "16",
            "--class-weights",
            "6,3,2",
            "--slo-ms",
            "250",
            "--cost-ceiling",
            "60",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        args.finish().unwrap();
        assert!((c.qos.tenant_rate - 3.0).abs() < 1e-12);
        assert!((c.qos.tenant_burst - 6.0).abs() < 1e-12);
        assert_eq!(c.qos.bucket_for("vip"), (100.0, 200.0));
        assert_eq!(c.qos.queue_cap, 16);
        assert_eq!(c.qos.weights, [6, 3, 2]);
        assert_eq!(c.qos.slo_ms, 250);
        assert!((c.qos.cost_ceiling_s - 60.0).abs() < 1e-12);
    }

    #[test]
    fn spec_depth_knob() {
        let c = SsrConfig::default();
        assert_eq!(c.spec_depth, SpecDepth::Fixed(1), "legacy lockstep is the default");

        assert_eq!(SpecDepth::parse("fixed:4").unwrap(), SpecDepth::Fixed(4));
        assert_eq!(SpecDepth::parse("adaptive").unwrap(), SpecDepth::Adaptive { max: 8 });
        assert_eq!(SpecDepth::parse("adaptive:6").unwrap(), SpecDepth::Adaptive { max: 6 });
        assert!(SpecDepth::parse("deep").is_err());
        assert!(SpecDepth::parse("fixed:x").is_err());
        assert_eq!(SpecDepth::Fixed(4).label(), "fixed:4");
        assert_eq!(SpecDepth::Adaptive { max: 8 }.label(), "adaptive:8");

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"spec_depth": "fixed:2"}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.spec_depth, SpecDepth::Fixed(2));

        // out-of-range depths rejected at validation
        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"spec_depth": "fixed:0"}"#).unwrap()).is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"spec_depth": "adaptive:32"}"#).unwrap())
            .is_err());

        let argv: Vec<String> = ["serve", "--spec-depth", "adaptive:4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.spec_depth, SpecDepth::Adaptive { max: 4 });
    }

    #[test]
    fn shard_class_knob() {
        let c = SsrConfig::default();
        assert!(c.shard_classes.is_empty(), "uniform pool is the default");
        assert_eq!(c.class_of(0), ShardClass::Balanced);
        assert_eq!(c.class_of(7), ShardClass::Balanced);

        let mut c = SsrConfig::default();
        let v =
            Value::parse(r#"{"shard_classes": ["draft_heavy", "balanced", "target_heavy"]}"#)
                .unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.class_of(0), ShardClass::DraftHeavy);
        assert_eq!(c.class_of(2), ShardClass::TargetHeavy);
        // cyclic: hot-added shard 3 wraps to the pattern head
        assert_eq!(c.class_of(3), ShardClass::DraftHeavy);

        // pure draft pools are rejected: nothing target-capable to
        // migrate collapsed-gamma runs onto
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"shard_classes": ["draft_heavy"]}"#).unwrap())
            .is_err());
        let mut c = SsrConfig::default();
        assert!(c
            .apply_json(&Value::parse(r#"{"shard_classes": ["gpu"]}"#).unwrap())
            .is_err());

        let argv: Vec<String> = ["serve", "--shard-classes", "draft_heavy,target_heavy"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.shard_classes, vec![ShardClass::DraftHeavy, ShardClass::TargetHeavy]);

        // class contract: profiles are clock/capacity-only knobs
        assert_eq!(ShardClass::Balanced.cost_profile(), (1.0, 1.0));
        assert_eq!(ShardClass::DraftHeavy.lane_factor(), 2);
        assert!(!ShardClass::DraftHeavy.target_capable());
        assert!(ShardClass::TargetHeavy.target_capable());
        assert_eq!(
            ShardClass::parse_list("draft_heavy, balanced").unwrap(),
            vec![ShardClass::DraftHeavy, ShardClass::Balanced]
        );
    }

    #[test]
    fn connection_and_quarantine_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.conn_idle_timeout_ms, 30_000, "slow-loris guard on by default");
        assert_eq!(c.quarantine_cap, 1024);

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"conn_idle_timeout_ms": 5000, "quarantine_cap": 16}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.conn_idle_timeout_ms, 5000);
        assert_eq!(c.quarantine_cap, 16);

        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"quarantine_cap": 0}"#).unwrap()).is_err());

        let argv: Vec<String> =
            ["serve", "--conn-idle-timeout-ms", "1000", "--quarantine-cap", "8"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.conn_idle_timeout_ms, 1000);
        assert_eq!(c.quarantine_cap, 8);
    }

    #[test]
    fn transport_and_stream_buffer_knobs() {
        let c = SsrConfig::default();
        assert_eq!(c.transport, Transport::Jsonl, "jsonl stays the compat default");
        assert_eq!(c.stream_buffer, 64);
        assert_eq!(Transport::parse("framed").unwrap(), Transport::Framed);
        assert_eq!(Transport::parse("json-lines").unwrap(), Transport::Jsonl);
        assert!(Transport::parse("carrier-pigeon").is_err());
        assert_eq!(Transport::Framed.name(), "framed");

        let mut c = SsrConfig::default();
        let v = Value::parse(r#"{"transport": "framed", "stream_buffer": 8}"#).unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.transport, Transport::Framed);
        assert_eq!(c.stream_buffer, 8);

        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"stream_buffer": 0}"#).unwrap()).is_err());
        let mut c = SsrConfig::default();
        assert!(c.apply_json(&Value::parse(r#"{"stream_buffer": 5000}"#).unwrap()).is_err());

        let argv: Vec<String> = ["serve", "--transport", "framed", "--stream-buffer", "1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut args = Args::parse(&argv).unwrap();
        let mut c = SsrConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.transport, Transport::Framed);
        assert_eq!(c.stream_buffer, 1);
    }
}
