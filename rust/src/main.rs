//! `ssr` — leader binary: solve one problem, serve a TCP endpoint, or
//! regenerate the paper's experiments.
//!
//! ```text
//! ssr solve --expr "(17+25)*3" [--method ssr|baseline|parallel|parallel-spm|
//!           spec-reason|ssr-fast1|ssr-fast2] [--backend pjrt|calibrated]
//! ssr serve [--host 127.0.0.1] [--port 7878] [--backend ...] [--threads 4]
//!           [--max-lanes 32] [--admission fifo|smallest-first]
//!           [--shards N] [--placement least-loaded|affinity|round-robin]
//!           [--steal-threshold L] [--min-shards N] [--migrate on|off]
//!           [--spec-depth fixed:<k>|adaptive:<max>]
//!           [--shard-classes draft_heavy,balanced,target_heavy]
//!           [--autoscale on|off] [--max-shards N] [--scale-up-wait S]
//!           [--scale-up-queue Q] [--scale-down-occupancy F]
//!           [--scale-interval-ms MS] [--scale-cooldown-ms MS]
//!           [--deadline-ms MS] [--recover-retries N]
//!           [--fault-spec '{"seed":7,"panic_rate":0.01,...}']
//!           [--qos on|off] [--tenant-rate R] [--tenant-burst B]
//!           [--tenants '{"acme":{"rate":2,"burst":8}}']
//!           [--queue-cap N] [--class-weights 'i,b,e'] [--slo-ms MS]
//!           [--cost-ceiling S] [--quarantine-cap N]
//!           [--conn-idle-timeout-ms MS]
//!           [--transport jsonl|framed] [--stream-buffer N]
//!           [--prefix-evict lru|cost] [--prefix-spill-dir DIR]
//!           [--prefix-spill-bytes B] [--trace-record PATH]
//! ssr exp   fig2|fig3|fig4|fig5|table1|gamma|all [--backend calibrated]
//!           [--trials 6] [--problems 60]
//! ssr selfcheck            # artifacts -> PJRT -> one SSR problem
//! ```
//! Shared engine flags: --paths N --tau T --temp X --stop full|fast1|fast2
//! --selection model-top|model-sample|random|oracle --seed S --artifacts DIR
//! --prefix-reuse on|off --prefix-cache-cap N --prefix-cache-bytes B
//! (shared-prefix prefill + cross-request prefix cache; DESIGN.md §2, §10)
//!
//! `serve` runs the sharded backend pool: `--shards N` scheduler
//! threads each own one backend and a `--max-lanes` lane pool;
//! concurrent solves are routed by `--placement` and share backend step
//! batches per shard (see `coordinator::pool`). The pool is elastic:
//! `{"op":"add_shard"}` / `{"op":"remove_shard","shard":i}` grow and
//! drain it at runtime (bounded below by `--min-shards`), and
//! `--steal-threshold L` lets under-occupied shards steal queued work
//! from the most-loaded shard. With `--migrate on` (the default),
//! drains and steals move *in-flight* runs between shards at step
//! boundaries (lane-state serialization on the Backend trait; drain =
//! O(one step)), and `--autoscale on` runs the queue-driven policy loop
//! (`coordinator::autoscaler`) that grows/shrinks the pool within
//! `[--min-shards, --max-shards]`. `{"op":"stats"}` reports batch
//! occupancy, queue depth, admission waits, per-shard request counts,
//! steal/migration/lifecycle/drain/scale gauges and the model-time
//! makespan alongside the latency percentiles.
//!
//! Serving is fault-tolerant (DESIGN.md §13): shard panics are caught,
//! the shard is respawned and its in-flight runs are re-admitted on the
//! survivors; `--deadline-ms` (or a per-request `deadline_ms` field)
//! bounds solve latency with a degraded best-effort reply; and
//! `--fault-spec` wraps every shard's backend in a deterministic,
//! seeded fault injector (step errors, stalls, panics) for chaos
//! testing — see `{"op":"stats"}` keys `shard_crashes`,
//! `runs_recovered`, `quarantined`, `degraded_replies`.
//!
//! Speculation is adaptive (DESIGN.md §15): `--spec-depth adaptive:<max>`
//! lets each run's depth controller widen the draft burst while its
//! measured acceptance rate (gamma) stays high and narrow it — down to
//! target-only — when gamma collapses; `fixed:<k>` (default `fixed:1`)
//! pins the depth, and `fixed:1` is bit-identical to the pre-§15
//! lockstep engine. `--shard-classes` declares a heterogeneous fleet
//! (`draft_heavy` doubles lanes and cheapens draft seconds,
//! `target_heavy` the reverse); the scheduler migrates gamma-collapsed
//! runs to target-heavy shards and gamma-rich runs to draft-heavy ones,
//! and the autoscaler scales each class independently. See
//! `{"op":"stats"}` keys `gamma_overall`, `gamma_<class>`,
//! `spec_depth_mean`, `target_only_runs`, `gamma_migrations`,
//! `model_secs_draft`/`model_secs_target` and `placement_shape_hits`.
//!
//! The front end is a single nonblocking event loop multiplexing many
//! connections (PROTOCOL.md, DESIGN.md §16): `--transport` selects
//! newline-delimited JSON (`jsonl`, the compat default) or the
//! length-delimited `framed` codec; requests may carry a `request_id`
//! (echoed on every reply) and interleave freely on one connection; a
//! solve with `"stream":true` also receives `progress` / `first_vote`
//! events over a bounded drop-oldest buffer (`--stream-buffer N`)
//! before its terminal reply. `{"op":"hello"}` reports the protocol
//! version and feature list. See `{"op":"stats"}` keys
//! `streams_active`, `stream_events`, `stream_drops`,
//! `stream_disconnects` and `time_to_first_vote_*`. Streamed solves
//! also emit `token_delta` events (newly committed tokens since the
//! last frame plus the monotone running total).
//!
//! The prefix store is two-tier (DESIGN.md §17): `--prefix-evict`
//! selects the hot-tier victim policy (`lru` default; `cost` weighs
//! recompute cost × refork frequency), and `--prefix-spill-dir`
//! enables a persistent spill tier — evicted prefill state is
//! serialized to disk (bounded by `--prefix-spill-bytes`, 0 =
//! unbounded), promoted back on a hot-tier miss, and reloaded on the
//! next start for warm restarts. `--trace-record PATH` appends every
//! admitted solve to a compact replayable trace log
//! (`workload::trace`); benches replay such traces deterministically.
//! See `{"op":"stats"}` keys `prefix_spills`, `prefix_promotes`,
//! `prefix_warm_hits`, `prefix_spill_hit_rate`, the tier size gauges
//! and `prefill_prompt_tokens`.
//!
//! Serving is overload-safe (DESIGN.md §14): a `solve` may carry
//! `tenant` and `class` (`interactive`|`batch`|`best_effort`) wire
//! fields; per-tenant token buckets (`--tenant-rate`/`--tenant-burst`,
//! per-tenant overrides via `--tenants`), per-class bounded queues
//! (`--queue-cap`, weighted dequeue via `--class-weights`), fair-share
//! lane quotas and SLO-driven shedding (`--slo-ms`) gate intake before
//! a job touches the pool — shed requests get a structured
//! `{"ok":false,"err":"overloaded","retry_after_ms":...}` reply and
//! in-flight work is never dropped. `--cost-ceiling` bounds the
//! autoscaler's spend; `--conn-idle-timeout-ms` closes slow-loris
//! connections — see `{"op":"stats"}` keys `rejected`, `shed`,
//! `retry_after_hints`, per-class p50/p99 and per-tenant gauges.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use ssr::backend::calibrated::CalibratedBackend;
use ssr::backend::faulty::FaultInjector;
use ssr::backend::Backend;
use ssr::config::SsrConfig;
use ssr::coordinator::engine::Engine;
use ssr::coordinator::server::{parse_method, Server};
use ssr::eval::experiments::{self, ExpOpts};
use ssr::model::tokenizer;
use ssr::util::cli::Args;
use ssr::util::json;
use ssr::util::threadpool::ThreadPool;
use ssr::workload::problems::problem_from_text;

fn main() {
    ssr::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(cfg: &SsrConfig) -> PathBuf {
    SsrConfig::locate_artifacts(&cfg.artifacts_dir)
}

fn make_factory(
    backend: String,
    cfg: &SsrConfig,
) -> impl FnMut(&str, u64) -> Result<Box<dyn Backend>> {
    let dir = artifacts_dir(cfg);
    let temp = cfg.temp;
    let max_steps = cfg.max_steps;
    move |suite: &str, seed: u64| -> Result<Box<dyn Backend>> {
        match backend.as_str() {
            "calibrated" => {
                Ok(Box::new(CalibratedBackend::for_suite(suite, seed)?) as Box<dyn Backend>)
            }
            "pjrt" => load_pjrt(&dir, temp, max_steps),
            other => bail!("unknown backend `{other}` (pjrt|calibrated)"),
        }
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt(dir: &std::path::Path, temp: f32, max_steps: usize) -> Result<Box<dyn Backend>> {
    let mut b = ssr::backend::pjrt::PjrtBackend::load(dir)?;
    b.temp = temp;
    b.max_steps = max_steps;
    Ok(Box::new(b) as Box<dyn Backend>)
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt(_dir: &std::path::Path, _temp: f32, _max_steps: usize) -> Result<Box<dyn Backend>> {
    bail!(
        "this binary was built without the `pjrt` feature. Enabling it needs \
         the vendored `xla` crate: add `xla = {{ path = ... }}` to \
         rust/Cargo.toml (see the note there), then rebuild with \
         `--features pjrt` — or use `--backend calibrated`"
    )
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    let mut cfg = SsrConfig::default();
    cfg.apply_args(&mut args)?;
    // default to the backend this build actually ships: pjrt when the
    // feature is compiled in, the calibrated substrate otherwise
    let default_backend = if cfg!(feature = "pjrt") { "pjrt" } else { "calibrated" };
    let backend_kind = args.opt_str("backend", default_backend);

    match args.command.clone().as_deref() {
        Some("solve") => {
            let expr = args
                .opt("expr")
                .map(|s| s.to_string())
                .or_else(|| args.positional.first().cloned())
                .context("need --expr or a positional expression")?;
            let method_name = args.opt_str("method", "ssr");
            args.finish()?;
            let req = json::obj(vec![("method", json::s(method_name))]);
            let method = parse_method(&req, cfg.n_paths, cfg.tau)?;
            let mut factory = make_factory(backend_kind, &cfg);
            // calibrated backend needs a suite profile; medium fits ad-hoc
            let mut backend = factory("synth-livemath", cfg.seed)?;
            let vocab = tokenizer::builtin_vocab();
            let problem = problem_from_text(&vocab, &expr)?;
            let mut engine = Engine::new(backend.as_mut(), cfg.clone());
            let r = engine.run(&problem, method, cfg.seed)?;
            println!("expr           : {expr}");
            println!("method         : {}", method.name());
            println!("answer         : {:?}", r.answer());
            println!("gold           : {}", problem.answer);
            println!("correct        : {}", r.answer() == Some(problem.answer));
            println!("selection      : {:?}", r.selection);
            println!("steps/rewrites : {}/{}", r.steps, r.rewrites);
            println!("tokens d/t     : {}/{}", r.draft_tokens, r.target_tokens);
            println!("model time     : {:.3}s (wall {:.3}s)", r.model_secs, r.wall_secs);
            Ok(())
        }
        Some("serve") => {
            let host = args.opt_str("host", "127.0.0.1");
            let port = args.opt_usize("port", 7878)? as u16;
            let threads = args.opt_usize("threads", 4)?;
            let suite = args.opt_str("suite", "synth-livemath");
            args.finish()?;
            let factory = make_factory(backend_kind, &cfg);
            let vocab = tokenizer::builtin_vocab();
            let seed = cfg.seed;
            // one factory serves every shard (called once per shard, on
            // that shard's thread); all shards share one backend seed so
            // the calibrated substrate's derived streams make placement
            // decision-neutral (DESIGN.md §10)
            let factory = std::sync::Mutex::new(factory);
            // --fault-spec: wrap every shard's backend in the seeded
            // injector; one shared budget caps faults pool-wide and
            // survives respawns (DESIGN.md §13)
            let fault = cfg.fault;
            let budget = FaultInjector::shared_budget(&fault);
            if fault.is_active() {
                println!("fault injection ACTIVE: {fault:?}");
            }
            let shard_factory = move |shard: usize| {
                let mut f = factory.lock().unwrap();
                let b = (*f)(&suite, seed)?;
                Ok(if fault.is_active() {
                    Box::new(FaultInjector::new(b, fault, shard, budget.clone()))
                        as Box<dyn Backend>
                } else {
                    b
                })
            };
            println!(
                "pool: shards={} (min {} max {}) placement={:?} max_lanes={}/shard \
                 steal_threshold={} migration={} autoscale={} admission={:?} \
                 prefix_reuse={} prefix_cache_cap={} prefix_cache_bytes={} \
                 prefix_evict={} prefix_spill_dir={:?} prefix_spill_bytes={}",
                cfg.shards,
                cfg.min_shards,
                cfg.autoscale.max_shards,
                cfg.placement,
                cfg.max_lanes,
                cfg.steal_threshold,
                cfg.migration,
                cfg.autoscale.enabled,
                cfg.admission,
                cfg.prefix.enabled,
                cfg.prefix.capacity,
                cfg.prefix.max_bytes,
                cfg.prefix.evict.name(),
                cfg.prefix.spill_dir,
                cfg.prefix.spill_bytes
            );
            if let Some(p) = &cfg.trace_record {
                println!("trace record: {p:?} (one entry per admitted solve)");
            }
            println!(
                "speculation: spec_depth={:?} shard_classes={:?}",
                cfg.spec_depth,
                cfg.shard_classes.iter().map(|c| c.name()).collect::<Vec<_>>()
            );
            println!(
                "qos: enabled={} tenant_rate={}/s burst={} queue_cap={}/class \
                 weights={:?} slo_ms={} cost_ceiling_s={} idle_timeout_ms={}",
                cfg.qos.enabled,
                cfg.qos.tenant_rate,
                cfg.qos.tenant_burst,
                cfg.qos.queue_cap,
                cfg.qos.weights,
                cfg.qos.slo_ms,
                cfg.qos.cost_ceiling_s,
                cfg.conn_idle_timeout_ms
            );
            let (server, listener) = Server::start(&host, port, cfg, vocab, shard_factory)?;
            println!("listening on {}", server.addr);
            let pool = ThreadPool::new(threads);
            server.serve(listener, &pool)
        }
        Some("exp") => {
            let which = args.positional.first().cloned().unwrap_or_else(|| "all".into());
            let opts = ExpOpts {
                trials: args.opt_u64("trials", 6)?,
                max_problems: args.opt_usize("problems", 60)?,
            };
            let backend_kind = args.opt_str("backend", "calibrated");
            let out_path = args.opt("out").map(PathBuf::from);
            args.finish()?;
            let mut factory = make_factory(backend_kind, &cfg);
            let text = run_experiment(&which, &mut factory, &cfg, &opts)?;
            println!("{text}");
            if let Some(p) = out_path {
                std::fs::write(&p, &text).with_context(|| format!("writing {p:?}"))?;
                println!("(written to {p:?})");
            }
            Ok(())
        }
        Some("selfcheck") => {
            args.finish()?;
            selfcheck(&cfg)
        }
        Some(cmd) => bail!("unknown command `{cmd}` (solve|serve|exp|selfcheck)"),
        None => {
            println!(
                "ssr — Speculative Parallel Scaling Reasoning\n\
                 commands: solve | serve | exp | selfcheck   (see README)"
            );
            Ok(())
        }
    }
}

fn run_experiment(
    which: &str,
    factory: &mut dyn FnMut(&str, u64) -> Result<Box<dyn Backend>>,
    cfg: &SsrConfig,
    opts: &ExpOpts,
) -> Result<String> {
    Ok(match which {
        "fig2" => experiments::fig2(factory, cfg, opts)?.1,
        "fig3" => experiments::fig3(factory, cfg, opts)?.1,
        "fig4" => experiments::fig4(factory, cfg, opts)?.1,
        "fig5" => experiments::fig5(factory, cfg, opts)?.1,
        "table1" => experiments::table1(factory, cfg, opts)?.1,
        "gamma" => experiments::gamma_check(factory, cfg, opts)?.1,
        "tau" => experiments::tau_sweep(factory, cfg, opts)?.1,
        "selection" => experiments::selection_ablation(factory, cfg, opts)?.1,
        "all" => {
            let mut text = String::new();
            for name in ["fig2", "fig3", "fig4", "fig5", "table1", "gamma", "tau", "selection"] {
                let t = run_experiment(name, factory, cfg, opts)?;
                text.push_str(&format!("==== {name} ====\n{t}\n"));
            }
            text
        }
        other => bail!("unknown experiment `{other}`"),
    })
}

/// Load artifacts, run one SSR problem end-to-end on the PJRT backend,
/// print timing — the fastest way to verify an installation.
#[cfg(feature = "pjrt")]
fn selfcheck(cfg: &SsrConfig) -> Result<()> {
    use ssr::backend::pjrt::PjrtBackend;
    use ssr::config::StopRule;
    use ssr::coordinator::engine::Method;

    let dir = artifacts_dir(cfg);
    println!("artifacts: {dir:?}");
    let mut b = PjrtBackend::load(&dir)?;
    b.temp = cfg.temp;
    b.warmup(3)?; // precompile the variants this run touches
    let vocab = b.manifest().vocab.clone();
    let problem = problem_from_text(&vocab, "17+25*3")?;
    let mut engine = Engine::new(&mut b, cfg.clone());
    let t0 = std::time::Instant::now();
    let r = engine.run(&problem, Method::Ssr { n: 3, tau: cfg.tau, stop: StopRule::Full }, 7)?;
    println!(
        "answer={:?} gold={} steps={} rewrites={}",
        r.answer(),
        problem.answer,
        r.steps,
        r.rewrites
    );
    println!(
        "tokens draft/target = {}/{}   model {:.2}s   wall {:.2}s",
        r.draft_tokens,
        r.target_tokens,
        r.model_secs,
        t0.elapsed().as_secs_f64()
    );
    println!("selfcheck OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn selfcheck(_cfg: &SsrConfig) -> Result<()> {
    bail!(
        "selfcheck drives the real PJRT backend; vendor the `xla` crate \
         (see rust/Cargo.toml) and rebuild with `--features pjrt`"
    )
}
