//! Workload substrate: procedural problem generation (the stand-in for
//! the paper's math benchmarks), the strategy pool, canonical evaluation
//! suites, and serving traces (`traces` for closed-loop engine benches,
//! `trace` for the recorded/replayed serving-request logs).

pub mod problems;
pub mod strategies;
pub mod suites;
pub mod trace;
pub mod traces;

pub use problems::{Family, Problem};
pub use suites::Suite;
