//! Benchmark suites — the canonical problem sets standing in for the
//! paper's AIME 2024 / MATH-500 / LiveMathBench (loaded from the
//! python-generated `artifacts/suite-*.json`, or regenerated in-process
//! for manifest-free runs; both paths are deterministic and the
//! integration tests assert they agree).

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::Vocab;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workload::problems::{Family, Problem, FAMILIES};

#[derive(Debug, Clone)]
pub struct Suite {
    pub name: String,
    pub problems: Vec<Problem>,
}

/// Suite generation profiles (mirror `corpus.SUITES`).
#[derive(Debug, Clone)]
pub struct SuiteSpec {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub n_problems: usize,
    pub seed: u64,
    pub family_mix: [f64; 4],
    pub max_operand: i64,
    pub ops_lo: usize,
    pub ops_hi: usize,
}

pub const SUITE_SPECS: [SuiteSpec; 3] = [
    SuiteSpec {
        name: "synth-math500",
        paper_name: "MATH-500",
        n_problems: 500,
        seed: 0x4D41_5448,
        family_mix: [0.40, 0.30, 0.20, 0.10],
        max_operand: 30,
        ops_lo: 2,
        ops_hi: 3,
    },
    SuiteSpec {
        name: "synth-livemath",
        paper_name: "LiveMathBench",
        n_problems: 138,
        seed: 0x4C49_5645,
        family_mix: [0.25, 0.25, 0.25, 0.25],
        max_operand: 50,
        ops_lo: 2,
        ops_hi: 4,
    },
    SuiteSpec {
        name: "synth-aime",
        paper_name: "AIME2024",
        n_problems: 30,
        seed: 0x4149_4D45,
        family_mix: [0.10, 0.25, 0.35, 0.30],
        max_operand: 99,
        ops_lo: 3,
        ops_hi: 4,
    },
];

pub fn spec(name: &str) -> Result<&'static SuiteSpec> {
    SUITE_SPECS
        .iter()
        .find(|s| s.name == name || s.paper_name == name)
        .with_context(|| format!("unknown suite `{name}`"))
}

/// Load a python-generated suite file.
pub fn load(dir: &Path, file: &str, name: &str) -> Result<Suite> {
    let path = dir.join(file);
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
    let v = Value::parse(&text)?;
    let problems = v
        .get("problems")?
        .arr()?
        .iter()
        .map(|p| {
            let tokens: Vec<i32> = p
                .get("tokens")?
                .arr()?
                .iter()
                .map(|t| Ok(t.i64()? as i32))
                .collect::<Result<Vec<_>>>()?;
            Ok(Problem {
                family: Family::from_index(p.get_usize("family")?),
                expr: crate::workload::problems::Expr::Num(p.get_i64("answer")?),
                answer: p.get_i64("answer")?,
                difficulty: p.get_i64("difficulty")? as u32,
                tokens,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Suite { name: name.to_string(), problems })
}

/// Regenerate a suite in-process (manifest-free paths: calibrated
/// experiments, tests). Must match the python generator's output for the
/// same spec — guarded by the cross-language integration test.
pub fn generate(spec: &SuiteSpec, vocab: &Vocab) -> Suite {
    let mut rng = Rng::new(spec.seed);
    let mut problems = Vec::with_capacity(spec.n_problems);
    while problems.len() < spec.n_problems {
        let fam = FAMILIES[rng.choice_weighted(&spec.family_mix)];
        let n_ops = rng.range(spec.ops_lo as i64, spec.ops_hi as i64) as usize;
        // python gen_suite filters on answer range and prompt length 40
        // (prompt = expr + 5 framing tokens); gen_valid uses 36-token exprs
        let p =
            crate::workload::problems::gen_problem(&mut rng, vocab, fam, spec.max_operand, n_ops);
        if (0..=999).contains(&p.answer) && p.tokens.len() + 4 <= 40 {
            problems.push(p);
        }
    }
    Suite { name: spec.name.to_string(), problems }
}

impl Suite {
    /// Mean difficulty (used by the calibrated backend's difficulty model).
    pub fn mean_difficulty(&self) -> f64 {
        if self.problems.is_empty() {
            return 0.0;
        }
        self.problems.iter().map(|p| p.difficulty as f64).sum::<f64>() / self.problems.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::model::tokenizer;

    #[test]
    fn specs_resolve_by_both_names() {
        assert_eq!(spec("synth-aime").unwrap().paper_name, "AIME2024");
        assert_eq!(spec("MATH-500").unwrap().name, "synth-math500");
        assert!(spec("nope").is_err());
    }

    #[test]
    fn generated_suites_deterministic_and_valid() {
        let v = test_vocab();
        for s in &SUITE_SPECS {
            let a = generate(s, &v);
            let b = generate(s, &v);
            assert_eq!(a.problems.len(), s.n_problems);
            for (pa, pb) in a.problems.iter().zip(&b.problems) {
                assert_eq!(pa.tokens, pb.tokens);
                assert_eq!(pa.answer, pb.answer);
            }
            for p in &a.problems {
                assert_eq!(tokenizer::eval_expr(&v, &p.tokens).unwrap(), p.answer);
            }
        }
    }

    #[test]
    fn aime_is_hardest() {
        let v = test_vocab();
        let aime = generate(spec("synth-aime").unwrap(), &v);
        let math = generate(spec("synth-math500").unwrap(), &v);
        assert!(aime.mean_difficulty() > math.mean_difficulty());
    }
}
