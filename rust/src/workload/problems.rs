//! Procedural arithmetic-reasoning problems — the benchmark substrate
//! standing in for AIME / MATH-500 / LiveMathBench (DESIGN.md §1).
//!
//! Mirrors `python/compile/corpus.py` (same splitmix64 stream, same
//! families, same rendering grammar); the canonical evaluation suites are
//! generated in python at artifact-build time (`suites.rs` loads them),
//! while this generator feeds serving traces, fuzzing and property tests.

use anyhow::Result;

use crate::model::tokenizer;
use crate::runtime::Vocab;
use crate::util::rng::Rng;

/// Problem families (indices match the python corpus).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    AddChain = 0,
    MulMix = 1,
    Paren = 2,
    Modular = 3,
}

pub const FAMILIES: [Family; 4] =
    [Family::AddChain, Family::MulMix, Family::Paren, Family::Modular];

impl Family {
    pub fn from_index(i: usize) -> Family {
        FAMILIES[i]
    }

    pub fn name(self) -> &'static str {
        match self {
            Family::AddChain => "add_chain",
            Family::MulMix => "mul_mix",
            Family::Paren => "paren",
            Family::Modular => "modular",
        }
    }
}

/// Expression AST (leaf value or binary op).
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Num(i64),
    Bin(Op, Box<Expr>, Box<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Mod,
}

impl Expr {
    pub fn eval(&self) -> i64 {
        match self {
            Expr::Num(v) => *v,
            Expr::Bin(op, a, b) => {
                let (x, y) = (a.eval(), b.eval());
                match op {
                    Op::Add => x + y,
                    Op::Sub => x - y,
                    Op::Mul => x * y,
                    Op::Mod => x.rem_euclid(y),
                }
            }
        }
    }

    /// Render with minimal parentheses (matches the python renderer:
    /// `%` binds loosest, compound `%`-lhs always parenthesized).
    pub fn tokens(&self, v: &Vocab) -> Vec<i32> {
        let mut out = Vec::new();
        self.render(v, 0, &mut out);
        out
    }

    fn prec(op: Op) -> i32 {
        match op {
            Op::Mod => 0,
            Op::Add | Op::Sub => 1,
            Op::Mul => 2,
        }
    }

    fn render(&self, v: &Vocab, parent_prec: i32, out: &mut Vec<i32>) {
        match self {
            Expr::Num(x) => out.extend(tokenizer::num_tokens(v, *x)),
            Expr::Bin(op, a, b) => {
                let prec = Self::prec(*op);
                let lhs_prec = if *op == Op::Mod { 3 } else { prec };
                let need_parens = prec < parent_prec;
                if need_parens {
                    out.push(v.lparen);
                }
                a.render(v, lhs_prec, out);
                out.push(match op {
                    Op::Add => v.plus,
                    Op::Sub => v.minus,
                    Op::Mul => v.mul,
                    Op::Mod => v.modulo,
                });
                b.render(v, prec + 1, out);
                if need_parens {
                    out.push(v.rparen);
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct Problem {
    pub family: Family,
    pub expr: Expr,
    pub answer: i64,
    pub difficulty: u32,
    /// pre-rendered expression tokens
    pub tokens: Vec<i32>,
}

fn bin(op: Op, a: Expr, b: Expr) -> Expr {
    Expr::Bin(op, Box::new(a), Box::new(b))
}

fn gen_add_chain(rng: &mut Rng, max_operand: i64, n_ops: usize) -> Expr {
    let mut node = Expr::Num(rng.range(1, max_operand));
    let mut total = node.eval();
    for _ in 0..n_ops {
        if total > 10 && rng.below(2) == 0 {
            let v = rng.range(1, total.min(max_operand));
            node = bin(Op::Sub, node, Expr::Num(v));
            total -= v;
        } else {
            let v = rng.range(1, max_operand);
            node = bin(Op::Add, node, Expr::Num(v));
            total += v;
        }
    }
    node
}

fn gen_mul_mix(rng: &mut Rng, max_operand: i64, n_ops: usize) -> Expr {
    let small = (max_operand / 4).clamp(2, 9);
    let prod = bin(Op::Mul, Expr::Num(rng.range(2, small)), Expr::Num(rng.range(2, small)));
    let mut node = bin(Op::Add, Expr::Num(rng.range(1, max_operand)), prod);
    for _ in 0..n_ops.saturating_sub(2) {
        if rng.below(3) == 0 {
            let prod =
                bin(Op::Mul, Expr::Num(rng.range(2, small)), Expr::Num(rng.range(2, small)));
            node = bin(Op::Add, node, prod);
        } else if node.eval() > max_operand && rng.below(2) == 0 {
            node = bin(Op::Sub, node, Expr::Num(rng.range(1, max_operand)));
        } else {
            node = bin(Op::Add, node, Expr::Num(rng.range(1, max_operand)));
        }
    }
    node
}

fn gen_paren(rng: &mut Rng, max_operand: i64, n_ops: usize) -> Expr {
    let half = max_operand / 2 + 1;
    let inner = bin(Op::Add, Expr::Num(rng.range(1, half)), Expr::Num(rng.range(1, half)));
    let mut node = bin(Op::Mul, inner, Expr::Num(rng.range(2, 5)));
    for _ in 0..n_ops.saturating_sub(2) {
        if node.eval() > 20 && rng.below(2) == 0 {
            node = bin(Op::Sub, node, Expr::Num(rng.range(1, 20)));
        } else {
            node = bin(Op::Add, node, Expr::Num(rng.range(1, max_operand)));
        }
    }
    node
}

fn gen_modular(rng: &mut Rng, max_operand: i64, n_ops: usize) -> Expr {
    let small = (max_operand / 4).clamp(2, 9);
    let mut base = bin(
        Op::Add,
        bin(Op::Mul, Expr::Num(rng.range(2, small)), Expr::Num(rng.range(2, small))),
        Expr::Num(rng.range(1, max_operand)),
    );
    for _ in 0..n_ops.saturating_sub(3) {
        base = bin(Op::Add, base, Expr::Num(rng.range(1, max_operand)));
    }
    bin(Op::Mod, base, Expr::Num(rng.range(3, 9)))
}

/// Generate one problem (mirrors `corpus.gen_problem`).
pub fn gen_problem(
    rng: &mut Rng,
    v: &Vocab,
    family: Family,
    max_operand: i64,
    n_ops: usize,
) -> Problem {
    let expr = match family {
        Family::AddChain => gen_add_chain(rng, max_operand, n_ops),
        Family::MulMix => gen_mul_mix(rng, max_operand, n_ops),
        Family::Paren => gen_paren(rng, max_operand, n_ops),
        Family::Modular => gen_modular(rng, max_operand, n_ops),
    };
    let answer = expr.eval();
    let difficulty = (1 + n_ops as u32
        + u32::from(max_operand > 30)
        + u32::from(matches!(family, Family::Paren | Family::Modular)))
    .min(5);
    let tokens = expr.tokens(v);
    Problem { family, expr, answer, difficulty, tokens }
}

/// Generate a problem guaranteed renderable (answer in [0, 999], short).
pub fn gen_valid_problem(
    rng: &mut Rng,
    v: &Vocab,
    family: Family,
    max_operand: i64,
    n_ops: usize,
) -> Problem {
    loop {
        let p = gen_problem(rng, v, family, max_operand, n_ops);
        if (0..=999).contains(&p.answer) && p.tokens.len() <= 36 {
            return p;
        }
    }
}

/// Parse a user-supplied expression string into a Problem (server path).
pub fn problem_from_text(v: &Vocab, text: &str) -> Result<Problem> {
    let tokens = tokenizer::tokenize_expr(v, text)?;
    let answer = tokenizer::eval_expr(v, &tokens)?;
    let family = if tokens.contains(&v.modulo) {
        Family::Modular
    } else if tokens.contains(&v.lparen) {
        Family::Paren
    } else if tokens.contains(&v.mul) {
        Family::MulMix
    } else {
        Family::AddChain
    };
    let n_ops = tokens
        .iter()
        .filter(|&&t| t == v.plus || t == v.minus || t == v.mul || t == v.modulo)
        .count();
    Ok(Problem {
        family,
        expr: Expr::Num(answer), // AST not reconstructed; tokens are canonical
        answer,
        difficulty: (1 + n_ops as u32).min(5),
        tokens,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::util::prop;

    #[test]
    fn generator_answers_match_token_evaluator() {
        let v = test_vocab();
        prop::check("gen answer == eval(tokens)", 300, |rng| {
            let fam = FAMILIES[rng.below(4) as usize];
            let n_ops = rng.range(2, 4) as usize;
            let p = gen_problem(rng, &v, fam, 50, n_ops);
            let evald = tokenizer::eval_expr(&v, &p.tokens)?;
            anyhow::ensure!(
                evald == p.answer,
                "expr {} evals to {evald}, answer says {}",
                tokenizer::detokenize(&v, &p.tokens),
                p.answer
            );
            Ok(())
        });
    }

    #[test]
    fn valid_problems_renderable() {
        let v = test_vocab();
        prop::check("valid problems in range", 100, |rng| {
            let fam = FAMILIES[rng.below(4) as usize];
            let p = gen_valid_problem(rng, &v, fam, 99, 4);
            anyhow::ensure!((0..=999).contains(&p.answer));
            anyhow::ensure!(p.tokens.len() <= 36);
            anyhow::ensure!(p.difficulty >= 1 && p.difficulty <= 5);
            Ok(())
        });
    }

    #[test]
    fn families_have_signature_ops() {
        let v = test_vocab();
        let mut rng = Rng::new(9);
        let p = gen_problem(&mut rng, &v, Family::Modular, 40, 3);
        assert!(p.tokens.contains(&v.modulo));
        let p = gen_problem(&mut rng, &v, Family::MulMix, 40, 3);
        assert!(p.tokens.contains(&v.mul));
    }

    #[test]
    fn modular_answers_small() {
        let v = test_vocab();
        let mut rng = Rng::new(10);
        for _ in 0..50 {
            let p = gen_problem(&mut rng, &v, Family::Modular, 60, 3);
            assert!((0..9).contains(&p.answer), "mod answer {}", p.answer);
        }
    }

    #[test]
    fn problem_from_text_roundtrip() {
        let v = test_vocab();
        let p = problem_from_text(&v, "(17+25)*3").unwrap();
        assert_eq!(p.answer, 126);
        assert_eq!(p.family, Family::Paren);
        assert!(problem_from_text(&v, "1+").is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let v = test_vocab();
        let a = gen_problem(&mut Rng::new(77), &v, Family::AddChain, 30, 3);
        let b = gen_problem(&mut Rng::new(77), &v, Family::AddChain, 30, 3);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.answer, b.answer);
    }
}
