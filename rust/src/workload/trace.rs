//! Serving-trace record/replay — the workload side of DESIGN.md §17.
//!
//! A *serving trace* is a compact, versioned log of admitted `solve`
//! requests: one JSON header line `{"ssr_trace":1}` followed by one
//! JSON object per request carrying everything needed to replay it
//! decision-for-decision against a pool — arrival offset, tenant,
//! expression text, method (wire name + `paths` + `tau`), seed, QoS
//! class and deadline. The live server appends to such a log behind
//! `--trace-record <path>` ([`TraceWriter`]); benches replay one
//! deterministically (`benches/trace_replay.rs`,
//! `benches/prefix_spill.rs`).
//!
//! Unlike [`super::traces`] (closed-loop problem-level arrival traces
//! for engine benchmarks), this module captures the *serving* surface:
//! entries round-trip through the same wire fields the TCP front end
//! parses (`coordinator::server::parse_method`, `QosClass::parse`), so
//! a recorded trace replays with zero drift and a hand-written one is
//! validated by the same parsers the socket path uses.
//!
//! Three synthetic generator presets produce the arrival shapes the
//! overload and caching work cares about: [`heavy_tailed`]
//! (Zipf-skewed repeated prompts + Pareto interarrivals), [`diurnal`]
//! (sinusoidal rate swing) and [`flash_crowd`] (mid-trace burst of one
//! hot prompt). All are pure functions of their [`GenSpec`].

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::tokenizer;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

use super::problems::{self, FAMILIES};

/// Trace format version — the header line's `ssr_trace` value. Bump on
/// any incompatible record-shape change; `load` refuses other versions.
pub const TRACE_VERSION: i64 = 1;

/// One recorded `solve` request. Field names match the wire protocol
/// (PROTOCOL.md) wherever a wire field exists, so `to_value()` output
/// feeds `parse_method` directly.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    /// arrival offset from trace start, milliseconds
    pub offset_ms: u64,
    pub tenant: Option<String>,
    pub expr: String,
    /// wire method name (`ssr`, `parallel-spm`, ...)
    pub method: String,
    pub paths: usize,
    pub tau: u8,
    pub seed: u64,
    /// QoS class wire name (`interactive` | `batch` | `best_effort`)
    pub class: String,
    /// 0 = no deadline
    pub deadline_ms: u64,
}

impl TraceEntry {
    /// Render as one trace record. The object doubles as a `solve`
    /// request body minus `op`: `parse_method(&e.to_value(), ..)` is
    /// the supported replay path.
    pub fn to_value(&self) -> Value {
        let mut pairs = vec![
            ("offset_ms", json::i(self.offset_ms as i64)),
            ("expr", json::s(self.expr.clone())),
            ("method", json::s(self.method.clone())),
            ("paths", json::i(self.paths as i64)),
            ("tau", json::i(self.tau as i64)),
            ("seed", json::i(self.seed as i64)),
            ("class", json::s(self.class.clone())),
            ("deadline_ms", json::i(self.deadline_ms as i64)),
        ];
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", json::s(t.clone())));
        }
        json::obj(pairs)
    }

    pub fn from_value(v: &Value) -> Result<TraceEntry> {
        Ok(TraceEntry {
            offset_ms: v.get_i64("offset_ms")?.max(0) as u64,
            tenant: v.opt("tenant").map(|t| t.str().map(String::from)).transpose()?,
            expr: v.get_str("expr")?.to_string(),
            method: v.get_str("method")?.to_string(),
            paths: v.get_usize("paths")?,
            tau: v.get_i64("tau")? as u8,
            seed: v.get_i64("seed")? as u64,
            class: v.get_str("class")?.to_string(),
            deadline_ms: v.get_i64("deadline_ms")?.max(0) as u64,
        })
    }
}

/// Appends entries to a trace file, one flushed JSON line each, so a
/// crashed or killed server still leaves a replayable prefix. Created
/// by the server when `--trace-record` is set.
pub struct TraceWriter {
    out: BufWriter<File>,
}

impl TraceWriter {
    /// Create (truncating) `path` and write the version header line.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating trace dir {}", dir.display()))?;
            }
        }
        let file =
            File::create(path).with_context(|| format!("creating trace {}", path.display()))?;
        let mut out = BufWriter::new(file);
        writeln!(out, "{}", json::obj(vec![("ssr_trace", json::i(TRACE_VERSION))]).print())?;
        out.flush()?;
        Ok(TraceWriter { out })
    }

    pub fn record(&mut self, e: &TraceEntry) -> Result<()> {
        writeln!(self.out, "{}", e.to_value().print())?;
        self.out.flush()?;
        Ok(())
    }
}

/// Load a trace, validating the version header. Blank lines are
/// skipped; any malformed record is an error (traces are machine
/// written — a bad line means truncation mid-record or version skew,
/// not style).
pub fn load(path: &Path) -> Result<Vec<TraceEntry>> {
    let file = File::open(path).with_context(|| format!("opening trace {}", path.display()))?;
    let mut lines = BufReader::new(file).lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("trace {} is empty (missing header line)", path.display()),
        }
    };
    let v = Value::parse(&header).context("parsing trace header")?;
    let version = v.get_i64("ssr_trace").context("trace header")?;
    if version != TRACE_VERSION {
        bail!("unsupported trace version {version} (this build reads {TRACE_VERSION})");
    }
    let mut out = Vec::new();
    for (i, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(&line).with_context(|| format!("trace record {}", i + 1))?;
        out.push(
            TraceEntry::from_value(&v).with_context(|| format!("trace record {}", i + 1))?,
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// synthetic generator presets
// ---------------------------------------------------------------------

/// Parameters shared by the synthetic trace generators.
#[derive(Debug, Clone, Copy)]
pub struct GenSpec {
    /// total requests
    pub n: usize,
    /// distinct prompts in the pool (popularity rank 0 is hottest)
    pub pool: usize,
    /// mean arrival rate, requests per virtual second
    pub rate_rps: f64,
    pub seed: u64,
}

impl Default for GenSpec {
    fn default() -> GenSpec {
        GenSpec { n: 64, pool: 8, rate_rps: 50.0, seed: 0x7ACE }
    }
}

/// (wire name, mix weight) — names must stay parseable by
/// `coordinator::server::parse_method` (pinned by a test below).
const METHODS: [(&str, f64); 7] = [
    ("ssr", 4.0),
    ("ssr-fast1", 1.0),
    ("ssr-fast2", 1.0),
    ("parallel", 2.0),
    ("parallel-spm", 1.0),
    ("spec-reason", 1.0),
    ("baseline", 1.0),
];
const CLASSES: [(&str, f64); 3] = [("interactive", 7.0), ("batch", 2.0), ("best_effort", 1.0)];
const TENANTS: [(&str, f64); 4] =
    [("acme", 5.0), ("globex", 2.0), ("initech", 2.0), ("hooli", 1.0)];

fn pick<'a>(rng: &mut Rng, table: &[(&'a str, f64)]) -> &'a str {
    let weights: Vec<f64> = table.iter().map(|(_, w)| *w).collect();
    table[rng.choice_weighted(&weights)].0
}

/// Render `spec.pool` distinct prompt strings (rank 0 first), drawn
/// from the procedural problem families so every expr parses back
/// through `problem_from_text`.
fn prompt_pool(spec: &GenSpec, rng: &mut Rng) -> Vec<String> {
    let v = tokenizer::builtin_vocab();
    (0..spec.pool.max(1))
        .map(|i| {
            let fam = FAMILIES[i % FAMILIES.len()];
            let p = problems::gen_valid_problem(rng, &v, fam, 40, 2 + i % 3);
            tokenizer::detokenize(&v, &p.tokens)
        })
        .collect()
}

/// One synthetic request against `prompt` at virtual time `t_s`.
fn entry_at(t_s: f64, prompt: &str, rng: &mut Rng) -> TraceEntry {
    let method = pick(rng, &METHODS).to_string();
    let class = pick(rng, &CLASSES).to_string();
    let deadline_ms = if class == "interactive" { rng.range(2_000, 8_000) as u64 } else { 0 };
    TraceEntry {
        offset_ms: (t_s * 1_000.0) as u64,
        tenant: Some(pick(rng, &TENANTS).to_string()),
        expr: prompt.to_string(),
        method,
        paths: [2usize, 4, 8][rng.below(3) as usize],
        tau: rng.range(5, 9) as u8,
        seed: rng.below(1 << 32),
        class,
        deadline_ms,
    }
}

/// Zipf-skewed repeated prompts (exponent 1.2, rank 0 dominates) with
/// Pareto(α = 1.5) interarrivals: bursts of near-simultaneous arrivals
/// plus a heavy tail of long gaps, mean gap ≈ `1/rate_rps` (capped at
/// 100× the mean so one tail draw cannot stall a replay).
pub fn heavy_tailed(spec: &GenSpec) -> Vec<TraceEntry> {
    let mut rng = Rng::new(spec.seed);
    let pool = prompt_pool(spec, &mut rng);
    let zipf: Vec<f64> = (0..pool.len()).map(|i| 1.0 / ((i + 1) as f64).powf(1.2)).collect();
    let alpha = 1.5;
    let xm = (alpha - 1.0) / (alpha * spec.rate_rps.max(1e-6));
    let mut t = 0.0;
    (0..spec.n)
        .map(|_| {
            let dt = xm * rng.f64().max(1e-12).powf(-1.0 / alpha);
            t += dt.min(100.0 / spec.rate_rps.max(1e-6));
            let k = rng.choice_weighted(&zipf);
            entry_at(t, &pool[k], &mut rng)
        })
        .collect()
}

/// Sinusoidal rate swing (±80% around `rate_rps`, two full cycles over
/// the trace) with uniform prompt popularity — the slow cache
/// warm/cool shape the spill tier rides through.
pub fn diurnal(spec: &GenSpec) -> Vec<TraceEntry> {
    let mut rng = Rng::new(spec.seed);
    let pool = prompt_pool(spec, &mut rng);
    let period_s = (spec.n as f64 / spec.rate_rps.max(1e-6) / 2.0).max(1e-3);
    let mut t = 0.0;
    (0..spec.n)
        .map(|_| {
            let phase = (2.0 * std::f64::consts::PI * t / period_s).sin();
            let rate = (spec.rate_rps * (1.0 + 0.8 * phase)).max(0.05 * spec.rate_rps);
            t += -rng.f64().max(1e-12).ln() / rate;
            let k = rng.below(pool.len() as u64) as usize;
            entry_at(t, &pool[k], &mut rng)
        })
        .collect()
}

/// Steady Poisson baseline with a 10× burst over the middle fifth of
/// the trace, every burst request hitting the rank-0 prompt — the
/// flash-crowd shape admission control and the prefix tiers absorb.
pub fn flash_crowd(spec: &GenSpec) -> Vec<TraceEntry> {
    let mut rng = Rng::new(spec.seed);
    let pool = prompt_pool(spec, &mut rng);
    let (burst_lo, burst_hi) = (2 * spec.n / 5, 3 * spec.n / 5);
    let mut t = 0.0;
    (0..spec.n)
        .map(|i| {
            let burst = (burst_lo..burst_hi).contains(&i);
            let rate = if burst { 10.0 * spec.rate_rps } else { spec.rate_rps };
            t += -rng.f64().max(1e-12).ln() / rate.max(1e-6);
            let k = if burst { 0 } else { rng.below(pool.len() as u64) as usize };
            entry_at(t, &pool[k], &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::QosClass;
    use crate::coordinator::server::parse_method;
    use crate::model::tokenizer::builtin_vocab;
    use crate::workload::problems::problem_from_text;
    use std::path::PathBuf;

    fn tmp_trace(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ssr-trace-{name}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn file_round_trip_and_version_gate() {
        let path = tmp_trace("roundtrip");
        let entries = heavy_tailed(&GenSpec { n: 12, ..GenSpec::default() });
        {
            let mut w = TraceWriter::create(&path).unwrap();
            for e in &entries {
                w.record(e).unwrap();
            }
        }
        assert_eq!(load(&path).unwrap(), entries);
        // a future version is refused, not misread
        std::fs::write(&path, "{\"ssr_trace\":99}\n").unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::write(&path, "").unwrap();
        assert!(load(&path).is_err(), "empty trace must not load");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn generators_are_deterministic_and_serve_ready() {
        let spec = GenSpec { n: 40, ..GenSpec::default() };
        let vocab = builtin_vocab();
        for (name, gen) in [
            ("heavy_tailed", heavy_tailed as fn(&GenSpec) -> Vec<TraceEntry>),
            ("diurnal", diurnal),
            ("flash_crowd", flash_crowd),
        ] {
            let a = gen(&spec);
            assert_eq!(a, gen(&spec), "{name}: not deterministic");
            assert_eq!(a.len(), spec.n, "{name}");
            let mut last = 0;
            for e in &a {
                assert!(e.offset_ms >= last, "{name}: offsets must be nondecreasing");
                last = e.offset_ms;
                // every record must replay through the real wire parsers
                parse_method(&e.to_value(), 5, 7).unwrap();
                QosClass::parse(&e.class).unwrap();
                problem_from_text(&vocab, &e.expr).unwrap();
                assert_eq!(e, &TraceEntry::from_value(&e.to_value()).unwrap(), "{name}");
            }
        }
    }

    #[test]
    fn heavy_tailed_is_actually_skewed() {
        let spec = GenSpec { n: 200, pool: 8, ..GenSpec::default() };
        let t = heavy_tailed(&spec);
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        for e in &t {
            *counts.entry(e.expr.as_str()).or_default() += 1;
        }
        assert!(counts.len() >= 2, "trace must mix prompts, got {}", counts.len());
        let hottest = counts.values().copied().max().unwrap();
        assert!(hottest * 4 > spec.n, "hottest prompt only {hottest}/{}", spec.n);
    }
}
