//! The strategy pool (paper §3.1 / Appendix D): K = 12 interpretable
//! reasoning strategies + "Unknown", each mapping to a decomposition
//! style with a per-family aptitude. Metadata comes from the artifact
//! manifest (single source of truth shared with the training corpus);
//! a built-in copy backs manifest-free paths (calibrated experiments,
//! property tests).

use crate::runtime::manifest::StrategyMeta;
use crate::workload::problems::Family;

pub const NUM_STRATEGIES: usize = 13; // A..L + M(unknown)
pub const NUM_REAL_STRATEGIES: usize = 12;
pub const UNKNOWN_STRATEGY: usize = 12;

/// Paper Appendix-D strategy names, in token order A..M.
pub const STRATEGY_NAMES: [&str; NUM_STRATEGIES] = [
    "algebraic_simplification",
    "clever_substitution",
    "coordinate_geometry",
    "complex_numbers",
    "number_theory",
    "combinatorics",
    "probability",
    "functional_equations",
    "recursion_invariants",
    "geometry",
    "casework_constructive",
    "calculus_inequalities",
    "unknown",
];

/// Decomposition styles (indices match `corpus.py`).
pub const STYLE_NAMES: [&str; 6] =
    ["l2r", "prec_first", "paren_first", "rtl", "tens", "mod_reduce"];

/// strategy index -> style index (strategy M has no fixed style).
pub const STRATEGY_STYLE: [usize; NUM_REAL_STRATEGIES] = [1, 2, 0, 3, 5, 4, 1, 0, 3, 2, 4, 5];

/// style x family aptitude in [0,1] (mirrors corpus.STYLE_APTITUDE).
pub const STYLE_APTITUDE: [[f64; 4]; 6] = [
    [0.95, 0.35, 0.30, 0.40], // l2r
    [0.80, 0.95, 0.55, 0.55], // prec_first
    [0.70, 0.70, 0.95, 0.50], // paren_first
    [0.45, 0.25, 0.25, 0.30], // rtl
    [0.90, 0.45, 0.40, 0.35], // tens
    [0.30, 0.30, 0.30, 0.95], // mod_reduce
];

/// Static pool used when no manifest is loaded.
pub fn builtin_meta() -> StrategyMeta {
    StrategyMeta {
        names: STRATEGY_NAMES.iter().map(|s| s.to_string()).collect(),
        styles: STRATEGY_STYLE.to_vec(),
        style_names: STYLE_NAMES.iter().map(|s| s.to_string()).collect(),
        aptitude: STYLE_APTITUDE.iter().map(|row| row.to_vec()).collect(),
    }
}

/// Aptitude of `strategy` for `family` per the pool metadata.
pub fn aptitude(meta: &StrategyMeta, strategy: usize, family: Family) -> f64 {
    if strategy >= meta.styles.len() {
        return 0.40; // Unknown
    }
    meta.aptitude[meta.styles[strategy]][family as usize]
}

/// The best-aptitude ordering of strategies for a family (ground truth
/// the SPM selector is measured against in the ablation).
pub fn oracle_ranking(meta: &StrategyMeta, family: Family) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..NUM_REAL_STRATEGIES).collect();
    idx.sort_by(|&a, &b| {
        aptitude(meta, b, family)
            .partial_cmp(&aptitude(meta, a, family))
            .unwrap()
            .then(a.cmp(&b))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_meta_consistent() {
        let m = builtin_meta();
        assert_eq!(m.names.len(), NUM_STRATEGIES);
        assert_eq!(m.styles.len(), NUM_REAL_STRATEGIES);
        assert!(m.styles.iter().all(|&s| s < m.style_names.len()));
        for row in &m.aptitude {
            assert_eq!(row.len(), 4);
            assert!(row.iter().all(|&a| (0.0..=1.0).contains(&a)));
        }
    }

    #[test]
    fn aptitude_matches_table() {
        let m = builtin_meta();
        // strategy E (number_theory, idx 4) -> mod_reduce, best on Modular
        assert_eq!(aptitude(&m, 4, Family::Modular), 0.95);
        // unknown strategy gets the flat prior
        assert_eq!(aptitude(&m, UNKNOWN_STRATEGY, Family::AddChain), 0.40);
    }

    #[test]
    fn oracle_ranking_sorted() {
        let m = builtin_meta();
        for fam in crate::workload::problems::FAMILIES {
            let rank = oracle_ranking(&m, fam);
            assert_eq!(rank.len(), NUM_REAL_STRATEGIES);
            for w in rank.windows(2) {
                assert!(aptitude(&m, w[0], fam) >= aptitude(&m, w[1], fam));
            }
        }
        // modular family ranks a mod_reduce strategy first
        let top = oracle_ranking(&m, Family::Modular)[0];
        assert_eq!(STRATEGY_STYLE[top], 5);
    }
}
