//! Serving workload traces: request arrival processes over suite
//! problems, used by the throughput benchmarks and the e2e example
//! (`examples/serve_trace.rs`). Stands in for the request logs the
//! paper's 4xA800 latency numbers were measured on.

use crate::util::rng::Rng;
use crate::workload::problems::Problem;
use crate::workload::suites::Suite;

#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: u64,
    /// offset from trace start, seconds
    pub arrival_s: f64,
    pub problem: Problem,
}

#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

/// Poisson arrivals at `rate_rps` over `n` requests sampled (with
/// replacement) from the suite.
pub fn poisson_trace(suite: &Suite, n: usize, rate_rps: f64, seed: u64) -> Trace {
    assert!(rate_rps > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut requests = Vec::with_capacity(n);
    for id in 0..n {
        // exponential inter-arrival
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate_rps;
        let p = &suite.problems[rng.below(suite.problems.len() as u64) as usize];
        requests.push(TraceRequest { id: id as u64, arrival_s: t, problem: p.clone() });
    }
    Trace { requests }
}

/// All requests at t=0 (offline batch evaluation shape).
pub fn batch_trace(suite: &Suite, n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let requests = (0..n)
        .map(|id| {
            let p = &suite.problems[rng.below(suite.problems.len() as u64) as usize];
            TraceRequest { id: id as u64, arrival_s: 0.0, problem: p.clone() }
        })
        .collect();
    Trace { requests }
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::workload::suites::{generate, spec};

    fn suite() -> Suite {
        generate(spec("synth-aime").unwrap(), &test_vocab())
    }

    #[test]
    fn poisson_arrivals_monotone_and_rate_plausible() {
        let t = poisson_trace(&suite(), 500, 10.0, 1);
        assert_eq!(t.len(), 500);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        // 500 requests at 10 rps ~ 50s; loose 3-sigma bound
        assert!((30.0..80.0).contains(&t.duration_s()), "{}", t.duration_s());
    }

    #[test]
    fn batch_trace_all_at_zero() {
        let t = batch_trace(&suite(), 10, 2);
        assert!(t.requests.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn traces_deterministic() {
        let a = poisson_trace(&suite(), 20, 5.0, 7);
        let b = poisson_trace(&suite(), 20, 5.0, 7);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.problem.answer, y.problem.answer);
        }
    }
}
