//! Normalized FLOPs (gamma) — the paper's Appendix B closed forms plus a
//! measured ledger that the experiments compare against the analytic
//! model (EXPERIMENTS.md reports both).
//!
//! Notation (paper Table 2):
//!   N      parallel paths,
//!   T_base tokens of a baseline (single-path target) trace,
//!   T      tokens per speculative path,  beta = T / T_base,
//!   F_t / F_d  per-token FLOPs of target / draft,  alpha = F_d / F_t,
//!   R      fraction of tokens rewritten by the target.

/// gamma_base = 1 (Eq. 6).
pub fn gamma_base() -> f64 {
    1.0
}

/// gamma_parallel = N (Eq. 8).
pub fn gamma_parallel(n: usize) -> f64 {
    n as f64
}

/// gamma_spec = N * beta * (R + (1 - R) * alpha)  (Eq. 11, the paper's
/// boxed form). NOTE: the paper's Appendix B is internally inconsistent —
/// Eq. 9 derives the per-path cost as T*F_t*(alpha + R) (the draft
/// processes *every* token, the target re-processes the rewritten
/// fraction), but Eq. 10/11 prints N*beta*(R + (1-R)*alpha). We implement
/// both; the measured ledger matches [`gamma_spec_eq9`], and
/// EXPERIMENTS.md documents the discrepancy.
pub fn gamma_spec(n: usize, beta: f64, r: f64, alpha: f64) -> f64 {
    n as f64 * beta * (r + (1.0 - r) * alpha)
}

/// gamma per Eq. 9's derivation: N * beta * (alpha + R).
pub fn gamma_spec_eq9(n: usize, beta: f64, r: f64, alpha: f64) -> f64 {
    n as f64 * beta * (alpha + r)
}

/// Prompt-prefill tokens one problem costs WITHOUT prefix reuse: each
/// of the N lanes prefills the full prompt (shared prompt P plus its
/// per-lane strategy suffix S), and SPM methods pay one extra bare-
/// prompt scoring prefill — the (N+1)·P + N·S the prefix-reuse tentpole
/// removes (DESIGN.md §2).
pub fn prefill_tokens_per_lane(n: usize, prompt: u64, suffix: u64, spm_pass: bool) -> u64 {
    let n = n as u64;
    (n + spm_pass as u64) * prompt + n * suffix
}

/// Prompt-prefill tokens WITH the shared-prefix fork: the prompt is
/// prefilled once (the same pass yields the SPM scores) and each lane
/// ingests only its suffix: P + N·S. A prefix-cache hit drops even the
/// P term; this form is the cold-start bound.
pub fn prefill_tokens_shared(n: usize, prompt: u64, suffix: u64) -> u64 {
    prompt + n as u64 * suffix
}

/// Fraction of per-lane prefill tokens the shared-prefix open removes.
pub fn prefix_prefill_saving(n: usize, prompt: u64, suffix: u64, spm_pass: bool) -> f64 {
    let per_lane = prefill_tokens_per_lane(n, prompt, suffix, spm_pass);
    if per_lane == 0 {
        return 0.0;
    }
    1.0 - prefill_tokens_shared(n, prompt, suffix) as f64 / per_lane as f64
}

/// Expected compute per step per path, C_step = C_d + R*C_t (Eq. 3),
/// in units of C_t.
pub fn step_cost_ratio(r: f64, alpha: f64) -> f64 {
    alpha + r
}

/// Resource saving ratio of Eq. 4: (n/K) * (C_d + R*C_t)/C_t.
pub fn resource_saving(n: usize, k: usize, r: f64, alpha: f64) -> f64 {
    (n as f64 / k as f64) * step_cost_ratio(r, alpha)
}

/// Measured FLOPs ledger for one inference method run, normalized against
/// a measured baseline cost.
///
/// THE canonical gamma accounting — `eval::experiments` and the benches
/// normalize through this one type so every BENCH_JSON gamma scalar
/// agrees. The convention (Eq. 9): draft tokens cost `alpha` units,
/// rewritten target tokens cost 1 unit, and *scored-but-not-rewritten*
/// tokens are excluded — scoring rides the target's verify pass, whose
/// cost Eq. 9 already folds into the rewrite term, so counting score
/// tokens again would double-bill the verify pass. They are tracked
/// (`score_tokens`) for visibility but never enter [`cost_units`].
///
/// [`cost_units`]: MeasuredGamma::cost_units
#[derive(Debug, Clone, Default)]
pub struct MeasuredGamma {
    pub draft_tokens: u64,
    pub target_tokens: u64,
    /// scored-but-not-rewritten tokens — visible, never billed
    pub score_tokens: u64,
    pub alpha: f64,
}

impl MeasuredGamma {
    pub fn new(alpha: f64) -> Self {
        MeasuredGamma { alpha, ..Default::default() }
    }

    pub fn add_tokens(&mut self, draft: u64, target: u64) {
        self.draft_tokens += draft;
        self.target_tokens += target;
    }

    /// Record scored-but-not-rewritten tokens (excluded from the bill;
    /// see the type docs).
    pub fn add_score_tokens(&mut self, score: u64) {
        self.score_tokens += score;
    }

    /// Cost in units of target-token FLOPs.
    pub fn cost_units(&self) -> f64 {
        self.target_tokens as f64 + self.alpha * self.draft_tokens as f64
    }

    /// gamma relative to a baseline that consumed `base_target_tokens`.
    pub fn gamma(&self, base_target_tokens: f64) -> f64 {
        if base_target_tokens <= 0.0 {
            return f64::NAN;
        }
        self.cost_units() / base_target_tokens
    }

    /// gamma of a multi-run ledger against a *per-run* baseline cost —
    /// the normalization `eval::experiments::run_method` and the
    /// gamma benches share.
    pub fn gamma_per_run(&self, runs: f64, base_target_tokens_per_run: f64) -> f64 {
        self.gamma(base_target_tokens_per_run * runs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gen};
    use anyhow::ensure;

    #[test]
    fn closed_forms_paper_values() {
        assert_eq!(gamma_base(), 1.0);
        assert_eq!(gamma_parallel(5), 5.0);
        // paper example shape: n=5 of K=12, alpha=0.047, R=0.2:
        // gamma_spec with beta=1 = 5*(0.2 + 0.8*0.047) = 1.188
        let g = gamma_spec(5, 1.0, 0.2, 0.047);
        assert!((g - 1.188).abs() < 1e-9, "{g}");
        // Eq. 4: (5/12)*(0.047+0.2) ~ 0.103
        let s = resource_saving(5, 12, 0.2, 0.047);
        assert!((s - 5.0 / 12.0 * 0.247).abs() < 1e-12);
    }

    #[test]
    fn gamma_spec_bounds() {
        prop::check("0 <= gamma_spec <= N*beta for R,alpha in [0,1]", 500, |rng| {
            let n = 1 + gen::index(rng, 12);
            let beta = gen::f64_in(rng, 0.1, 3.0);
            let r = rng.f64();
            let alpha = rng.f64();
            let g = gamma_spec(n, beta, r, alpha);
            ensure!(g >= 0.0);
            ensure!(g <= n as f64 * beta + 1e-12, "g={g} > N*beta");
            // with a perfect draft (R=0) cost is alpha-scaled
            let g0 = gamma_spec(n, beta, 0.0, alpha);
            ensure!((g0 - n as f64 * beta * alpha).abs() < 1e-12);
            Ok(())
        });
    }

    #[test]
    fn gamma_spec_monotone_in_rewrite_rate() {
        prop::check("gamma_spec monotone in R when alpha<1", 200, |rng| {
            let n = 1 + gen::index(rng, 8);
            let beta = gen::f64_in(rng, 0.2, 2.0);
            let alpha = gen::f64_in(rng, 0.0, 0.99);
            let r1 = rng.f64() * 0.5;
            let r2 = r1 + rng.f64() * 0.5;
            ensure!(gamma_spec(n, beta, r1, alpha) <= gamma_spec(n, beta, r2, alpha) + 1e-12);
            Ok(())
        });
    }

    #[test]
    fn measured_gamma_matches_eq9_on_synthetic_counts() {
        // N=3 paths, T=100 tokens each, R=0.25, alpha=0.1, T_base=100:
        // draft processes N*T, target rewrites the R fraction. This is
        // exactly Eq. 9's derivation (see gamma_spec doc comment for the
        // paper's Eq. 9 vs Eq. 11 inconsistency).
        let alpha = 0.1;
        let (n, t, r) = (3u64, 100u64, 0.25);
        let mut m = MeasuredGamma::new(alpha);
        m.add_tokens(n * t, (n as f64 * t as f64 * r) as u64);
        let measured = m.gamma(t as f64);
        let eq9 = gamma_spec_eq9(n as usize, 1.0, r, alpha);
        assert!((measured - eq9).abs() < 1e-9, "{measured} vs {eq9}");
        // Eq. 11 differs by exactly R*alpha*N*beta
        let eq11 = gamma_spec(n as usize, 1.0, r, alpha);
        assert!((eq9 - eq11 - 3.0 * 0.25 * 0.1).abs() < 1e-9);
    }

    #[test]
    fn gamma_handles_zero_baseline() {
        let m = MeasuredGamma::new(0.1);
        assert!(m.gamma(0.0).is_nan());
    }

    #[test]
    fn score_tokens_are_visible_but_never_billed() {
        let mut m = MeasuredGamma::new(0.1);
        m.add_tokens(100, 30);
        let before = m.cost_units();
        m.add_score_tokens(500);
        assert_eq!(m.score_tokens, 500);
        assert_eq!(m.cost_units(), before, "score tokens entered the bill");
        // per-run normalization: 2 runs against a 20-token baseline is
        // the same gamma as one 40-token baseline
        assert!((m.gamma_per_run(2.0, 20.0) - m.gamma(40.0)).abs() < 1e-12);
    }

    #[test]
    fn prefill_closed_forms() {
        // ISSUE acceptance shape: (N+1)·|prompt| + N·|suffix| -> |prompt| + N·|suffix|
        assert_eq!(prefill_tokens_per_lane(5, 20, 1, true), 6 * 20 + 5);
        assert_eq!(prefill_tokens_per_lane(5, 20, 0, false), 5 * 20);
        assert_eq!(prefill_tokens_shared(5, 20, 1), 20 + 5);
        assert_eq!(prefill_tokens_shared(5, 20, 0), 20);
        let s = prefix_prefill_saving(5, 20, 1, true);
        assert!((s - (1.0 - 25.0 / 125.0)).abs() < 1e-12, "{s}");
        // saving grows with N and with prompt length
        assert!(
            prefix_prefill_saving(8, 20, 1, true) > prefix_prefill_saving(4, 20, 1, true)
        );
        assert!(
            prefix_prefill_saving(5, 200, 1, true) > prefix_prefill_saving(5, 20, 1, true)
        );
        assert_eq!(prefix_prefill_saving(0, 0, 0, false), 0.0);
    }
}
