//! Answer aggregation (paper §3.2): majority voting over the parallel
//! paths' final answers, with score-based voting (mean step score, PRM
//! style) breaking ties — rewritten steps count as score 9, "reflecting
//! stronger confidence from the large model".

use std::collections::BTreeMap;

/// One finished path's vote.
#[derive(Debug, Clone, PartialEq)]
pub struct PathVote {
    pub answer: Option<i64>,
    /// 0..=9 scores of its accepted steps (rewrites recorded as 9)
    pub step_scores: Vec<u8>,
}

impl PathVote {
    pub fn mean_score(&self) -> f64 {
        if self.step_scores.is_empty() {
            return 0.0;
        }
        self.step_scores.iter().map(|&s| s as f64).sum::<f64>() / self.step_scores.len() as f64
    }
}

/// Outcome of aggregation, with the decision trail for logging.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    Majority { answer: i64, votes: usize },
    ScoreBased { answer: i64, mean_score: f64 },
    NoAnswer,
}

impl Decision {
    pub fn answer(&self) -> Option<i64> {
        match self {
            Decision::Majority { answer, .. } | Decision::ScoreBased { answer, .. } => {
                Some(*answer)
            }
            Decision::NoAnswer => None,
        }
    }
}

/// Aggregate path votes. Deterministic under permutation of `votes`
/// (ties inside score-voting break toward the smaller answer).
pub fn aggregate(votes: &[PathVote]) -> Decision {
    let mut counts: BTreeMap<i64, usize> = BTreeMap::new();
    for v in votes {
        if let Some(a) = v.answer {
            *counts.entry(a).or_insert(0) += 1;
        }
    }
    if counts.is_empty() {
        return Decision::NoAnswer;
    }
    let best = counts.values().copied().max().unwrap();
    let leaders: Vec<i64> =
        counts.iter().filter(|(_, &c)| c == best).map(|(&a, _)| a).collect();
    if leaders.len() == 1 && best > 1 {
        return Decision::Majority { answer: leaders[0], votes: best };
    }
    // Tie (or all answers distinct): score-based voting among the tied
    // leaders' paths — highest mean step score wins.
    let mut best_answer = None;
    let mut best_score = f64::NEG_INFINITY;
    for v in votes {
        let Some(a) = v.answer else { continue };
        if !leaders.contains(&a) {
            continue;
        }
        let s = v.mean_score();
        let better = s > best_score
            || (s == best_score && best_answer.map_or(true, |b| a < b));
        if better {
            best_score = s;
            best_answer = Some(a);
        }
    }
    match best_answer {
        Some(answer) => Decision::ScoreBased { answer, mean_score: best_score },
        None => Decision::NoAnswer,
    }
}

/// pass@k: does any of the top-k *distinct* answers (ranked by vote count
/// then mean score) match the gold answer?
pub fn pass_at_k(votes: &[PathVote], gold: i64, k: usize) -> bool {
    let mut by_answer: BTreeMap<i64, (usize, f64)> = BTreeMap::new();
    for v in votes {
        if let Some(a) = v.answer {
            let e = by_answer.entry(a).or_insert((0, f64::NEG_INFINITY));
            e.0 += 1;
            e.1 = e.1.max(v.mean_score());
        }
    }
    let mut ranked: Vec<(i64, usize, f64)> =
        by_answer.into_iter().map(|(a, (c, s))| (a, c, s)).collect();
    ranked.sort_by(|x, y| {
        y.1.cmp(&x.1)
            .then(y.2.partial_cmp(&x.2).unwrap_or(std::cmp::Ordering::Equal))
            .then(x.0.cmp(&y.0))
    });
    ranked.iter().take(k).any(|&(a, _, _)| a == gold)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use anyhow::ensure;

    fn vote(answer: Option<i64>, scores: &[u8]) -> PathVote {
        PathVote { answer, step_scores: scores.to_vec() }
    }

    #[test]
    fn clear_majority_wins() {
        let votes =
            [vote(Some(7), &[5]), vote(Some(7), &[2]), vote(Some(3), &[9, 9])];
        assert_eq!(aggregate(&votes), Decision::Majority { answer: 7, votes: 2 });
    }

    #[test]
    fn tie_resolved_by_score() {
        let votes = [vote(Some(7), &[5, 5]), vote(Some(3), &[9, 9])];
        match aggregate(&votes) {
            Decision::ScoreBased { answer, mean_score } => {
                assert_eq!(answer, 3);
                assert_eq!(mean_score, 9.0);
            }
            d => panic!("expected score-based, got {d:?}"),
        }
    }

    #[test]
    fn all_distinct_uses_scores() {
        let votes =
            [vote(Some(1), &[4]), vote(Some(2), &[8]), vote(Some(3), &[6])];
        assert_eq!(aggregate(&votes).answer(), Some(2));
    }

    #[test]
    fn no_answers() {
        assert_eq!(aggregate(&[vote(None, &[9])]), Decision::NoAnswer);
        assert_eq!(aggregate(&[]), Decision::NoAnswer);
    }

    #[test]
    fn none_votes_ignored_in_majority() {
        let votes = [vote(None, &[]), vote(Some(5), &[7]), vote(Some(5), &[6])];
        assert_eq!(aggregate(&votes).answer(), Some(5));
    }

    #[test]
    fn permutation_invariant() {
        prop::check("aggregate permutation-invariant", 300, |rng| {
            let n = 1 + rng.below(6) as usize;
            let mut votes: Vec<PathVote> = (0..n)
                .map(|_| {
                    let ans =
                        if rng.below(5) == 0 { None } else { Some(rng.below(4) as i64) };
                    let scores: Vec<u8> =
                        (0..1 + rng.below(4)).map(|_| rng.below(10) as u8).collect();
                    PathVote { answer: ans, step_scores: scores }
                })
                .collect();
            let d1 = aggregate(&votes);
            rng.shuffle(&mut votes);
            let d2 = aggregate(&votes);
            ensure!(d1.answer() == d2.answer(), "{d1:?} vs {d2:?}");
            Ok(())
        });
    }

    #[test]
    fn pass_at_k_ranking() {
        let votes = [
            vote(Some(10), &[9]),
            vote(Some(10), &[8]),
            vote(Some(20), &[9, 9]),
            vote(Some(30), &[1]),
        ];
        assert!(pass_at_k(&votes, 10, 1)); // 2 votes beats 1
        assert!(!pass_at_k(&votes, 20, 1));
        assert!(pass_at_k(&votes, 20, 2));
        assert!(pass_at_k(&votes, 30, 3));
        assert!(!pass_at_k(&votes, 99, 4));
    }

    #[test]
    fn majority_answer_always_wins_pass_at_1() {
        prop::check("aggregate majority in top-1 of pass@k ranking", 200, |rng| {
            let n = 2 + rng.below(5) as usize;
            let votes: Vec<PathVote> = (0..n)
                .map(|_| vote(Some(rng.below(3) as i64), &[rng.below(10) as u8]))
                .collect();
            if let Decision::Majority { answer, .. } = aggregate(&votes) {
                ensure!(pass_at_k(&votes, answer, 1));
            }
            Ok(())
        });
    }
}
