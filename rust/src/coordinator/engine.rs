//! The SSR engine: drives a [`Backend`] through the paper's inference
//! methods — baseline decoding, naive/SPM parallel scaling, sequential
//! speculative reasoning (spec-reason), and full SSR = SPM + step-level
//! speculative decoding + answer aggregation + fast modes.
//!
//! The step loop lives in [`ProblemRun`], a *resumable* per-problem
//! state machine: it owns the problem's lanes, fast-mode stop logic and
//! accounting, and advances exactly one reasoning step each time a tick
//! feeds it a batch of outcomes. [`step_tick`] executes one batched
//! draft/score/accept|rewrite (or target) cycle over the union of
//! active lanes of *any number* of in-flight runs — one run when called
//! from [`Engine::run`] (the single-problem wrapper the eval layer
//! uses), many when called from the cross-request scheduler
//! (`coordinator::scheduler`), which is how lanes from different
//! requests come to share backend batches.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use anyhow::Result;

use super::aggregation::{aggregate, Decision, PathVote};
use super::prefix::{Acquired, PrefixCache, PrefixProvider};
use super::spm;
use crate::backend::{
    severity_of, Backend, FaultSeverity, LaneSnapshot, PathId, SpecLane, StepOutcome,
};
use crate::config::{Selection, SpecDepth, SsrConfig, StopRule};
use crate::util::rng::Rng;
use crate::workload::Problem;

/// The five evaluated settings of the paper (§4.2) plus ablation knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// single-path target-only decoding
    Baseline,
    /// N parallel target-only paths; `spm` toggles strategy selection
    Parallel { n: usize, spm: bool },
    /// sequential speculative reasoning (single path, draft + rewrite)
    SpecReason { tau: u8 },
    /// the full framework: SPM selection + SSD + voting (+ fast modes)
    Ssr { n: usize, tau: u8, stop: StopRule },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline => "baseline".into(),
            Method::Parallel { n, spm: false } => format!("parallel-{n}"),
            Method::Parallel { n, spm: true } => format!("parallel-spm-{n}"),
            Method::SpecReason { tau } => format!("spec-reason({tau})"),
            Method::Ssr { n, stop: StopRule::Full, .. } => format!("ssr-m{n}"),
            Method::Ssr { n, stop: StopRule::Fast1, .. } => format!("ssr-m{n}-fast1"),
            Method::Ssr { n, stop: StopRule::Fast2, .. } => format!("ssr-m{n}-fast2"),
        }
    }

    pub fn uses_draft(&self) -> bool {
        matches!(self, Method::SpecReason { .. } | Method::Ssr { .. })
    }

    /// Lanes (parallel reasoning paths) this method occupies while in
    /// flight — the scheduler's admission currency.
    pub fn lanes(&self) -> usize {
        match self {
            Method::Baseline | Method::SpecReason { .. } => 1,
            Method::Parallel { n, .. } | Method::Ssr { n, .. } => *n,
        }
    }
}

/// Everything the eval layer needs from one problem run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub decision: Decision,
    pub votes: Vec<PathVote>,
    pub draft_tokens: u64,
    pub target_tokens: u64,
    /// scored-but-not-rewritten target tokens (excluded from gamma per
    /// the paper's Appendix B accounting; reported separately)
    pub score_tokens: u64,
    pub steps: u64,
    pub rewrites: u64,
    /// strategies the SPM picked (empty when not used)
    pub selection: Vec<usize>,
    /// wall-clock of the engine loop
    pub wall_secs: f64,
    /// backend model-time (real execute time on PJRT, virtual
    /// calibrated), measured as the delta of the backend-GLOBAL clock
    /// over the run's lifetime. Exact for the single-problem
    /// `Engine::run` path; for a `ProblemRun` driven by the scheduler
    /// it also includes time of batches shared with (or belonging to)
    /// concurrent runs, so it is NOT per-request attributable there —
    /// the scheduler reports the aggregate via `Metrics::model_secs`
    /// instead of surfacing this field per reply.
    pub model_secs: f64,
    /// draft steps proposed to / accepted by the target (the run's
    /// acceptance ledger; both 0 for non-speculative methods)
    pub proposed: u64,
    pub accepted: u64,
    /// lifetime acceptance rate gamma (None if the run never speculated)
    pub gamma: Option<f64>,
    /// speculation window depth when the run finished (1 = per-step)
    pub spec_depth: usize,
    /// the controller abandoned speculation (gamma below break-even)
    pub target_only: bool,
}

impl RunResult {
    pub fn answer(&self) -> Option<i64> {
        self.decision.answer()
    }

    /// Token-level rewrite-rate proxy R (paper Appendix B approximates
    /// the token rate by the step rate).
    pub fn rewrite_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rewrites as f64 / self.steps as f64
        }
    }
}

/// Step-boundary snapshot of one in-flight run — what a streamed
/// `progress` event carries (DESIGN.md §16). Everything here is
/// derived from the placement-invariant [`RunCore`], so identical
/// requests stream identical snapshots at identical step counts
/// regardless of shard placement or migration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunProgress {
    /// steps taken by the furthest lane so far
    pub steps: u64,
    pub lanes: usize,
    /// lanes that have terminated with a parsed answer (votes cast)
    pub finished: usize,
    /// current plurality answer over the finished lanes (ties break to
    /// the smallest answer — deterministic); None before any vote
    pub vote: Option<i64>,
    /// live acceptance EWMA (None until the run speculates)
    pub gamma: Option<f64>,
    /// current speculation window depth
    pub spec_depth: usize,
    /// committed step tokens across all lanes so far — the monotone
    /// total behind streamed `token_delta` frames
    pub tokens: u64,
}

/// Plurality answer over a finished-vote tally; ties break to the
/// smallest answer (BTreeMap iteration order + strict `>`).
fn plurality(tally: &BTreeMap<i64, usize>) -> Option<i64> {
    let mut best: Option<(i64, usize)> = None;
    for (&a, &c) in tally {
        if best.map_or(true, |(_, bc)| c > bc) {
            best = Some((a, c));
        }
    }
    best.map(|(a, _)| a)
}

/// Placement-invariant decision state of one lane: what the run has
/// decided about this path so far, with NO backend handle in it — the
/// half of a lane that travels verbatim when a run migrates between
/// shards (DESIGN.md §12).
#[derive(Debug, Clone)]
struct LaneDecisions {
    steps_taken: usize,
    scores: Vec<u8>,
    terminal: bool,
    /// parsed once at the step the lane terminated (its trace is frozen
    /// from then on), so the fast-mode checks stop re-running
    /// `parse_answer` over every finished trace on every step
    answer: Option<i64>,
}

/// One lane's outcome from a batched step cycle, routed back into
/// [`ProblemRun::observe`].
#[derive(Debug, Clone)]
pub struct StepResult {
    pub path: PathId,
    pub outcome: StepOutcome,
    /// accepted draft score; 9 for target-generated or rewritten steps
    pub score: u8,
}

/// Lane counts of the model-executing backend calls one [`step_tick`]
/// issued (draft/score/rewrite/target; the bookkeeping-only
/// `accept_step` is excluded) — the batch-occupancy telemetry the
/// serving metrics aggregate.
#[derive(Debug, Clone, Default)]
pub struct TickCalls {
    pub lanes_per_call: Vec<usize>,
    /// transient backend errors absorbed by in-place retry this tick
    pub retries: u64,
}

impl TickCalls {
    fn record(&mut self, lanes: usize) {
        self.lanes_per_call.push(lanes);
    }
}

/// In-place retry budget for [`FaultSeverity::Transient`] backend
/// errors within one step call. Transient errors are raised *before*
/// the backend mutates lane state (that is the contract that makes
/// them transient), so re-issuing the identical call is sound and the
/// run's decisions are unchanged. A transient that survives the budget
/// escalates to the caller as-is and is handled like a lane-fatal
/// error (DESIGN.md §13).
const TRANSIENT_RETRIES: u32 = 3;

fn with_transient_retry<T>(retries: &mut u64, mut call: impl FnMut() -> Result<T>) -> Result<T> {
    let mut attempts = 0u32;
    loop {
        match call() {
            Ok(v) => return Ok(v),
            Err(e)
                if attempts < TRANSIENT_RETRIES
                    && severity_of(&e) == FaultSeverity::Transient =>
            {
                attempts += 1;
                *retries += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// EWMA smoothing for the per-run acceptance (gamma) signal.
const GAMMA_EWMA_ALPHA: f64 = 0.3;

/// Widening break-even on the calibrated cost model (DESIGN.md §15):
/// a window span costs `alpha + 0.12 * tail` when discarded by a
/// rejection and saves `0.12 * (1 - tail)` of verify time when
/// committed (alpha = 0.047, verify tail = 0.15). Drafting one more
/// span is worth it while the window survives to it with probability
/// above waste / (waste + saving) = 0.065 / 0.167 ≈ 0.39, i.e. the
/// gamma-optimal window depth is ≈ 1 + ln(0.39) / ln(gamma).
const MARGINAL_REACH: f64 = 0.39;

/// Below this lifetime acceptance, speculation loses outright: a
/// proposed step costs alpha + 0.12 + (1 - gamma) rewrite target
/// seconds versus 1.0 for a plain target step, which crosses 1 at
/// gamma ≈ 0.167. The switch is sticky and gated on a meaningful
/// sample so a few unlucky ticks cannot kill speculation for good.
const TARGET_ONLY_BELOW: f64 = 0.12;
const TARGET_ONLY_MIN_PROPOSED: u64 = 50;

/// The per-run speculation controller (DESIGN.md §15): the acceptance
/// EWMA, the bounded depth controller around it, and the lifetime
/// accepted/proposed ledger. Lives in [`RunCore`], so a migrated run
/// carries its learned operating point with it.
#[derive(Debug, Clone)]
struct SpecCtl {
    mode: SpecDepth,
    /// acceptance EWMA (None until the first speculative tick)
    gamma: Option<f64>,
    /// current window depth; 1 = the legacy per-step cycle
    depth: usize,
    /// sticky: speculation abandoned, lanes decode target-only
    target_only: bool,
    /// speculative ticks folded into the EWMA
    samples: u64,
    /// lifetime accepted / proposed draft steps
    accepted: u64,
    proposed: u64,
    /// gamma-driven class migrations consumed — the scheduler's
    /// anti-ping-pong budget travels with the run
    class_moves: u32,
}

impl SpecCtl {
    fn new(mode: SpecDepth) -> SpecCtl {
        let depth = match mode {
            SpecDepth::Fixed(k) => k,
            SpecDepth::Adaptive { .. } => 1,
        };
        SpecCtl {
            mode,
            gamma: None,
            depth,
            target_only: false,
            samples: 0,
            accepted: 0,
            proposed: 0,
            class_moves: 0,
        }
    }

    /// Gamma-optimal window depth (see [`MARGINAL_REACH`]).
    fn optimal_depth(g: f64) -> usize {
        if g <= MARGINAL_REACH {
            return 1;
        }
        if g >= 0.98 {
            return usize::MAX; // the Adaptive max clamps this
        }
        1 + (MARGINAL_REACH.ln() / g.ln()) as usize
    }

    /// Fold one tick's accepted/proposed counts into the EWMA and move
    /// the depth one bounded step toward the gamma-optimal window —
    /// widen by one, narrow by halving (AIMD, so a collapse backs off
    /// fast while recovery re-widens carefully). Only Full-stop runs
    /// adjust depth: fast-stop runs re-check their stop rule every
    /// step, so they stay at depth 1 and every `--spec-depth` setting
    /// remains decision-identical for them.
    fn note_gamma(&mut self, accepted: u64, proposed: u64, stop: StopRule) {
        if proposed == 0 {
            return;
        }
        self.accepted += accepted;
        self.proposed += proposed;
        self.samples += 1;
        let g = accepted as f64 / proposed as f64;
        let ewma = match self.gamma {
            None => g,
            Some(prev) => prev + GAMMA_EWMA_ALPHA * (g - prev),
        };
        self.gamma = Some(ewma);
        let SpecDepth::Adaptive { max } = self.mode else { return };
        if self.target_only || stop != StopRule::Full {
            return;
        }
        if self.proposed >= TARGET_ONLY_MIN_PROPOSED
            && (self.accepted as f64) < TARGET_ONLY_BELOW * self.proposed as f64
        {
            self.target_only = true;
            self.depth = 1;
            return;
        }
        let target = Self::optimal_depth(ewma).min(max.max(1));
        if self.depth < target {
            self.depth += 1;
        } else if self.depth > target {
            self.depth = (self.depth / 2).max(target);
        }
    }
}

/// The placement-invariant half of a [`ProblemRun`]: every input to
/// future decisions (stop rules, votes, per-lane score histories) and
/// nothing shard-local. Plain `Send` data — it crosses shard-thread
/// boundaries inside a [`DetachedRun`] unchanged, which is what makes a
/// migrated run's remaining decisions bit-identical (DESIGN.md §12).
#[derive(Debug, Clone)]
struct RunCore {
    speculative: bool,
    tau: u8,
    stop: StopRule,
    max_steps: usize,
    lanes: Vec<LaneDecisions>,
    selection: Vec<usize>,
    /// answer -> finished lanes voting it (Fast2 agreement tally)
    finished_answers: BTreeMap<i64, usize>,
    /// committed step tokens across all lanes (monotone; outcomes are
    /// decision inputs, so this is placement-invariant like the rest of
    /// the core and survives migration verbatim)
    tokens: u64,
    stopped: bool,
    t0: Instant,
    /// speculation depth controller + acceptance ledger
    spec: SpecCtl,
}

/// A resumable single-problem step machine. `start` selects strategies
/// and opens the lane group; each [`step_tick`] that includes the run
/// advances every active lane one reasoning step; `finish` closes the
/// lanes and aggregates the vote. Between ticks the run is inert, which
/// is what lets the scheduler multiplex many of them over one backend —
/// and, since the decision state ([`RunCore`]) is split from the
/// shard-local backend handles below, a run can [`ProblemRun::detach`]
/// from one shard at any step boundary and [`ProblemRun::attach`] on
/// another mid-solve.
pub struct ProblemRun {
    core: RunCore,
    /// shard-local: `ids[i]` is the backend handle driving
    /// `core.lanes[i]`; rebuilt wholesale when the run migrates
    ids: Vec<PathId>,
    /// `PathId` -> lane index: ids are backend-global, so routing
    /// outcomes through this map replaces the per-step linear scan that
    /// made the old loop O(P^2)
    index: HashMap<PathId, usize>,
    /// this shard's backend clock at attach (shard-local baseline)
    clock0: f64,
    /// model-seconds accumulated on shards this run already left
    clock_carry: f64,
}

/// A mid-solve run detached from its shard: the decision core plus one
/// exported [`LaneSnapshot`] per lane. `Send` — it is the unit that
/// travels when a drain or a steal migrates in-flight work
/// (`coordinator::pool`, DESIGN.md §12). `Clone` — the recovery layer
/// keeps a copy as a step-boundary checkpoint so a crash on the
/// receiving shard can re-admit the run elsewhere (DESIGN.md §13).
#[derive(Clone)]
pub struct DetachedRun {
    core: RunCore,
    lanes: Vec<LaneSnapshot>,
    clock_carry: f64,
}

impl DetachedRun {
    /// Lanes the run will occupy once re-attached (admission currency).
    pub fn lanes(&self) -> usize {
        self.core.lanes.len()
    }

    /// Acceptance EWMA carried in the detached core (class placement
    /// hint for re-admission).
    pub fn gamma_ewma(&self) -> Option<f64> {
        self.core.spec.gamma
    }

    /// True if the detached run had dropped to target-only decoding.
    pub fn target_only(&self) -> bool {
        self.core.spec.target_only
    }

    /// Approximate serialized size — the `migration_bytes` gauge.
    pub fn approx_bytes(&self) -> u64 {
        let core: u64 = self
            .core
            .lanes
            .iter()
            .map(|l| l.scores.len() as u64 + 32)
            .sum::<u64>()
            + 128;
        core + self.lanes.iter().map(|s| s.approx_bytes()).sum::<u64>()
    }
}

impl ProblemRun {
    /// Select strategies and open the lane group for one problem.
    /// `seed` controls sampling (trial id). Uses the shared-prefix open
    /// when `cfg.prefix.enabled` (prefilling a private prefix and
    /// releasing it after the fork); [`ProblemRun::start_with_cache`]
    /// additionally reuses prefixes across runs.
    pub fn start(
        backend: &mut dyn Backend,
        cfg: &SsrConfig,
        problem: &Problem,
        method: Method,
        seed: u64,
    ) -> Result<ProblemRun> {
        Self::start_with_cache(backend, cfg, problem, method, seed, None)
    }

    /// [`ProblemRun::start`] with an optional cross-request prefix
    /// provider (the single-backend [`PrefixCache`] or a shard's view
    /// of the shared tier): repeated problems fork their lanes off an
    /// already-prefilled prompt and skip prompt prefill entirely.
    pub fn start_with_cache(
        backend: &mut dyn Backend,
        cfg: &SsrConfig,
        problem: &Problem,
        method: Method,
        seed: u64,
        mut cache: Option<&mut dyn PrefixProvider>,
    ) -> Result<ProblemRun> {
        let t0 = Instant::now();
        let clock0 = backend.clock_secs();
        let mut rng = Rng::new(seed ^ 0xE46);

        let speculative = method.uses_draft();
        let (tau, stop) = match method {
            Method::SpecReason { tau } => (tau, StopRule::Full),
            Method::Ssr { tau, stop, .. } => (tau, stop),
            _ => (0, StopRule::Full),
        };

        // The shared-prefix open pays off when the prompt is shared by
        // several lanes or can be cached for later solves; a single-lane
        // open with no cache to warm (none passed, or capacity 0) would
        // be pure fork overhead (on PJRT: an extra cache broadcast per
        // model), so it stays on the legacy path.
        let cache_usable = cache.as_deref().is_some_and(|c| c.capacity() > 0);
        let use_prefix = cfg.prefix.enabled && (cache_usable || method.lanes() > 1);
        let (ids, selection) = if use_prefix {
            // --- shared-prefix open: prefill the prompt once, read the
            // SPM logits off the same pass, fork one lane per strategy
            let wants_scores = matches!(
                method,
                Method::Parallel { spm: true, .. } | Method::Ssr { .. }
            ) && matches!(
                cfg.selection,
                Selection::ModelTopN | Selection::ModelSample
            );
            let acq = match cache.as_deref_mut() {
                Some(c) => c.acquire(backend, problem, speculative, wants_scores)?,
                None => Acquired::owned(backend.prefill_prefix(
                    problem,
                    speculative,
                    wants_scores,
                )?),
            };
            let forked = pick_strategies(backend, method, cfg, problem, &mut rng, Some(acq.handle))
                .and_then(|(strategies, selection)| {
                    Ok((backend.fork_paths(acq.handle, &strategies, seed)?, selection))
                });
            if !acq.retained {
                // private prefix: lanes own copies now; free the prompt
                let _ = backend.release_prefix(acq.handle);
            }
            forked?
        } else {
            // --- legacy per-lane open (single-lane no-cache opens,
            // ablation, and the equivalence baseline)
            let (strategies, selection) =
                pick_strategies(backend, method, cfg, problem, &mut rng, None)?;
            (backend.open_paths(problem, &strategies, seed, speculative)?, selection)
        };

        let lanes: Vec<LaneDecisions> = ids
            .iter()
            .map(|_| LaneDecisions {
                steps_taken: 0,
                scores: Vec::new(),
                terminal: false,
                answer: None,
            })
            .collect();
        let index: HashMap<PathId, usize> =
            ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();

        Ok(ProblemRun {
            core: RunCore {
                speculative,
                tau,
                stop,
                max_steps: cfg.max_steps,
                lanes,
                selection,
                finished_answers: BTreeMap::new(),
                tokens: 0,
                stopped: false,
                t0,
                spec: SpecCtl::new(cfg.spec_depth),
            },
            ids,
            index,
            clock0,
            clock_carry: 0.0,
        })
    }

    /// Lanes this run holds (the scheduler's admission currency).
    pub fn lanes(&self) -> usize {
        self.core.lanes.len()
    }

    pub fn speculative(&self) -> bool {
        self.core.speculative
    }

    pub fn tau(&self) -> u8 {
        self.core.tau
    }

    pub fn selection(&self) -> &[usize] {
        &self.core.selection
    }

    /// Acceptance EWMA the depth controller tracks (None until the run
    /// has speculated) — the scheduler's class-migration signal.
    pub fn gamma_ewma(&self) -> Option<f64> {
        self.core.spec.gamma
    }

    /// Speculative ticks folded into the gamma EWMA.
    pub fn gamma_samples(&self) -> u64 {
        self.core.spec.samples
    }

    /// Current speculation window depth (1 = per-step cycling).
    pub fn spec_depth(&self) -> usize {
        self.core.spec.depth
    }

    /// True once the controller dropped the run to target-only decoding.
    pub fn target_only(&self) -> bool {
        self.core.spec.target_only
    }

    /// Gamma-driven class migrations this run has consumed — the
    /// scheduler's anti-ping-pong budget, carried across shards.
    pub fn class_moves(&self) -> u32 {
        self.core.spec.class_moves
    }

    /// The per-run event tap (DESIGN.md §16): a read-only snapshot of
    /// the run's observable state at a step boundary, for streaming
    /// `progress`/`first_vote` frames. Pure observation over the same
    /// decision core the stop rules read — it can never steer the run,
    /// so streaming cannot violate the determinism contract.
    pub fn progress(&self) -> RunProgress {
        RunProgress {
            steps: self.core.lanes.iter().map(|l| l.steps_taken).max().unwrap_or(0) as u64,
            lanes: self.core.lanes.len(),
            finished: self.core.finished_answers.values().sum(),
            vote: plurality(&self.core.finished_answers),
            gamma: self.core.spec.gamma,
            spec_depth: self.core.spec.depth,
            tokens: self.core.tokens,
        }
    }

    pub fn note_class_move(&mut self) {
        self.core.spec.class_moves += 1;
    }

    /// Window depth for this run's next tick: 0 sends the lanes to the
    /// target-only bucket, 1 is the legacy draft/score/rewrite cycle,
    /// >1 bursts speculation windows. Fast-stop runs always tick at
    /// depth 1 so their early-stop checks keep per-step granularity.
    fn tick_depth(&self) -> usize {
        if !self.core.speculative || self.core.spec.target_only {
            return 0;
        }
        if self.core.stop != StopRule::Full {
            return 1;
        }
        self.core.spec.depth.max(1)
    }

    /// Lanes that still need a step this tick.
    pub fn active(&self) -> Vec<PathId> {
        if self.core.stopped {
            return Vec::new();
        }
        self.core
            .lanes
            .iter()
            .zip(&self.ids)
            .filter(|(l, _)| !l.terminal && l.steps_taken < self.core.max_steps)
            .map(|(_, &id)| id)
            .collect()
    }

    /// True once a fast mode fired or every lane terminated / hit the
    /// step cap — the run is ready to `finish` and vote.
    pub fn is_done(&self) -> bool {
        self.core.stopped
            || !self
                .core
                .lanes
                .iter()
                .any(|l| !l.terminal && l.steps_taken < self.core.max_steps)
    }

    /// Record one step of outcomes, then apply the fast-mode stop rules
    /// (paper §3.2) over the updated lane set.
    pub fn observe(&mut self, backend: &dyn Backend, results: Vec<StepResult>) {
        for r in results {
            let i = *self.index.get(&r.path).expect("step result for unknown path");
            self.core.tokens += r.outcome.tokens.len() as u64;
            let lp = &mut self.core.lanes[i];
            lp.steps_taken += 1;
            lp.scores.push(r.score);
            if r.outcome.terminal && !lp.terminal {
                lp.terminal = true;
                lp.answer = backend.parse_answer(backend.trace(r.path));
                if let Some(a) = lp.answer {
                    *self.core.finished_answers.entry(a).or_insert(0) += 1;
                }
            }
        }

        // --- fast modes (paper §3.2) ---------------------------------------
        match self.core.stop {
            StopRule::Full => {}
            StopRule::Fast1 => {
                if self.core.lanes.iter().any(|l| l.terminal && l.answer.is_some()) {
                    self.core.stopped = true;
                }
            }
            StopRule::Fast2 => {
                if self.core.finished_answers.values().any(|&c| c >= 2) {
                    self.core.stopped = true;
                }
            }
        }
    }

    /// Stop the run at the current step boundary regardless of lane
    /// state — deadline-expiry degradation (DESIGN.md §13). A later
    /// [`ProblemRun::finish`] closes the lanes and votes from whatever
    /// answers were collected so far (possibly none).
    pub fn force_stop(&mut self) {
        self.core.stopped = true;
    }

    /// Best-effort close of every lane without voting — the scheduler's
    /// failure path. Releases backend lane state (trace buffers,
    /// PJRT cache pins) when a run is dropped mid-flight; close errors
    /// are swallowed because the backend may already be faulted.
    pub fn abort(&mut self, backend: &mut dyn Backend) {
        for &id in &self.ids {
            let _ = backend.close_path(id);
        }
        self.core.stopped = true;
    }

    /// Detach this run from its shard at a step boundary: every lane is
    /// exported into a [`LaneSnapshot`] (closing the local lane) and the
    /// decision core travels with them. The result is `Send`;
    /// [`ProblemRun::attach`] resumes it on any identically-configured
    /// backend with bit-identical remaining decisions. On export
    /// failure the not-yet-exported lanes are closed so no backend
    /// state leaks (the caller fails the request).
    pub fn detach(self, backend: &mut dyn Backend) -> Result<DetachedRun> {
        let clock_carry = self.clock_carry + (backend.clock_secs() - self.clock0);
        let mut lanes = Vec::with_capacity(self.ids.len());
        for (k, &id) in self.ids.iter().enumerate() {
            match backend.export_lane_state(id) {
                Ok(s) => lanes.push(s),
                Err(e) => {
                    for &rest in &self.ids[k..] {
                        let _ = backend.close_path(rest);
                    }
                    return Err(e);
                }
            }
        }
        Ok(DetachedRun { core: self.core, lanes, clock_carry })
    }

    /// Resume a [`DetachedRun`] on `backend`: lanes are imported (fresh
    /// shard-local ids, re-uploaded device state on PJRT) and the
    /// decision core continues untouched. On import failure the lanes
    /// already imported are closed before the error propagates.
    pub fn attach(d: DetachedRun, backend: &mut dyn Backend) -> Result<ProblemRun> {
        let clock0 = backend.clock_secs();
        let mut ids = Vec::with_capacity(d.lanes.len());
        for snap in d.lanes {
            match backend.import_lane_state(snap) {
                Ok(id) => ids.push(id),
                Err(e) => {
                    for &done in &ids {
                        let _ = backend.close_path(done);
                    }
                    return Err(e);
                }
            }
        }
        let index = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        Ok(ProblemRun { core: d.core, ids, index, clock0, clock_carry: d.clock_carry })
    }

    /// Close every lane, aggregate the votes, and return the result.
    /// See [`RunResult::model_secs`] for its semantics under
    /// concurrent scheduling.
    pub fn finish(&mut self, backend: &mut dyn Backend) -> Result<RunResult> {
        let mut votes = Vec::with_capacity(self.core.lanes.len());
        let (mut draft_tokens, mut target_tokens, mut score_tokens) = (0, 0, 0);
        let (mut steps, mut rewrites) = (0, 0);
        for (lp, &id) in self.core.lanes.iter().zip(&self.ids) {
            let stats = backend.close_path(id)?;
            // the close decides the final digits (calibrated substrate)
            // or freezes the trace (PJRT); unfinished paths cast no vote
            // unless their trace happens to contain a FIN answer
            let answer = backend.parse_answer(&stats.trace);
            draft_tokens += stats.draft_tokens;
            target_tokens += stats.target_tokens;
            score_tokens += stats.score_tokens;
            steps += stats.steps;
            rewrites += stats.rewrites;
            votes.push(PathVote { answer, step_scores: lp.scores.clone() });
        }

        Ok(RunResult {
            decision: aggregate(&votes),
            votes,
            draft_tokens,
            target_tokens,
            score_tokens,
            steps,
            rewrites,
            selection: self.core.selection.clone(),
            wall_secs: self.core.t0.elapsed().as_secs_f64(),
            model_secs: self.clock_carry + (backend.clock_secs() - self.clock0),
            proposed: self.core.spec.proposed,
            accepted: self.core.spec.accepted,
            gamma: if self.core.spec.proposed > 0 {
                Some(self.core.spec.accepted as f64 / self.core.spec.proposed as f64)
            } else {
                None
            },
            spec_depth: self.core.spec.depth,
            target_only: self.core.spec.target_only,
        })
    }
}

/// Strategy selection for one run: the Method decides the lane shape,
/// and SPM-selected methods pull model scores either from a shared
/// prefix (`prefix = Some`) or a standalone scoring prefill — the one
/// place this Method match exists for both open shapes.
fn pick_strategies(
    backend: &mut dyn Backend,
    method: Method,
    cfg: &SsrConfig,
    problem: &Problem,
    rng: &mut Rng,
    prefix: Option<crate::backend::PrefixHandle>,
) -> Result<(Vec<Option<usize>>, Vec<usize>)> {
    Ok(match method {
        Method::Baseline | Method::SpecReason { .. } => (vec![None], vec![]),
        Method::Parallel { n, spm: false } => (vec![None; n], vec![]),
        Method::Parallel { n, spm: true } | Method::Ssr { n, .. } => {
            let picked = match prefix {
                Some(h) => spm::select_prefixed(
                    backend,
                    h,
                    problem,
                    cfg.pool_size,
                    n,
                    cfg.selection,
                    rng,
                )?,
                None => spm::select(backend, problem, cfg.pool_size, n, cfg.selection, rng)?,
            };
            (picked.iter().map(|&s| Some(s)).collect(), picked)
        }
    })
}

/// Split a tick's lanes into backend-call groups: one shared union
/// (chunked to the lane capacity) when the backend batches across
/// requests, per-run groups when lanes are pinned to their prefill
/// batch (PJRT). Entries arrive run-by-run, so same-run lanes are
/// contiguous.
fn call_groups<T: Copy>(
    lanes: Vec<(usize, T)>,
    cross_request: bool,
    max_lanes_per_call: usize,
) -> Vec<Vec<(usize, T)>> {
    let mut groups = Vec::new();
    if cross_request {
        for c in lanes.chunks(max_lanes_per_call) {
            groups.push(c.to_vec());
        }
    } else {
        let mut cur: Vec<(usize, T)> = Vec::new();
        for lp in lanes {
            if !cur.is_empty() && (cur[0].0 != lp.0 || cur.len() >= max_lanes_per_call) {
                groups.push(std::mem::take(&mut cur));
            }
            cur.push(lp);
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
    }
    groups
}

/// Advance every active lane of every not-done run, batching lanes
/// from different runs into shared backend calls where the backend
/// allows it. Lanes of runs at speculation depth 1 run one union
/// draft -> score -> accept|rewrite cycle (each lane judged against
/// its own run's tau) — the legacy tick, bit-identical to the
/// pre-controller engine. Lanes of runs whose controller widened past
/// depth 1 burst whole speculation windows through
/// [`Backend::spec_steps`]; target-only lanes (non-speculative methods
/// and gamma-collapsed runs) share one target_step. Outcomes are
/// routed back per run, the stop rules applied once per tick, and each
/// run's accepted/proposed tally feeds its gamma controller.
pub fn step_tick(backend: &mut dyn Backend, runs: &mut [&mut ProblemRun]) -> Result<TickCalls> {
    let meta = backend.meta();
    let chunk = meta.max_batch_lanes.max(1);
    let mut calls = TickCalls::default();

    let mut spec1: Vec<(usize, PathId)> = Vec::new();
    let mut burst: Vec<(usize, (PathId, usize))> = Vec::new();
    let mut tgt: Vec<(usize, PathId)> = Vec::new();
    for (ri, run) in runs.iter().enumerate() {
        if run.is_done() {
            continue;
        }
        let depth = run.tick_depth();
        for id in run.active() {
            match depth {
                // non-speculative methods and target-only fallback
                0 => tgt.push((ri, id)),
                // the legacy per-step cycle (fixed:1 default)
                1 => spec1.push((ri, id)),
                d => {
                    // clamp the window to the lane's remaining budget
                    let li = run.index[&id];
                    let left = run.core.max_steps - run.core.lanes[li].steps_taken;
                    match d.min(left) {
                        0 | 1 => spec1.push((ri, id)),
                        d => burst.push((ri, (id, d))),
                    }
                }
            }
        }
    }

    let mut per_run: Vec<Vec<StepResult>> = runs.iter().map(|_| Vec::new()).collect();
    let mut proposed = vec![0u64; runs.len()];
    let mut accepted = vec![0u64; runs.len()];

    for group in call_groups(spec1, meta.cross_request_batch, chunk) {
        let ids: Vec<PathId> = group.iter().map(|&(_, id)| id).collect();
        let outs = with_transient_retry(&mut calls.retries, || backend.draft_step(&ids))?;
        calls.record(ids.len());
        let scores = with_transient_retry(&mut calls.retries, || backend.score_step(&ids))?;
        calls.record(ids.len());

        let mut acc: Vec<(usize, PathId, StepOutcome, u8)> = Vec::new();
        let mut rej: Vec<(usize, PathId)> = Vec::new();
        for ((&(ri, id), o), &s) in group.iter().zip(outs).zip(&scores) {
            proposed[ri] += 1;
            if s >= runs[ri].core.tau {
                accepted[ri] += 1;
                acc.push((ri, id, o, s));
            } else {
                rej.push((ri, id));
            }
        }
        if !acc.is_empty() {
            let acc_ids: Vec<PathId> = acc.iter().map(|x| x.1).collect();
            with_transient_retry(&mut calls.retries, || backend.accept_step(&acc_ids))?;
        }
        if !rej.is_empty() {
            let rej_ids: Vec<PathId> = rej.iter().map(|x| x.1).collect();
            let rewritten =
                with_transient_retry(&mut calls.retries, || backend.rewrite_step(&rej_ids))?;
            calls.record(rej_ids.len());
            // rewritten steps replace the rejected outcome and are
            // recorded with score 9 (paper §3.2)
            for (&(ri, id), o) in rej.iter().zip(rewritten) {
                per_run[ri].push(StepResult { path: id, outcome: o, score: 9 });
            }
        }
        for (ri, id, o, s) in acc {
            per_run[ri].push(StepResult { path: id, outcome: o, score: s });
        }
    }

    // speculation windows (depth > 1): one draft barrier and one
    // verify/rewrite barrier per group instead of per micro-step. The
    // backend replays the exact per-lane op order of the depth-1 cycle,
    // so committed steps are bit-identical — only the clock model and
    // the call count change. Errors are NOT retried in place: a burst
    // is not transient-atomic (earlier micro-cycles may have committed),
    // so a mid-window fault escalates to the scheduler's lane-fatal
    // handling like an exhausted retry budget would (DESIGN.md §13).
    for group in call_groups(burst, meta.cross_request_batch, chunk) {
        let lanes: Vec<SpecLane> = group
            .iter()
            .map(|&(ri, (id, depth))| SpecLane { path: id, depth, tau: runs[ri].core.tau })
            .collect();
        let bursts = backend.spec_steps(&lanes)?;
        calls.record(lanes.len());
        for (&(ri, (id, _)), b) in group.iter().zip(bursts) {
            proposed[ri] += b.proposed;
            accepted[ri] += b.accepted;
            for ms in b.steps {
                per_run[ri].push(StepResult { path: id, outcome: ms.outcome, score: ms.score });
            }
        }
    }

    for group in call_groups(tgt, meta.cross_request_batch, chunk) {
        let ids: Vec<PathId> = group.iter().map(|&(_, id)| id).collect();
        let outs = with_transient_retry(&mut calls.retries, || backend.target_step(&ids))?;
        calls.record(ids.len());
        // target-generated steps carry full target confidence
        for (&(ri, id), o) in group.iter().zip(outs) {
            per_run[ri].push(StepResult { path: id, outcome: o, score: 9 });
        }
    }

    for (ri, results) in per_run.into_iter().enumerate() {
        if !results.is_empty() {
            runs[ri].observe(&*backend, results);
        }
    }
    // fold this tick's acceptance into each run's gamma controller
    for (ri, run) in runs.iter_mut().enumerate() {
        run.core.spec.note_gamma(accepted[ri], proposed[ri], run.core.stop);
    }
    Ok(calls)
}

pub struct Engine<'a> {
    pub backend: &'a mut dyn Backend,
    pub cfg: SsrConfig,
    /// prefix cache shared by this engine's runs: re-solving a problem
    /// (pass@k, tau sweeps, fast-mode comparisons) skips prompt prefill
    pub prefix: PrefixCache,
}

impl<'a> Drop for Engine<'a> {
    /// Release the engine's cached prefixes so a backend reused across
    /// several `Engine` instances doesn't accumulate prefix state.
    fn drop(&mut self) {
        self.prefix.clear(&mut *self.backend);
    }
}

impl<'a> Engine<'a> {
    pub fn new(backend: &'a mut dyn Backend, cfg: SsrConfig) -> Self {
        let prefix = PrefixCache::with_limits(cfg.prefix.capacity, cfg.prefix.max_bytes);
        Engine { backend, cfg, prefix }
    }

    /// Run one problem under `method` to completion — a thin wrapper
    /// that drives a [`ProblemRun`] with single-run ticks, preserving
    /// the exact backend call sequence of the pre-scheduler engine.
    /// `seed` controls sampling (trial id).
    pub fn run(&mut self, problem: &Problem, method: Method, seed: u64) -> Result<RunResult> {
        let mut run = ProblemRun::start_with_cache(
            &mut *self.backend,
            &self.cfg,
            problem,
            method,
            seed,
            Some(&mut self.prefix as &mut dyn PrefixProvider),
        )?;
        while !run.is_done() {
            let mut group = [&mut run];
            step_tick(&mut *self.backend, &mut group)?;
        }
        run.finish(&mut *self.backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::workload::suites;

    fn setup(suite: &str, seed: u64) -> (CalibratedBackend, Vec<Problem>) {
        let b = CalibratedBackend::for_suite(suite, seed).unwrap();
        let v = test_vocab();
        let s = suites::generate(suites::spec(suite).unwrap(), &v);
        (b, s.problems)
    }

    fn accuracy(suite: &str, method: Method, n_problems: usize, trials: u64) -> f64 {
        let mut correct = 0u64;
        let mut total = 0u64;
        for trial in 0..trials {
            let (mut b, problems) = setup(suite, 1000 + trial);
            let mut eng = Engine::new(&mut b, SsrConfig::default());
            for p in problems.iter().take(n_problems) {
                let r = eng.run(p, method, trial * 7919 + 11).unwrap();
                if r.answer() == Some(p.answer) {
                    correct += 1;
                }
                total += 1;
            }
        }
        correct as f64 / total as f64
    }

    #[test]
    fn baseline_run_shape() {
        let (mut b, problems) = setup("synth-aime", 1);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let r = eng.run(&problems[0], Method::Baseline, 3).unwrap();
        assert_eq!(r.votes.len(), 1);
        assert_eq!(r.draft_tokens, 0);
        assert!(r.target_tokens > 0);
        assert!(r.rewrites == 0);
        assert!(r.model_secs > 0.0);
    }

    #[test]
    fn ssr_run_uses_both_models_and_selects() {
        let (mut b, problems) = setup("synth-math500", 2);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let r = eng
            .run(&problems[0], Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }, 4)
            .unwrap();
        assert_eq!(r.votes.len(), 5);
        assert_eq!(r.selection.len(), 5);
        assert!(r.draft_tokens > 0);
        assert!(r.target_tokens > 0);
        assert!(r.steps > 0);
    }

    #[test]
    fn tau9_rewrites_more_than_tau0() {
        let (mut b, problems) = setup("synth-aime", 3);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let mut hi = 0.0;
        let mut lo = 0.0;
        for (i, p) in problems.iter().take(10).enumerate() {
            let r9 = eng
                .run(p, Method::Ssr { n: 3, tau: 9, stop: StopRule::Full }, i as u64)
                .unwrap();
            let r0 = eng
                .run(p, Method::Ssr { n: 3, tau: 0, stop: StopRule::Full }, i as u64)
                .unwrap();
            hi += r9.rewrite_rate();
            lo += r0.rewrite_rate();
        }
        assert!(hi > lo + 1.0, "tau=9 rate {hi} vs tau=0 rate {lo}");
    }

    #[test]
    fn fast_modes_cost_no_more_than_full() {
        let (mut b, problems) = setup("synth-math500", 4);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let mut full = 0u64;
        let mut fast = 0u64;
        for (i, p) in problems.iter().take(12).enumerate() {
            let rf = eng
                .run(p, Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }, i as u64)
                .unwrap();
            let r1 = eng
                .run(p, Method::Ssr { n: 5, tau: 7, stop: StopRule::Fast1 }, i as u64)
                .unwrap();
            full += rf.target_tokens + rf.draft_tokens;
            fast += r1.target_tokens + r1.draft_tokens;
        }
        assert!(fast <= full, "fast1 {fast} > full {full}");
    }

    #[test]
    fn parallel_beats_baseline_on_calibrated_substrate() {
        let base = accuracy("synth-livemath", Method::Baseline, 40, 3);
        let par5 = accuracy("synth-livemath", Method::Parallel { n: 5, spm: false }, 40, 3);
        let spm5 = accuracy("synth-livemath", Method::Parallel { n: 5, spm: true }, 40, 3);
        assert!(par5 > base, "parallel {par5} <= baseline {base}");
        assert!(spm5 > par5 - 0.02, "spm {spm5} much worse than parallel {par5}");
    }

    #[test]
    fn interleaved_ticks_match_sequential_runs() {
        // The batching claim in miniature: two problems advanced through
        // SHARED step batches must produce exactly the results of two
        // sequential Engine::run calls on an identically-seeded backend —
        // per-path sampling streams are independent of batch composition.
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        let cfg = SsrConfig::default();

        let (mut b1, problems) = setup("synth-math500", 21);
        let mut eng = Engine::new(&mut b1, cfg.clone());
        let ra = eng.run(&problems[0], m, 5).unwrap();
        let rb = eng.run(&problems[1], m, 9).unwrap();

        let (mut b2, problems2) = setup("synth-math500", 21);
        let mut run_a = ProblemRun::start(&mut b2, &cfg, &problems2[0], m, 5).unwrap();
        let mut run_b = ProblemRun::start(&mut b2, &cfg, &problems2[1], m, 9).unwrap();
        let mut occupied = Vec::new();
        while !(run_a.is_done() && run_b.is_done()) {
            let mut runs = [&mut run_a, &mut run_b];
            let tick = step_tick(&mut b2, &mut runs).unwrap();
            occupied.extend(tick.lanes_per_call);
        }
        let ia = run_a.finish(&mut b2).unwrap();
        let ib = run_b.finish(&mut b2).unwrap();

        assert_eq!(ra.decision, ia.decision);
        assert_eq!(rb.decision, ib.decision);
        assert_eq!(ra.draft_tokens, ia.draft_tokens);
        assert_eq!(rb.target_tokens, ib.target_tokens);
        assert_eq!(ra.steps, ia.steps);
        assert_eq!(rb.rewrites, ib.rewrites);
        // and the shared batches really were shared: some call carried
        // lanes of both problems (> 3 lanes in one call)
        assert!(
            occupied.iter().any(|&l| l > 3),
            "no cross-problem batch observed: {occupied:?}"
        );
    }

    #[test]
    fn migrated_run_matches_unmigrated_at_every_step_boundary() {
        // ISSUE acceptance: a run detached after k ticks and re-attached
        // on a fresh identically-seeded backend must produce the exact
        // trace/vote/answer of the unmigrated run, for EVERY k.
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        let cfg = SsrConfig::default();

        let (mut b_ref, problems) = setup("synth-math500", 41);
        let mut run = ProblemRun::start(&mut b_ref, &cfg, &problems[0], m, 13).unwrap();
        let mut ref_ticks = 0usize;
        while !run.is_done() {
            let mut group = [&mut run];
            step_tick(&mut b_ref, &mut group).unwrap();
            ref_ticks += 1;
        }
        let r_ref = run.finish(&mut b_ref).unwrap();

        for k in 0..=ref_ticks {
            let (mut b_src, problems_s) = setup("synth-math500", 41);
            let (mut b_dst, _) = setup("synth-math500", 41);
            let mut run =
                ProblemRun::start(&mut b_src, &cfg, &problems_s[0], m, 13).unwrap();
            for _ in 0..k {
                let mut group = [&mut run];
                step_tick(&mut b_src, &mut group).unwrap();
            }
            let detached = run.detach(&mut b_src).unwrap();
            assert_eq!(detached.lanes(), 3);
            assert!(detached.approx_bytes() > 0);
            let mut run = ProblemRun::attach(detached, &mut b_dst).unwrap();
            while !run.is_done() {
                let mut group = [&mut run];
                step_tick(&mut b_dst, &mut group).unwrap();
            }
            let r = run.finish(&mut b_dst).unwrap();
            assert_eq!(r.decision, r_ref.decision, "k={k}: decision diverged");
            assert_eq!(r.votes, r_ref.votes, "k={k}: votes diverged");
            assert_eq!(r.steps, r_ref.steps, "k={k}: steps diverged");
            assert_eq!(r.rewrites, r_ref.rewrites, "k={k}: rewrites diverged");
            assert_eq!(r.draft_tokens, r_ref.draft_tokens, "k={k}: draft ledger");
            assert_eq!(r.target_tokens, r_ref.target_tokens, "k={k}: target ledger");
        }
    }

    #[test]
    fn detached_run_model_secs_spans_both_shards() {
        // clock accounting across a migration: the run's model_secs is
        // carry (source shard) + delta (destination shard), so it keeps
        // covering the whole solve rather than resetting at attach.
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        let cfg = SsrConfig::default();
        let (mut b_src, problems) = setup("synth-math500", 43);
        let (mut b_dst, _) = setup("synth-math500", 43);
        let mut run = ProblemRun::start(&mut b_src, &cfg, &problems[0], m, 5).unwrap();
        let mut group = [&mut run];
        step_tick(&mut b_src, &mut group).unwrap();
        let d = run.detach(&mut b_src).unwrap();
        let mut run = ProblemRun::attach(d, &mut b_dst).unwrap();
        while !run.is_done() {
            let mut group = [&mut run];
            step_tick(&mut b_dst, &mut group).unwrap();
        }
        let r = run.finish(&mut b_dst).unwrap();
        let src_secs = b_src.clock_secs();
        let dst_secs = b_dst.clock_secs();
        assert!(src_secs > 0.0 && dst_secs > 0.0);
        assert!(
            (r.model_secs - (src_secs + dst_secs)).abs() < 1e-9,
            "model_secs {} != src {} + dst {}",
            r.model_secs,
            src_secs,
            dst_secs
        );
    }

    #[test]
    fn problem_run_reports_lanes_and_retires() {
        let (mut b, problems) = setup("synth-aime", 8);
        let cfg = SsrConfig::default();
        let mut run = ProblemRun::start(
            &mut b,
            &cfg,
            &problems[0],
            Method::Parallel { n: 4, spm: false },
            3,
        )
        .unwrap();
        assert_eq!(run.lanes(), 4);
        assert!(!run.speculative());
        assert!(!run.is_done());
        let mut ticks = 0;
        while !run.is_done() {
            let mut runs = [&mut run];
            step_tick(&mut b, &mut runs).unwrap();
            ticks += 1;
            assert!(ticks <= cfg.max_steps, "run never retired");
        }
        let r = run.finish(&mut b).unwrap();
        assert_eq!(r.votes.len(), 4);
    }

    #[test]
    fn prefix_open_matches_per_lane_decisions_and_votes() {
        // ISSUE acceptance: prefix-forked opens leave accuracy/decision
        // outputs unchanged — engine-level half of the equivalence suite
        // (trace-level lives in backend::calibrated::tests).
        let methods = [
            Method::Baseline,
            Method::Parallel { n: 4, spm: true },
            Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
        ];
        for method in methods {
            let (mut b_on, problems) = setup("synth-math500", 77);
            let (mut b_off, problems2) = setup("synth-math500", 77);
            let cfg_on = SsrConfig::default();
            assert!(cfg_on.prefix.enabled);
            let mut cfg_off = SsrConfig::default();
            cfg_off.prefix.enabled = false;
            let mut e_on = Engine::new(&mut b_on, cfg_on);
            let mut e_off = Engine::new(&mut b_off, cfg_off);
            for (i, p) in problems.iter().take(8).enumerate() {
                let r_on = e_on.run(p, method, 100 + i as u64).unwrap();
                let r_off = e_off.run(&problems2[i], method, 100 + i as u64).unwrap();
                assert_eq!(r_on.decision, r_off.decision, "{method:?} problem {i}");
                assert_eq!(r_on.votes, r_off.votes, "{method:?} problem {i}");
                assert_eq!(r_on.selection, r_off.selection, "{method:?} problem {i}");
                assert_eq!(r_on.steps, r_off.steps, "{method:?} problem {i}");
                // the fork never pays more prefill than the per-lane open
                assert!(r_on.target_tokens <= r_off.target_tokens);
                assert!(r_on.draft_tokens <= r_off.draft_tokens);
            }
        }
    }

    #[test]
    fn engine_prefill_accounting_matches_flops_closed_form() {
        use crate::coordinator::flops;
        let (mut b, problems) = setup("synth-math500", 55);
        let p = &problems[0];
        let n = 5usize;
        {
            let mut eng = Engine::new(&mut b, SsrConfig::default());
            let _ = eng.run(p, Method::Ssr { n, tau: 7, stop: StopRule::Full }, 3).unwrap();
        }
        let ps = b.prefill_stats();
        let bare = p.tokens.len() as u64 + 3;
        // |prompt| + N·|suffix|, SPM pass riding the shared prefill
        assert_eq!(
            ps.target_prompt_tokens + ps.suffix_tokens + ps.spm_prompt_tokens,
            flops::prefill_tokens_shared(n, bare, 1)
        );
    }

    #[test]
    fn engine_prefix_cache_hits_on_resolve() {
        let (mut b, problems) = setup("synth-aime", 66);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        let _ = eng.run(&problems[0], m, 1).unwrap();
        let _ = eng.run(&problems[0], m, 2).unwrap();
        assert_eq!(eng.prefix.misses, 1);
        assert_eq!(eng.prefix.hits, 1, "re-solving the same problem must hit");
    }

    #[test]
    fn spec_ctl_fixed_mode_never_moves() {
        let mut c = SpecCtl::new(SpecDepth::Fixed(4));
        assert_eq!(c.depth, 4);
        for _ in 0..100 {
            c.note_gamma(0, 10, StopRule::Full);
        }
        assert_eq!(c.depth, 4, "fixed depth must not adapt");
        assert!(!c.target_only, "fixed depth must never drop to target-only");
        // ... but the gamma ledger still accumulates for reporting
        assert_eq!(c.proposed, 1000);
        assert_eq!(c.gamma, Some(0.0));
    }

    #[test]
    fn spec_ctl_widens_on_high_gamma_and_collapses_to_target_only() {
        // high acceptance: AIMD climbs to the gamma-optimal depth
        let mut c = SpecCtl::new(SpecDepth::Adaptive { max: 8 });
        for _ in 0..20 {
            c.note_gamma(9, 10, StopRule::Full);
        }
        let settled = c.depth;
        assert!(settled >= 4, "gamma 0.9 should widen well past 1 (got {settled})");
        for _ in 0..5 {
            c.note_gamma(9, 10, StopRule::Full);
        }
        assert_eq!(c.depth, settled, "controller should settle, not oscillate");
        // collapse: halving backs off fast, then the sticky target-only
        // switch fires once the lifetime sample is meaningful
        for _ in 0..60 {
            c.note_gamma(0, 10, StopRule::Full);
        }
        assert!(c.target_only, "gamma 0 must abandon speculation");
        assert_eq!(c.depth, 1);
        // sticky: recovery does not resurrect speculation
        for _ in 0..50 {
            c.note_gamma(10, 10, StopRule::Full);
        }
        assert!(c.target_only);
    }

    #[test]
    fn spec_ctl_fast_stop_runs_stay_at_depth_one() {
        let mut c = SpecCtl::new(SpecDepth::Adaptive { max: 8 });
        for _ in 0..30 {
            c.note_gamma(10, 10, StopRule::Fast1);
        }
        assert_eq!(c.depth, 1, "fast-stop runs must keep per-step granularity");
        assert_eq!(c.gamma, Some(1.0), "... while still tracking gamma");
    }

    #[test]
    fn spec_ctl_optimal_depth_tracks_gamma() {
        assert_eq!(SpecCtl::optimal_depth(0.2), 1);
        assert_eq!(SpecCtl::optimal_depth(0.39), 1);
        assert_eq!(SpecCtl::optimal_depth(0.6), 2);
        assert_eq!(SpecCtl::optimal_depth(0.8), 5);
        assert_eq!(SpecCtl::optimal_depth(0.9), 9);
        assert!(SpecCtl::optimal_depth(0.99) >= 100);
        // monotone in gamma
        let mut prev = 0;
        for g in [0.1, 0.3, 0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95] {
            let d = SpecCtl::optimal_depth(g);
            assert!(d >= prev, "optimal depth not monotone at gamma {g}");
            prev = d;
        }
    }

    #[test]
    fn fixed_depth_full_runs_match_depth1_bit_for_bit() {
        // ISSUE acceptance: --spec-depth fixed:k is decision-equivalent
        // to the pre-controller engine. Under the Full stop rule the
        // whole run record must match at every depth.
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        for k in [2usize, 4, 8] {
            let (mut b_ref, problems) = setup("synth-math500", 31);
            let (mut b_k, problems_k) = setup("synth-math500", 31);
            let mut cfg_k = SsrConfig::default();
            cfg_k.spec_depth = SpecDepth::Fixed(k);
            let mut e_ref = Engine::new(&mut b_ref, SsrConfig::default());
            let mut e_k = Engine::new(&mut b_k, cfg_k);
            let (mut secs_ref, mut secs_k) = (0.0, 0.0);
            for (i, p) in problems.iter().take(6).enumerate() {
                let r1 = e_ref.run(p, m, 50 + i as u64).unwrap();
                let rk = e_k.run(&problems_k[i], m, 50 + i as u64).unwrap();
                assert_eq!(r1.decision, rk.decision, "k={k} problem {i}: decision");
                assert_eq!(r1.votes, rk.votes, "k={k} problem {i}: votes");
                assert_eq!(r1.steps, rk.steps, "k={k} problem {i}: steps");
                assert_eq!(r1.rewrites, rk.rewrites, "k={k} problem {i}: rewrites");
                assert_eq!(r1.draft_tokens, rk.draft_tokens, "k={k} problem {i}");
                assert_eq!(r1.target_tokens, rk.target_tokens, "k={k} problem {i}");
                assert_eq!(r1.score_tokens, rk.score_tokens, "k={k} problem {i}");
                assert_eq!(r1.proposed, rk.proposed, "k={k} problem {i}: proposed");
                assert_eq!(r1.accepted, rk.accepted, "k={k} problem {i}: accepted");
                assert_eq!(rk.spec_depth, k);
                secs_ref += r1.model_secs;
                secs_k += rk.model_secs;
            }
            // acceptance is high here, so a moderate window is cheaper
            if k == 2 {
                assert!(
                    secs_k < secs_ref,
                    "k=2 windows should amortize verification: {secs_k} vs {secs_ref}"
                );
            }
        }
    }

    #[test]
    fn fast_modes_are_depth_invariant() {
        // fast-stop runs always tick at depth 1: a fixed:8 config must
        // reproduce the default run exactly, clock included.
        for stop in [StopRule::Fast1, StopRule::Fast2] {
            let m = Method::Ssr { n: 5, tau: 7, stop };
            let (mut b1, problems) = setup("synth-math500", 17);
            let (mut b8, problems8) = setup("synth-math500", 17);
            let mut cfg8 = SsrConfig::default();
            cfg8.spec_depth = SpecDepth::Fixed(8);
            let mut e1 = Engine::new(&mut b1, SsrConfig::default());
            let mut e8 = Engine::new(&mut b8, cfg8);
            for (i, p) in problems.iter().take(6).enumerate() {
                let r1 = e1.run(p, m, 70 + i as u64).unwrap();
                let r8 = e8.run(&problems8[i], m, 70 + i as u64).unwrap();
                assert_eq!(r1.decision, r8.decision, "{stop:?} problem {i}");
                assert_eq!(r1.votes, r8.votes, "{stop:?} problem {i}");
                assert_eq!(r1.steps, r8.steps, "{stop:?} problem {i}");
                assert!(
                    (r1.model_secs - r8.model_secs).abs() < 1e-9,
                    "{stop:?} problem {i}: clock diverged"
                );
            }
        }
    }

    #[test]
    fn adaptive_depth_saves_model_secs_at_equal_decisions() {
        // The tentpole claim at engine scale: on a high-acceptance suite
        // the controller widens and total model-seconds drop, while
        // every decision matches the fixed:1 reference bit for bit.
        let m = Method::Ssr { n: 5, tau: 7, stop: StopRule::Full };
        let (mut b1, problems) = setup("synth-math500", 23);
        let (mut ba, problems_a) = setup("synth-math500", 23);
        let mut cfg_a = SsrConfig::default();
        cfg_a.spec_depth = SpecDepth::Adaptive { max: 8 };
        let mut e1 = Engine::new(&mut b1, SsrConfig::default());
        let mut ea = Engine::new(&mut ba, cfg_a);
        let (mut secs_1, mut secs_a) = (0.0, 0.0);
        let mut widened = false;
        for (i, p) in problems.iter().take(10).enumerate() {
            let r1 = e1.run(p, m, 90 + i as u64).unwrap();
            let ra = ea.run(&problems_a[i], m, 90 + i as u64).unwrap();
            assert_eq!(r1.decision, ra.decision, "problem {i}: decision");
            assert_eq!(r1.votes, ra.votes, "problem {i}: votes");
            assert_eq!(r1.steps, ra.steps, "problem {i}: steps");
            assert_eq!(r1.draft_tokens, ra.draft_tokens, "problem {i}");
            assert_eq!(r1.target_tokens, ra.target_tokens, "problem {i}");
            assert!(ra.gamma.is_some());
            widened |= ra.spec_depth > 1;
            secs_1 += r1.model_secs;
            secs_a += ra.model_secs;
        }
        assert!(widened, "controller never widened on an easy suite");
        assert!(
            secs_a < secs_1,
            "adaptive depth should cut model-seconds: {secs_a} vs {secs_1}"
        );
    }

    #[test]
    fn adaptive_spec_ctl_travels_with_migration() {
        // The controller state lives in RunCore: a run migrated
        // mid-solve keeps its gamma EWMA and depth, so the remaining
        // windows (and the final record) are bit-identical.
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        let mut cfg = SsrConfig::default();
        cfg.spec_depth = SpecDepth::Adaptive { max: 8 };

        let (mut b_ref, problems) = setup("synth-math500", 47);
        let mut run = ProblemRun::start(&mut b_ref, &cfg, &problems[0], m, 13).unwrap();
        while !run.is_done() {
            let mut group = [&mut run];
            step_tick(&mut b_ref, &mut group).unwrap();
        }
        let depth_ref = run.spec_depth();
        let r_ref = run.finish(&mut b_ref).unwrap();
        assert!(depth_ref > 1, "controller never widened");

        let (mut b_src, problems_s) = setup("synth-math500", 47);
        let (mut b_dst, _) = setup("synth-math500", 47);
        let mut run = ProblemRun::start(&mut b_src, &cfg, &problems_s[0], m, 13).unwrap();
        // tick until the controller has widened, then migrate mid-run
        for _ in 0..6 {
            let mut group = [&mut run];
            step_tick(&mut b_src, &mut group).unwrap();
        }
        assert!(run.spec_depth() > 1, "expected a widened run before detach");
        let d = run.detach(&mut b_src).unwrap();
        assert!(d.gamma_ewma().is_some());
        let mut run = ProblemRun::attach(d, &mut b_dst).unwrap();
        assert!(run.spec_depth() > 1, "depth lost in migration");
        while !run.is_done() {
            let mut group = [&mut run];
            step_tick(&mut b_dst, &mut group).unwrap();
        }
        assert_eq!(run.spec_depth(), depth_ref, "migrated depth diverged");
        let r = run.finish(&mut b_dst).unwrap();
        assert_eq!(r.decision, r_ref.decision);
        assert_eq!(r.votes, r_ref.votes);
        assert_eq!(r.steps, r_ref.steps);
        assert_eq!(r.proposed, r_ref.proposed);
        assert_eq!(r.accepted, r_ref.accepted);
        assert_eq!(r.gamma, r_ref.gamma);
    }

    #[test]
    fn method_lane_need() {
        assert_eq!(Method::Baseline.lanes(), 1);
        assert_eq!(Method::SpecReason { tau: 7 }.lanes(), 1);
        assert_eq!(Method::Parallel { n: 4, spm: true }.lanes(), 4);
        assert_eq!(Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }.lanes(), 5);
    }

    #[test]
    fn method_names() {
        assert_eq!(Method::Baseline.name(), "baseline");
        assert_eq!(Method::Parallel { n: 5, spm: true }.name(), "parallel-spm-5");
        assert_eq!(Method::SpecReason { tau: 7 }.name(), "spec-reason(7)");
        assert_eq!(
            Method::Ssr { n: 3, tau: 7, stop: StopRule::Fast2 }.name(),
            "ssr-m3-fast2"
        );
    }
}
