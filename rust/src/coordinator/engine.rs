//! The SSR engine: drives a [`Backend`] through the paper's inference
//! methods — baseline decoding, naive/SPM parallel scaling, sequential
//! speculative reasoning (spec-reason), and full SSR = SPM + step-level
//! speculative decoding + answer aggregation + fast modes.
//!
//! One call = one problem = one lane group; the server and the
//! experiment runners layer batching-across-requests and trial
//! repetition on top.

use std::time::Instant;

use anyhow::Result;

use super::aggregation::{aggregate, Decision, PathVote};
use super::spm;
use crate::backend::{Backend, PathId, StepOutcome};
use crate::config::{SsrConfig, StopRule};
use crate::util::rng::Rng;
use crate::workload::Problem;

/// The five evaluated settings of the paper (§4.2) plus ablation knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// single-path target-only decoding
    Baseline,
    /// N parallel target-only paths; `spm` toggles strategy selection
    Parallel { n: usize, spm: bool },
    /// sequential speculative reasoning (single path, draft + rewrite)
    SpecReason { tau: u8 },
    /// the full framework: SPM selection + SSD + voting (+ fast modes)
    Ssr { n: usize, tau: u8, stop: StopRule },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline => "baseline".into(),
            Method::Parallel { n, spm: false } => format!("parallel-{n}"),
            Method::Parallel { n, spm: true } => format!("parallel-spm-{n}"),
            Method::SpecReason { tau } => format!("spec-reason({tau})"),
            Method::Ssr { n, stop: StopRule::Full, .. } => format!("ssr-m{n}"),
            Method::Ssr { n, stop: StopRule::Fast1, .. } => format!("ssr-m{n}-fast1"),
            Method::Ssr { n, stop: StopRule::Fast2, .. } => format!("ssr-m{n}-fast2"),
        }
    }

    pub fn uses_draft(&self) -> bool {
        matches!(self, Method::SpecReason { .. } | Method::Ssr { .. })
    }
}

/// Everything the eval layer needs from one problem run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub decision: Decision,
    pub votes: Vec<PathVote>,
    pub draft_tokens: u64,
    pub target_tokens: u64,
    /// scored-but-not-rewritten target tokens (excluded from gamma per
    /// the paper's Appendix B accounting; reported separately)
    pub score_tokens: u64,
    pub steps: u64,
    pub rewrites: u64,
    /// strategies the SPM picked (empty when not used)
    pub selection: Vec<usize>,
    /// wall-clock of the engine loop
    pub wall_secs: f64,
    /// backend model-time (real execute time on PJRT, virtual calibrated)
    pub model_secs: f64,
}

impl RunResult {
    pub fn answer(&self) -> Option<i64> {
        self.decision.answer()
    }

    /// Token-level rewrite-rate proxy R (paper Appendix B approximates
    /// the token rate by the step rate).
    pub fn rewrite_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rewrites as f64 / self.steps as f64
        }
    }
}

struct LivePath {
    id: PathId,
    steps_taken: usize,
    scores: Vec<u8>,
    terminal: bool,
}

pub struct Engine<'a> {
    pub backend: &'a mut dyn Backend,
    pub cfg: SsrConfig,
}

impl<'a> Engine<'a> {
    pub fn new(backend: &'a mut dyn Backend, cfg: SsrConfig) -> Self {
        Engine { backend, cfg }
    }

    /// Run one problem under `method`. `seed` controls sampling (trial id).
    pub fn run(&mut self, problem: &Problem, method: Method, seed: u64) -> Result<RunResult> {
        let t0 = Instant::now();
        let clock0 = self.backend.clock_secs();
        let mut rng = Rng::new(seed ^ 0xE46);

        // --- strategy selection -------------------------------------------------
        let (strategies, selection): (Vec<Option<usize>>, Vec<usize>) = match method {
            Method::Baseline | Method::SpecReason { .. } => (vec![None], vec![]),
            Method::Parallel { n, spm: false } => (vec![None; n], vec![]),
            Method::Parallel { n, spm: true } | Method::Ssr { n, .. } => {
                let picked = spm::select(
                    self.backend,
                    problem,
                    self.cfg.pool_size,
                    n,
                    self.cfg.selection,
                    &mut rng,
                )?;
                (picked.iter().map(|&s| Some(s)).collect(), picked)
            }
        };

        let speculative = method.uses_draft();
        let (tau, stop) = match method {
            Method::SpecReason { tau } => (tau, StopRule::Full),
            Method::Ssr { tau, stop, .. } => (tau, stop),
            _ => (0, StopRule::Full),
        };

        // --- open the lane group ------------------------------------------------
        let ids = self.backend.open_paths(problem, &strategies, seed, speculative)?;
        let mut live: Vec<LivePath> = ids
            .iter()
            .map(|&id| LivePath { id, steps_taken: 0, scores: Vec::new(), terminal: false })
            .collect();

        // --- the step loop ------------------------------------------------------
        let max_steps = self.cfg.max_steps;
        loop {
            let active: Vec<PathId> = live
                .iter()
                .filter(|p| !p.terminal && p.steps_taken < max_steps)
                .map(|p| p.id)
                .collect();
            if active.is_empty() {
                break;
            }

            let outcomes: Vec<(PathId, StepOutcome, u8)> = if speculative {
                let outs = self.backend.draft_step(&active)?;
                let scores = self.backend.score_step(&active)?;
                let mut acc = Vec::new();
                let mut rej = Vec::new();
                for ((&id, o), &s) in active.iter().zip(outs).zip(&scores) {
                    if s >= tau {
                        acc.push((id, o, s));
                    } else {
                        rej.push((id, o, s));
                    }
                }
                if !acc.is_empty() {
                    let ids: Vec<PathId> = acc.iter().map(|x| x.0).collect();
                    self.backend.accept_step(&ids)?;
                }
                if !rej.is_empty() {
                    let ids: Vec<PathId> = rej.iter().map(|x| x.0).collect();
                    let rewritten = self.backend.rewrite_step(&ids)?;
                    // rewritten steps replace the rejected outcome and are
                    // recorded with score 9 (paper §3.2)
                    rej = ids
                        .into_iter()
                        .zip(rewritten)
                        .map(|(id, o)| (id, o, 9u8))
                        .collect();
                }
                acc.into_iter().chain(rej).collect()
            } else {
                let outs = self.backend.target_step(&active)?;
                // target-generated steps carry full target confidence
                active.iter().zip(outs).map(|(&id, o)| (id, o, 9u8)).collect()
            };

            for (id, outcome, score) in outcomes {
                let lp = live.iter_mut().find(|p| p.id == id).expect("live path");
                lp.steps_taken += 1;
                lp.scores.push(score);
                if outcome.terminal {
                    lp.terminal = true;
                }
            }

            // --- fast modes (paper §3.2) ---------------------------------------
            match stop {
                StopRule::Full => {}
                StopRule::Fast1 => {
                    let any_done = live.iter().any(|p| {
                        p.terminal && self.backend.parse_answer(self.backend.trace(p.id)).is_some()
                    });
                    if any_done {
                        break;
                    }
                }
                StopRule::Fast2 => {
                    let mut finished: Vec<i64> = live
                        .iter()
                        .filter(|p| p.terminal)
                        .filter_map(|p| self.backend.parse_answer(self.backend.trace(p.id)))
                        .collect();
                    finished.sort_unstable();
                    if finished.windows(2).any(|w| w[0] == w[1]) {
                        break;
                    }
                }
            }
        }

        // --- close + vote -------------------------------------------------------
        let mut votes = Vec::with_capacity(live.len());
        let (mut draft_tokens, mut target_tokens, mut score_tokens) = (0, 0, 0);
        let (mut steps, mut rewrites) = (0, 0);
        for lp in &live {
            let stats = self.backend.close_path(lp.id)?;
            let answer = if lp.terminal {
                self.backend.parse_answer(&stats.trace)
            } else {
                // unfinished path (fast mode cut or step cap): no vote
                // unless the trace happens to contain a FIN answer
                self.backend.parse_answer(&stats.trace)
            };
            draft_tokens += stats.draft_tokens;
            target_tokens += stats.target_tokens;
            score_tokens += stats.score_tokens;
            steps += stats.steps;
            rewrites += stats.rewrites;
            votes.push(PathVote { answer, step_scores: lp.scores.clone() });
        }

        Ok(RunResult {
            decision: aggregate(&votes),
            votes,
            draft_tokens,
            target_tokens,
            score_tokens,
            steps,
            rewrites,
            selection,
            wall_secs: t0.elapsed().as_secs_f64(),
            model_secs: self.backend.clock_secs() - clock0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::workload::suites;

    fn setup(suite: &str, seed: u64) -> (CalibratedBackend, Vec<Problem>) {
        let b = CalibratedBackend::for_suite(suite, seed).unwrap();
        let v = test_vocab();
        let s = suites::generate(suites::spec(suite).unwrap(), &v);
        (b, s.problems)
    }

    fn accuracy(suite: &str, method: Method, n_problems: usize, trials: u64) -> f64 {
        let mut correct = 0u64;
        let mut total = 0u64;
        for trial in 0..trials {
            let (mut b, problems) = setup(suite, 1000 + trial);
            let mut eng = Engine::new(&mut b, SsrConfig::default());
            for p in problems.iter().take(n_problems) {
                let r = eng.run(p, method, trial * 7919 + 11).unwrap();
                if r.answer() == Some(p.answer) {
                    correct += 1;
                }
                total += 1;
            }
        }
        correct as f64 / total as f64
    }

    #[test]
    fn baseline_run_shape() {
        let (mut b, problems) = setup("synth-aime", 1);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let r = eng.run(&problems[0], Method::Baseline, 3).unwrap();
        assert_eq!(r.votes.len(), 1);
        assert_eq!(r.draft_tokens, 0);
        assert!(r.target_tokens > 0);
        assert!(r.rewrites == 0);
        assert!(r.model_secs > 0.0);
    }

    #[test]
    fn ssr_run_uses_both_models_and_selects() {
        let (mut b, problems) = setup("synth-math500", 2);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let r = eng
            .run(&problems[0], Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }, 4)
            .unwrap();
        assert_eq!(r.votes.len(), 5);
        assert_eq!(r.selection.len(), 5);
        assert!(r.draft_tokens > 0);
        assert!(r.target_tokens > 0);
        assert!(r.steps > 0);
    }

    #[test]
    fn tau9_rewrites_more_than_tau0() {
        let (mut b, problems) = setup("synth-aime", 3);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let mut hi = 0.0;
        let mut lo = 0.0;
        for (i, p) in problems.iter().take(10).enumerate() {
            let r9 = eng
                .run(p, Method::Ssr { n: 3, tau: 9, stop: StopRule::Full }, i as u64)
                .unwrap();
            let r0 = eng
                .run(p, Method::Ssr { n: 3, tau: 0, stop: StopRule::Full }, i as u64)
                .unwrap();
            hi += r9.rewrite_rate();
            lo += r0.rewrite_rate();
        }
        assert!(hi > lo + 1.0, "tau=9 rate {hi} vs tau=0 rate {lo}");
    }

    #[test]
    fn fast_modes_cost_no_more_than_full() {
        let (mut b, problems) = setup("synth-math500", 4);
        let mut eng = Engine::new(&mut b, SsrConfig::default());
        let mut full = 0u64;
        let mut fast = 0u64;
        for (i, p) in problems.iter().take(12).enumerate() {
            let rf = eng
                .run(p, Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }, i as u64)
                .unwrap();
            let r1 = eng
                .run(p, Method::Ssr { n: 5, tau: 7, stop: StopRule::Fast1 }, i as u64)
                .unwrap();
            full += rf.target_tokens + rf.draft_tokens;
            fast += r1.target_tokens + r1.draft_tokens;
        }
        assert!(fast <= full, "fast1 {fast} > full {full}");
    }

    #[test]
    fn parallel_beats_baseline_on_calibrated_substrate() {
        let base = accuracy("synth-livemath", Method::Baseline, 40, 3);
        let par5 = accuracy("synth-livemath", Method::Parallel { n: 5, spm: false }, 40, 3);
        let spm5 = accuracy("synth-livemath", Method::Parallel { n: 5, spm: true }, 40, 3);
        assert!(par5 > base, "parallel {par5} <= baseline {base}");
        assert!(spm5 > par5 - 0.02, "spm {spm5} much worse than parallel {par5}");
    }

    #[test]
    fn method_names() {
        assert_eq!(Method::Baseline.name(), "baseline");
        assert_eq!(Method::Parallel { n: 5, spm: true }.name(), "parallel-spm-5");
        assert_eq!(Method::SpecReason { tau: 7 }.name(), "spec-reason(7)");
        assert_eq!(
            Method::Ssr { n: 3, tau: 7, stop: StopRule::Fast2 }.name(),
            "ssr-m3-fast2"
        );
    }
}
