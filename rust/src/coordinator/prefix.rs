//! Cross-request prefix-reuse cache: keep prefilled problem prompts
//! alive across solves so repeated or re-sampled problems (pass@k,
//! ablation sweeps, benches re-running a suite) skip prompt prefill
//! entirely (DESIGN.md §2).
//!
//! The cache maps a 64-bit hash of the problem's prompt tokens (plus
//! the draft-cache flag — a speculative fork needs a draft prefix) to a
//! live [`PrefixHandle`]. Capacity is bounded; eviction is
//! least-recently-used and releases the backend-side prefix state.
//! Hit / miss / eviction counters feed the serving [`Metrics`]
//! (`prefix_hits` etc. in `{"op":"stats"}`).
//!
//! Ownership: a handle returned with `retained = true` belongs to the
//! cache (released on eviction or [`PrefixCache::clear`]); with
//! `retained = false` (capacity 0) the caller must release it after
//! forking. Forked lanes never dangle either way — the backend contract
//! says lanes copy what they need at fork time.
//!
//! [`Metrics`]: super::metrics::Metrics

use std::collections::HashMap;

use anyhow::Result;

use crate::backend::{Backend, PrefixHandle};
use crate::workload::Problem;

/// Result of [`PrefixCache::acquire`].
pub struct Acquired {
    pub handle: PrefixHandle,
    /// the cache keeps the handle alive; callers must NOT release it
    pub retained: bool,
    /// served from cache (no prompt prefill happened)
    pub hit: bool,
}

impl Acquired {
    /// A handle the caller prefilled itself and must release.
    pub fn owned(handle: PrefixHandle) -> Self {
        Acquired { handle, retained: false, hit: false }
    }
}

struct Entry {
    handle: PrefixHandle,
    last_used: u64,
}

/// Bounded LRU cache of prefilled prompt prefixes.
pub struct PrefixCache {
    capacity: usize,
    map: HashMap<u64, Entry>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PrefixCache {
    pub fn new(capacity: usize) -> Self {
        PrefixCache { capacity, map: HashMap::new(), tick: 0, hits: 0, misses: 0, evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Configured capacity; 0 = caching disabled (pure passthrough).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// FNV-1a over the prompt tokens, salted with the draft flag — the
    /// same cheap keying the calibrated hardness cache uses; collisions
    /// at 64 bits are negligible against any sane capacity.
    fn key(tokens: &[i32], use_draft: bool) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut mix = |byte: u8| {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        };
        for &t in tokens {
            for b in t.to_le_bytes() {
                mix(b);
            }
        }
        mix(use_draft as u8);
        h
    }

    /// Return a live prefix for `problem`, prefilling on miss. LRU
    /// eviction keeps at most `capacity` prefixes alive on the backend.
    pub fn acquire(
        &mut self,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired> {
        if self.capacity == 0 {
            // caching disabled: behave like a plain prefill the caller owns
            self.misses += 1;
            return Ok(Acquired::owned(backend.prefill_prefix(problem, use_draft, want_scores)?));
        }
        let k = Self::key(&problem.tokens, use_draft);
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&k) {
            e.last_used = self.tick;
            self.hits += 1;
            return Ok(Acquired { handle: e.handle, retained: true, hit: true });
        }
        self.misses += 1;
        // evict BEFORE prefilling so live backend prefixes never exceed
        // the capacity, even transiently. O(capacity) scan per miss at
        // capacity — fine for the bounded caps validate() allows; an
        // ordered LRU is a ROADMAP item if caps ever grow.
        if self.map.len() >= self.capacity {
            if let Some((&old_k, _)) = self.map.iter().min_by_key(|(_, e)| e.last_used) {
                if let Some(old) = self.map.remove(&old_k) {
                    let _ = backend.release_prefix(old.handle);
                    self.evictions += 1;
                }
            }
        }
        let handle = backend.prefill_prefix(problem, use_draft, want_scores)?;
        self.map.insert(k, Entry { handle, last_used: self.tick });
        Ok(Acquired { handle, retained: true, hit: false })
    }

    /// Release every cached prefix (scheduler drain / backend teardown).
    pub fn clear(&mut self, backend: &mut dyn Backend) {
        for (_, e) in self.map.drain() {
            let _ = backend.release_prefix(e.handle);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::model::tokenizer::builtin_vocab;
    use crate::workload::suites;

    fn problems() -> Vec<Problem> {
        let v = builtin_vocab();
        suites::generate(suites::spec("synth-math500").unwrap(), &v).problems
    }

    #[test]
    fn repeat_acquire_hits_and_skips_prefill() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 1).unwrap();
        let mut c = PrefixCache::new(8);
        let p = &problems()[0];
        let a1 = c.acquire(&mut b, p, true, true).unwrap();
        assert!(!a1.hit && a1.retained);
        let a2 = c.acquire(&mut b, p, true, false).unwrap();
        assert!(a2.hit, "second acquire of the same problem must hit");
        assert_eq!(a1.handle, a2.handle);
        assert_eq!((c.hits, c.misses), (1, 1));
        // exactly one backend prefill happened
        assert_eq!(b.prefill_stats().prefixes, 1);
    }

    #[test]
    fn draft_flag_is_part_of_the_key() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 2).unwrap();
        let mut c = PrefixCache::new(8);
        let p = &problems()[0];
        let a1 = c.acquire(&mut b, p, false, false).unwrap();
        let a2 = c.acquire(&mut b, p, true, false).unwrap();
        assert!(!a2.hit, "a draftless prefix must not serve a speculative fork");
        assert_ne!(a1.handle, a2.handle);
    }

    #[test]
    fn capacity_bound_evicts_lru_and_releases() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
        let mut c = PrefixCache::new(2);
        let ps = problems();
        let a0 = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _a1 = c.acquire(&mut b, &ps[1], false, false).unwrap();
        // touch p0 so p1 is the LRU victim when p2 arrives
        let _ = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _a2 = c.acquire(&mut b, &ps[2], false, false).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 1);
        // p0 survived the eviction (recently used, still a hit) ...
        let p0 = c.acquire(&mut b, &ps[0], false, false).unwrap();
        assert!(p0.hit);
        assert_eq!(p0.handle, a0.handle);
        // ... while p1 (the LRU) was evicted: re-acquiring misses
        let again = c.acquire(&mut b, &ps[1], false, false).unwrap();
        assert!(!again.hit);
    }

    #[test]
    fn zero_capacity_passthrough_is_caller_owned() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 4).unwrap();
        let mut c = PrefixCache::new(0);
        let p = &problems()[0];
        let a = c.acquire(&mut b, p, false, false).unwrap();
        assert!(!a.retained && !a.hit);
        assert!(c.is_empty());
        b.release_prefix(a.handle).unwrap();
    }

    #[test]
    fn clear_releases_everything() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 5).unwrap();
        let mut c = PrefixCache::new(8);
        let ps = problems();
        let a = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _ = c.acquire(&mut b, &ps[1], false, false).unwrap();
        c.clear(&mut b);
        assert!(c.is_empty());
        // released on the backend: forking the old handle now fails
        assert!(b.fork_paths(a.handle, &[None], 1).is_err());
    }
}
