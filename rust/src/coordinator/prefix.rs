//! Cross-request prefix reuse: keep prefilled problem prompts alive
//! across solves so repeated or re-sampled problems (pass@k, ablation
//! sweeps, benches re-running a suite) skip prompt prefill entirely
//! (DESIGN.md §2), in two tiers:
//!
//! * [`PrefixCache`] — the single-backend cache (one engine, one
//!   backend): prompt-hash -> live [`PrefixHandle`], LRU-bounded by an
//!   entry cap AND a byte budget (`Backend::prefix_bytes`), so long
//!   prompts can't silently dominate host memory.
//! * [`SharedPrefixTier`] — the sharded serving path's ONE logical
//!   cache (DESIGN.md §10, §11): a prompt has a single tier entry
//!   holding a *per-shard handle map*, because handles are only
//!   meaningful on the backend that issued them. A prompt prefilled on
//!   shard A is admitted as a tier hit everywhere and re-prefilled at
//!   most once per shard that actually serves it (`shard_fills` counts
//!   those). Prefills run OUTSIDE the tier lock behind a per-(entry,
//!   shard) in-flight latch (`Pending` -> `Ready` + condvar), so
//!   different prompts prefill on different shards concurrently while
//!   the once-per-shard guarantee holds — the lock only covers map
//!   bookkeeping. Eviction is LRU over logical entries (entries with an
//!   in-flight fill are pinned); handles owned by other shards cannot
//!   be released from the evicting thread (backends are thread-owned),
//!   so they are parked on per-shard release queues each shard drains
//!   at its next tier interaction. All per-shard state is keyed by
//!   LIVE shard id (maps, not columns): hot-added shards
//!   (`PoolHandle::add_shard`, monotonic ids) insert their own slots
//!   on first use and `clear_shard` leaves no dead-id residue, so
//!   sustained autoscale churn cannot grow the tables (DESIGN.md §12).
//!
//! A third, persistent tier sits UNDER the shared tier (DESIGN.md §17):
//! [`SpillStore`], an append-only on-disk log plus index snapshot.
//! When the hot tier evicts a logical entry, the evicting shard's
//! serialized prefill state (`Backend::export_prefix` — exact for the
//! calibrated backend, best-effort `None` for pjrt) is *demoted* to the
//! store; a later logical miss *promotes* it back via
//! `Backend::import_prefix` (no prompt prefill, no clock charge).
//! `clear_shard` — the graceful drain path — demotes every entry the
//! departing shard holds, so a restarted pool pointed at the same
//! `--prefix-spill-dir` reloads the store at startup and serves the old
//! working set warm (`warm_hits` counts promotes of entries that
//! predate this process). Spill I/O runs under the tier lock, matching
//! the existing release-under-lock discipline: eviction is already a
//! stop-the-tier event and the store does one appending write per
//! demotion.
//!
//! Eviction is policy-selectable (`--prefix-evict lru|cost`):
//! [`EvictPolicy::Lru`] is the historical recency order;
//! [`EvictPolicy::Cost`] keeps the entries that are most expensive to
//! lose — recompute cost from the `flops.rs` closed form (prompt
//! prefill tokens) scaled by the observed refork frequency, recency as
//! the tie-break. Either way prefix reuse stays a cost/clock concern
//! only: run seeds and decisions are untouched (DESIGN.md §2).
//!
//! Ownership: a handle returned with `retained = true` belongs to the
//! cache/tier (released on eviction or clear); with `retained = false`
//! (capacity 0 passthrough) the caller must release it after forking.
//! Forked lanes never dangle either way — the backend contract says
//! lanes copy what they need at fork time. Hit / miss / eviction /
//! shard-fill / spill / promote counters feed the serving [`Metrics`]
//! (`prefix_hits` etc. in `{"op":"stats"}`).
//!
//! [`Metrics`]: super::metrics::Metrics

use std::collections::{HashMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex};

use anyhow::{Context, Result};

use crate::backend::{Backend, PrefixHandle};
use crate::config::EvictPolicy;
use crate::util::hash;
use crate::util::sync::lock_ok;
use crate::workload::Problem;

use super::flops;

/// Result of a prefix acquisition ([`PrefixCache::acquire`] /
/// [`SharedPrefixTier::acquire_for_shard`]).
pub struct Acquired {
    pub handle: PrefixHandle,
    /// the cache keeps the handle alive; callers must NOT release it
    pub retained: bool,
    /// served from cache (no prompt prefill happened)
    pub hit: bool,
}

impl Acquired {
    /// A handle the caller prefilled itself and must release.
    pub fn owned(handle: PrefixHandle) -> Self {
        Acquired { handle, retained: false, hit: false }
    }
}

/// The engine/scheduler-facing seam over "give me a live prefix for
/// this problem": implemented by the single-backend [`PrefixCache`] and
/// by a shard's view of the [`SharedPrefixTier`] ([`ShardPrefix`]), so
/// `ProblemRun::start_with_cache` is tier-agnostic.
pub trait PrefixProvider {
    fn acquire(
        &mut self,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired>;

    /// Configured entry capacity; 0 = caching disabled (passthrough).
    fn capacity(&self) -> usize;
}

/// Prompt-token cache key, salted with the draft-cache flag — a
/// speculative fork needs a draft prefix, so draftless and speculative
/// prefixes of one prompt are distinct entries.
fn prefix_key(tokens: &[i32], use_draft: bool) -> u64 {
    hash::fnv1a_i32(tokens) ^ (use_draft as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

struct Entry {
    handle: PrefixHandle,
    bytes: u64,
    last_used: u64,
}

/// Bounded LRU cache of prefilled prompt prefixes (single backend).
pub struct PrefixCache {
    capacity: usize,
    /// byte budget across live entries (0 = entry cap only)
    max_bytes: u64,
    bytes: u64,
    map: HashMap<u64, Entry>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PrefixCache {
    pub fn new(capacity: usize) -> Self {
        Self::with_limits(capacity, 0)
    }

    /// Entry cap plus a byte budget fed by [`Backend::prefix_bytes`].
    /// The budget bounds the *retained set*: the most recently touched
    /// entry is always admitted (a single over-budget prefix evicts
    /// everything else and lives alone, mirroring the lane pool's
    /// always-admit-into-idle rule).
    pub fn with_limits(capacity: usize, max_bytes: u64) -> Self {
        PrefixCache {
            capacity,
            max_bytes,
            bytes: 0,
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Configured capacity; 0 = caching disabled (pure passthrough).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes currently retained (as reported by the backend).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Evict the LRU entry, skipping `protect`. Returns false when
    /// nothing evictable remains.
    fn evict_lru(&mut self, backend: &mut dyn Backend, protect: Option<u64>) -> bool {
        let victim = self
            .map
            .iter()
            .filter(|(k, _)| Some(**k) != protect)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                let e = self.map.remove(&k).expect("victim key present");
                self.bytes = self.bytes.saturating_sub(e.bytes);
                let _ = backend.release_prefix(e.handle);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Return a live prefix for `problem`, prefilling on miss. LRU
    /// eviction keeps at most `capacity` prefixes (and at most
    /// `max_bytes` retained bytes) alive on the backend.
    pub fn acquire(
        &mut self,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired> {
        if self.capacity == 0 {
            // caching disabled: behave like a plain prefill the caller owns
            self.misses += 1;
            return Ok(Acquired::owned(backend.prefill_prefix(problem, use_draft, want_scores)?));
        }
        let k = prefix_key(&problem.tokens, use_draft);
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&k) {
            e.last_used = self.tick;
            self.hits += 1;
            return Ok(Acquired { handle: e.handle, retained: true, hit: true });
        }
        self.misses += 1;
        // evict BEFORE prefilling so live backend prefixes never exceed
        // the entry cap, even transiently. O(capacity) scan per miss at
        // capacity — fine for the bounded caps validate() allows; an
        // ordered LRU is a ROADMAP item if caps ever grow.
        while self.map.len() >= self.capacity {
            if !self.evict_lru(backend, None) {
                break;
            }
        }
        let handle = backend.prefill_prefix(problem, use_draft, want_scores)?;
        let cost = backend.prefix_bytes(handle);
        self.bytes += cost;
        self.map.insert(k, Entry { handle, bytes: cost, last_used: self.tick });
        // byte budget second (the cost is only known post-prefill):
        // shed LRU entries until under budget, keeping the newcomer
        while self.max_bytes > 0 && self.bytes > self.max_bytes && self.map.len() > 1 {
            if !self.evict_lru(backend, Some(k)) {
                break;
            }
        }
        Ok(Acquired { handle, retained: true, hit: false })
    }

    /// Release every cached prefix (scheduler drain / backend teardown).
    pub fn clear(&mut self, backend: &mut dyn Backend) {
        for (_, e) in self.map.drain() {
            let _ = backend.release_prefix(e.handle);
        }
        self.bytes = 0;
    }
}

impl PrefixProvider for PrefixCache {
    fn acquire(
        &mut self,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired> {
        PrefixCache::acquire(self, backend, problem, use_draft, want_scores)
    }

    fn capacity(&self) -> usize {
        PrefixCache::capacity(self)
    }
}

// ---------------------------------------------------------------------------
// Spill tier: append-only on-disk store for demoted prefixes (§17)
// ---------------------------------------------------------------------------

/// Index entry for one live spill record: where its payload sits in
/// `spill.dat`, plus the prompt length (for the cost policy on
/// re-promotion) and whether the record predates this process.
struct SpillRec {
    offset: u64,
    len: u32,
    prompt_tokens: u64,
    /// loaded from disk at `open` rather than demoted in-process — a
    /// promote of a warm record is a `warm_hits` (warm-restart) hit
    warm: bool,
}

/// Persistent spill tier under the [`SharedPrefixTier`]: an append-only
/// record log (`spill.dat`) plus an index snapshot (`spill.idx`).
///
/// Log format (little-endian), one record per mutation:
/// `[tag u8][key u64][prompt_tokens u32][len u32][payload: len bytes]`
/// with `tag = 1` for a put and `tag = 0` (empty payload) for a
/// delete/tombstone — so the live set is always reconstructible by a
/// forward scan where later records win. The index file is a snapshot
/// (`[dat_len u64][n u32]` then `n × [key u64][offset u64][len
/// u32][prompt_tokens u32]` in insertion order), rewritten atomically
/// (tmp + rename) after each mutation and trusted at `open` only when
/// its `dat_len` stamp matches the log — otherwise the log is scanned.
///
/// A byte budget (`--prefix-spill-bytes`, 0 = unbounded) bounds the
/// LIVE payload bytes: overflow drops the oldest live records with
/// tombstones (the newest record is always admitted, mirroring the hot
/// tiers' always-admit rule). Dead log space is not compacted — the log
/// is bench/restart-scale, not a database; compaction is a ROADMAP item.
pub struct SpillStore {
    dir: PathBuf,
    file: File,
    /// log length in bytes (tracked, not re-stat'ed; append-only)
    dat_len: u64,
    /// live payload byte budget (0 = unbounded)
    max_bytes: u64,
    live_bytes: u64,
    index: HashMap<u64, SpillRec>,
    /// live keys in insertion order (unique; re-put moves to the back)
    order: VecDeque<u64>,
}

const SPILL_HDR: usize = 17; // tag(1) + key(8) + prompt_tokens(4) + len(4)
const SPILL_IDX_ENTRY: usize = 24; // key(8) + offset(8) + len(4) + prompt_tokens(4)

impl SpillStore {
    /// Open (or create) the spill store in `dir`. Records already on
    /// disk are loaded as the warm set for this incarnation.
    pub fn open(dir: &Path, max_bytes: u64) -> Result<SpillStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating prefix spill dir {}", dir.display()))?;
        let dat = dir.join("spill.dat");
        let file = OpenOptions::new()
            .read(true)
            .append(true)
            .create(true)
            .open(&dat)
            .with_context(|| format!("opening {}", dat.display()))?;
        let dat_len = file.metadata()?.len();
        let mut store = SpillStore {
            dir: dir.to_path_buf(),
            file,
            dat_len,
            max_bytes,
            live_bytes: 0,
            index: HashMap::new(),
            order: VecDeque::new(),
        };
        if !store.load_index()? {
            store.scan_dat()?;
            store.write_index()?;
        }
        Ok(store)
    }

    /// Live records.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Live payload bytes (dead log space excluded).
    pub fn bytes_live(&self) -> u64 {
        self.live_bytes
    }

    #[cfg(test)]
    fn contains(&self, key: u64) -> bool {
        self.index.contains_key(&key)
    }

    /// Trust the index snapshot only when its stamp matches the log.
    fn load_index(&mut self) -> Result<bool> {
        let buf = match std::fs::read(self.dir.join("spill.idx")) {
            Ok(b) => b,
            Err(_) => return Ok(false),
        };
        if buf.len() < 12 {
            return Ok(false);
        }
        let stamp = u64::from_le_bytes(buf[0..8].try_into().expect("8 bytes"));
        let n = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes")) as usize;
        if stamp != self.dat_len || buf.len() != 12 + n * SPILL_IDX_ENTRY {
            return Ok(false);
        }
        for i in 0..n {
            let o = 12 + i * SPILL_IDX_ENTRY;
            let key = u64::from_le_bytes(buf[o..o + 8].try_into().expect("8 bytes"));
            let offset = u64::from_le_bytes(buf[o + 8..o + 16].try_into().expect("8 bytes"));
            let len = u32::from_le_bytes(buf[o + 16..o + 20].try_into().expect("4 bytes"));
            let ptoks = u32::from_le_bytes(buf[o + 20..o + 24].try_into().expect("4 bytes"));
            self.order.push_back(key);
            self.index.insert(
                key,
                SpillRec { offset, len, prompt_tokens: ptoks as u64, warm: true },
            );
        }
        self.live_bytes = self.index.values().map(|r| r.len as u64).sum();
        Ok(true)
    }

    /// Rebuild the live set by a forward log scan (later records win,
    /// tombstones delete). A truncated tail record is ignored.
    fn scan_dat(&mut self) -> Result<()> {
        self.index.clear();
        self.order.clear();
        self.file.seek(SeekFrom::Start(0))?;
        let mut pos = 0u64;
        let mut hdr = [0u8; SPILL_HDR];
        while pos + SPILL_HDR as u64 <= self.dat_len {
            self.file.read_exact(&mut hdr)?;
            let tag = hdr[0];
            let key = u64::from_le_bytes(hdr[1..9].try_into().expect("8 bytes"));
            let ptoks = u32::from_le_bytes(hdr[9..13].try_into().expect("4 bytes"));
            let len = u32::from_le_bytes(hdr[13..17].try_into().expect("4 bytes"));
            let payload_off = pos + SPILL_HDR as u64;
            if payload_off + len as u64 > self.dat_len {
                break;
            }
            self.order.retain(|k| *k != key);
            if tag == 1 {
                self.order.push_back(key);
                self.index.insert(
                    key,
                    SpillRec { offset: payload_off, len, prompt_tokens: ptoks as u64, warm: true },
                );
            } else {
                self.index.remove(&key);
            }
            self.file.seek(SeekFrom::Current(len as i64))?;
            pos = payload_off + len as u64;
        }
        self.live_bytes = self.index.values().map(|r| r.len as u64).sum();
        Ok(())
    }

    /// Snapshot the live index atomically (tmp + rename).
    fn write_index(&mut self) -> Result<()> {
        let mut buf = Vec::with_capacity(12 + self.order.len() * SPILL_IDX_ENTRY);
        buf.extend_from_slice(&self.dat_len.to_le_bytes());
        buf.extend_from_slice(&(self.index.len() as u32).to_le_bytes());
        for k in &self.order {
            let r = &self.index[k];
            buf.extend_from_slice(&k.to_le_bytes());
            buf.extend_from_slice(&r.offset.to_le_bytes());
            buf.extend_from_slice(&r.len.to_le_bytes());
            buf.extend_from_slice(&(r.prompt_tokens.min(u32::MAX as u64) as u32).to_le_bytes());
        }
        let tmp = self.dir.join("spill.idx.tmp");
        std::fs::write(&tmp, &buf)?;
        std::fs::rename(&tmp, self.dir.join("spill.idx"))?;
        Ok(())
    }

    /// Append a tombstone and drop `key` from the live set.
    fn delete(&mut self, key: u64) -> Result<()> {
        if let Some(rec) = self.index.remove(&key) {
            self.order.retain(|k| *k != key);
            self.live_bytes = self.live_bytes.saturating_sub(rec.len as u64);
            let mut hdr = [0u8; SPILL_HDR];
            hdr[1..9].copy_from_slice(&key.to_le_bytes());
            self.file.write_all(&hdr)?;
            self.dat_len += SPILL_HDR as u64;
        }
        Ok(())
    }

    /// Demote: append a record for `key` (re-put replaces), then shed
    /// the oldest live records until back under the byte budget.
    fn put(&mut self, key: u64, prompt_tokens: u64, payload: &[u8]) -> Result<()> {
        let len = u32::try_from(payload.len()).context("spill payload too large")?;
        if let Some(old) = self.index.remove(&key) {
            self.live_bytes = self.live_bytes.saturating_sub(old.len as u64);
            self.order.retain(|k| *k != key);
        }
        let mut rec = Vec::with_capacity(SPILL_HDR + payload.len());
        rec.push(1u8);
        rec.extend_from_slice(&key.to_le_bytes());
        rec.extend_from_slice(&(prompt_tokens.min(u32::MAX as u64) as u32).to_le_bytes());
        rec.extend_from_slice(&len.to_le_bytes());
        rec.extend_from_slice(payload);
        self.file.write_all(&rec)?;
        let offset = self.dat_len + SPILL_HDR as u64;
        self.dat_len += rec.len() as u64;
        self.index.insert(key, SpillRec { offset, len, prompt_tokens, warm: false });
        self.order.push_back(key);
        self.live_bytes += len as u64;
        while self.max_bytes > 0 && self.live_bytes > self.max_bytes && self.index.len() > 1 {
            let Some(oldest) = self.order.front().copied() else { break };
            if oldest == key {
                break; // the newcomer is always admitted
            }
            self.delete(oldest)?;
        }
        self.write_index()
    }

    /// Promote: read `key`'s payload and remove it from the live set.
    /// I/O failures degrade to a miss (the record is tombstoned).
    fn take(&mut self, key: u64) -> Option<(Vec<u8>, u64, bool)> {
        let (offset, len, ptoks, warm) = {
            let r = self.index.get(&key)?;
            (r.offset, r.len, r.prompt_tokens, r.warm)
        };
        let mut payload = vec![0u8; len as usize];
        let read_ok = self.file.seek(SeekFrom::Start(offset)).is_ok()
            && self.file.read_exact(&mut payload).is_ok();
        let _ = self.delete(key);
        let _ = self.write_index();
        if read_ok {
            Some((payload, ptoks, warm))
        } else {
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Shared tier: one logical cache, per-shard handle maps (DESIGN.md §10)
// ---------------------------------------------------------------------------

/// Tier-level counters (totals across every shard).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TierStats {
    /// acquisitions whose prompt was already a tier entry (the logical
    /// hit rate — includes first-touch-on-this-shard fills)
    pub hits: u64,
    /// acquisitions that created a new tier entry (one prompt prefill)
    pub misses: u64,
    /// hits that still had to prefill because THIS shard had no handle
    /// yet — bounded by (shards - 1) per entry, the re-prefill cost of
    /// non-affine placement
    pub shard_fills: u64,
    /// logical entries evicted by the capacity/byte bounds
    pub evictions: u64,
    /// evicted/drained entries demoted into the spill store
    pub spills: u64,
    /// logical misses served by promoting a spill record (no prefill;
    /// still counted under `misses` so the hot-tier hit rate is honest)
    pub promotes: u64,
    /// promotes of records that predate this process (warm restarts)
    pub warm_hits: u64,
}

/// One (entry, shard) slot of the tier: the in-flight latch. `Pending`
/// marks a prefill running outside the tier lock on the owning shard's
/// backend; waiters block on the tier condvar until it flips to `Ready`
/// (or is removed again on prefill failure). A shard with no slot in an
/// entry's map simply hasn't served that prompt (the old `Empty`
/// state) — absence IS empty, which is what keeps per-shard state keyed
/// by LIVE shard ids only (monotonic ids under autoscale churn would
/// otherwise grow every entry's column vector forever).
#[derive(Clone, Copy)]
enum SlotState {
    Pending,
    Ready { handle: PrefixHandle, bytes: u64 },
}

struct TierEntry {
    /// shard id -> the prompt's slot on that shard's backend (absent =
    /// the shard never served this prompt)
    per_shard: HashMap<usize, SlotState>,
    last_used: u64,
    /// prompt length in tokens — the recompute cost of losing the entry
    prompt_tokens: u64,
    /// ready-slot hits + shard fills since the entry was created: how
    /// often this prompt actually reforked out of the cache
    reforks: u64,
}

impl TierEntry {
    fn has_pending(&self) -> bool {
        self.per_shard.values().any(|s| matches!(s, SlotState::Pending))
    }

    /// Cost-aware retention value: the prompt-prefill recompute cost
    /// (`flops.rs` closed form at zero forks = the shared prompt pass)
    /// scaled by the observed refork frequency. The eviction victim is
    /// the MINIMUM — cheap-to-recompute, rarely-reforked entries go
    /// first; recency breaks ties.
    fn retain_score(&self) -> u64 {
        (1 + self.reforks) * flops::prefill_tokens_shared(0, self.prompt_tokens, 0)
    }
}

struct TierInner {
    capacity: usize,
    max_bytes: u64,
    bytes: u64,
    tick: u64,
    policy: EvictPolicy,
    map: HashMap<u64, TierEntry>,
    /// handles evicted while their owning shard wasn't the caller:
    /// release must run on the owning shard's thread (backends are
    /// thread-owned), so they park here until that shard next calls in.
    /// Keyed by live shard id; a drained shard's queue leaves with it.
    pending_release: HashMap<usize, Vec<PrefixHandle>>,
    /// persistent demotion target (`--prefix-spill-dir`); None = the
    /// historical evict-and-forget behaviour
    spill: Option<SpillStore>,
    stats: TierStats,
}

impl TierInner {
    /// Evict one logical entry (skipping `protect` and any entry with
    /// an in-flight fill — a `Pending` slot has no handle to release
    /// yet), chosen by the configured policy: LRU recency or minimum
    /// retain-score. If a spill store is configured and the CALLING
    /// shard holds a Ready handle (the only backend this thread may
    /// touch), the entry is demoted to disk before release. This
    /// shard's handle is released inline on `backend`; other shards'
    /// handles park on their pending queues. Returns false when nothing
    /// evictable remains.
    fn evict_one(
        &mut self,
        backend: &mut dyn Backend,
        cur_shard: usize,
        protect: Option<u64>,
    ) -> bool {
        let candidates = || {
            self.map.iter().filter(|(k, e)| Some(**k) != protect && !e.has_pending())
        };
        let victim = match self.policy {
            EvictPolicy::Lru => candidates().min_by_key(|(_, e)| e.last_used),
            EvictPolicy::Cost => {
                candidates().min_by_key(|(_, e)| (e.retain_score(), e.last_used))
            }
        }
        .map(|(&k, _)| k);
        let Some(k) = victim else { return false };
        let e = self.map.remove(&k).expect("victim key present");
        if let (Some(spill), Some(SlotState::Ready { handle, .. })) =
            (self.spill.as_mut(), e.per_shard.get(&cur_shard))
        {
            // demotion is best-effort: a backend without export support
            // (pjrt) or an I/O failure degrades to plain eviction
            if let Some(payload) = backend.export_prefix(*handle) {
                if spill.put(k, e.prompt_tokens, &payload).is_ok() {
                    self.stats.spills += 1;
                }
            }
        }
        for (s, slot) in e.per_shard {
            if let SlotState::Ready { handle, bytes } = slot {
                self.bytes = self.bytes.saturating_sub(bytes);
                if s == cur_shard {
                    let _ = backend.release_prefix(handle);
                } else {
                    self.pending_release.entry(s).or_default().push(handle);
                }
            }
        }
        self.stats.evictions += 1;
        true
    }
}

/// The sharded serving path's shared prefix cache: one logical entry
/// per prompt, one live handle per shard that serves it. The mutex only
/// covers map bookkeeping: a miss (or first-touch shard fill) marks its
/// slot `Pending`, drops the lock, prefills on the caller's backend,
/// then re-locks to publish `Ready` and wake any latch waiter — so
/// different prompts prefill on different shards concurrently while
/// each prompt is still prefilled at most once per shard. Hits (the
/// steady state) pay one map lookup.
pub struct SharedPrefixTier {
    inner: Mutex<TierInner>,
    /// signalled whenever a `Pending` slot resolves (to `Ready` or,
    /// on prefill failure, back to `Empty`)
    filled: Condvar,
}

impl SharedPrefixTier {
    /// `capacity` = logical entry cap (0 disables caching); `max_bytes`
    /// = byte budget summed over every shard's retained handles (0 =
    /// entry cap only). The per-shard tables are maps keyed by live
    /// shard id — any shard (spawn-time or hot-added, ids are
    /// monotonic) inserts its own slots on first use and a drained
    /// shard leaves no residue, so no shard count is declared up
    /// front.
    pub fn new(capacity: usize, max_bytes: u64) -> Self {
        Self::with_options(capacity, max_bytes, EvictPolicy::Lru, None)
    }

    /// Full construction: eviction `policy` (`--prefix-evict`) and an
    /// optional persistent [`SpillStore`] (`--prefix-spill-dir`). With
    /// the defaults (`Lru`, no spill) this is byte-for-byte the
    /// historical tier.
    pub fn with_options(
        capacity: usize,
        max_bytes: u64,
        policy: EvictPolicy,
        spill: Option<SpillStore>,
    ) -> Self {
        SharedPrefixTier {
            inner: Mutex::new(TierInner {
                capacity,
                max_bytes,
                bytes: 0,
                tick: 0,
                policy,
                map: HashMap::new(),
                pending_release: HashMap::new(),
                spill,
                stats: TierStats::default(),
            }),
            filled: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        lock_ok(&self.inner).capacity
    }

    /// Live logical entries.
    pub fn len(&self) -> usize {
        lock_ok(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes retained across all shards.
    pub fn bytes(&self) -> u64 {
        lock_ok(&self.inner).bytes
    }

    pub fn stats(&self) -> TierStats {
        lock_ok(&self.inner).stats.clone()
    }

    /// Live records in the spill tier (0 when no spill dir is set).
    pub fn spill_entries(&self) -> usize {
        lock_ok(&self.inner).spill.as_ref().map_or(0, |s| s.len())
    }

    /// Live payload bytes in the spill tier.
    pub fn spill_bytes(&self) -> u64 {
        lock_ok(&self.inner).spill.as_ref().map_or(0, |s| s.bytes_live())
    }

    /// Return a live prefix for `problem` on `shard`'s backend,
    /// prefilling at most once per (prompt, shard) — the prefill itself
    /// runs OUTSIDE the tier lock behind the entry's `Pending` latch.
    /// Also drains this shard's pending release queue — the only thread
    /// that may touch this backend is the one calling in.
    pub fn acquire_for_shard(
        &self,
        shard: usize,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired> {
        // pending releases are taken under the lock but released on the
        // backend outside it (release cost is the owning shard's alone)
        let (pending, passthrough) = {
            let mut guard = lock_ok(&self.inner);
            (
                guard.pending_release.remove(&shard).unwrap_or_default(),
                guard.capacity == 0,
            )
        };
        for h in pending {
            let _ = backend.release_prefix(h);
        }
        if passthrough {
            lock_ok(&self.inner).stats.misses += 1;
            return Ok(Acquired::owned(backend.prefill_prefix(problem, use_draft, want_scores)?));
        }

        let k = prefix_key(&problem.tokens, use_draft);
        let mut guard = lock_ok(&self.inner);
        loop {
            // plain &mut so field borrows below are disjoint (guard
            // derefs would otherwise re-borrow the whole struct)
            let inner = &mut *guard;
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&k) {
                e.last_used = tick;
                match e.per_shard.get(&shard) {
                    Some(SlotState::Ready { handle, .. }) => {
                        let handle = *handle;
                        e.reforks += 1;
                        inner.stats.hits += 1;
                        return Ok(Acquired { handle, retained: true, hit: true });
                    }
                    Some(SlotState::Pending) => {
                        // another caller is prefilling this (prompt,
                        // shard) outside the lock: wait for the latch.
                        // (With one scheduler thread per shard this arm
                        // is unreachable in serving; the tier does not
                        // assume that threading model.)
                        guard = self
                            .filled
                            .wait(guard)
                            .unwrap_or_else(|e| e.into_inner());
                        continue;
                    }
                    None => {
                        // known prompt, first service on this shard:
                        // latch, then prefill once outside the lock
                        // (the hit/shard-fill counters are bumped on
                        // success, inside fill — a failed prefill must
                        // not inflate the cache-effectiveness stats)
                        e.per_shard.insert(shard, SlotState::Pending);
                        drop(guard);
                        return self
                            .fill(shard, backend, problem, use_draft, want_scores, k, true);
                    }
                }
            }
            // logical miss: promote from the spill tier if the prompt
            // was demoted earlier (no prefill), else make room, insert
            // the latched entry, and prefill outside the lock
            inner.stats.misses += 1;
            if let Some((payload, ptoks, warm)) = inner.spill.as_mut().and_then(|s| s.take(k)) {
                // import under the tier lock, matching the
                // release-under-lock discipline; a failed import (e.g.
                // a backend without import support) consumed the record
                // and degrades to a plain prefill below
                if let Ok(handle) = backend.import_prefix(&payload) {
                    inner.stats.promotes += 1;
                    if warm {
                        inner.stats.warm_hits += 1;
                    }
                    while inner.map.len() >= inner.capacity {
                        if !inner.evict_one(backend, shard, None) {
                            break;
                        }
                    }
                    let cost = backend.prefix_bytes(handle);
                    let per_shard =
                        HashMap::from([(shard, SlotState::Ready { handle, bytes: cost })]);
                    inner.map.insert(
                        k,
                        TierEntry { per_shard, last_used: tick, prompt_tokens: ptoks, reforks: 0 },
                    );
                    inner.bytes += cost;
                    while inner.max_bytes > 0
                        && inner.bytes > inner.max_bytes
                        && inner.map.len() > 1
                    {
                        if !inner.evict_one(backend, shard, Some(k)) {
                            break;
                        }
                    }
                    return Ok(Acquired { handle, retained: true, hit: true });
                }
            }
            while inner.map.len() >= inner.capacity {
                if !inner.evict_one(backend, shard, None) {
                    break;
                }
            }
            let per_shard = HashMap::from([(shard, SlotState::Pending)]);
            inner.map.insert(
                k,
                TierEntry {
                    per_shard,
                    last_used: tick,
                    prompt_tokens: problem.tokens.len() as u64,
                    reforks: 0,
                },
            );
            drop(guard);
            return self.fill(shard, backend, problem, use_draft, want_scores, k, false);
        }
    }

    /// Resolve a `Pending` latch this caller holds for (`k`, `shard`):
    /// prefill on the caller's backend with the tier unlocked, then
    /// publish the handle (or roll the slot back on failure) and wake
    /// latch waiters. `shard_fill` marks a first-touch fill of a known
    /// prompt — its hit/shard-fill counters are recorded only once the
    /// prefill has actually succeeded.
    #[allow(clippy::too_many_arguments)]
    fn fill(
        &self,
        shard: usize,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
        k: u64,
        shard_fill: bool,
    ) -> Result<Acquired> {
        let res = backend.prefill_prefix(problem, use_draft, want_scores);
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        match res {
            Ok(handle) => {
                if shard_fill {
                    inner.stats.hits += 1;
                    inner.stats.shard_fills += 1;
                }
                let cost = backend.prefix_bytes(handle);
                // the entry is pinned by its Pending slot (eviction
                // skips it), so it is still present unless a concurrent
                // clear dropped the whole tier state; then the caller
                // simply owns the prefix
                let retained = match inner.map.get_mut(&k) {
                    Some(e) => {
                        e.per_shard.insert(shard, SlotState::Ready { handle, bytes: cost });
                        if shard_fill {
                            e.reforks += 1;
                        }
                        inner.bytes += cost;
                        true
                    }
                    None => false,
                };
                if retained {
                    while inner.max_bytes > 0
                        && inner.bytes > inner.max_bytes
                        && inner.map.len() > 1
                    {
                        if !inner.evict_one(backend, shard, Some(k)) {
                            break;
                        }
                    }
                }
                self.filled.notify_all();
                Ok(Acquired { handle, retained, hit: false })
            }
            Err(e) => {
                if let Some(entry) = inner.map.get_mut(&k) {
                    entry.per_shard.remove(&shard);
                    if entry.per_shard.is_empty() {
                        inner.map.remove(&k);
                    }
                }
                self.filled.notify_all();
                Err(e)
            }
        }
    }

    /// Release every handle `shard` owns (drain/teardown of that
    /// shard). Logical entries survive while any other shard still
    /// holds (or is filling) a handle; fully-empty entries are dropped.
    /// Called by the shard's own thread, so none of this shard's slots
    /// can be `Pending` here. After this the tier holds NO state keyed
    /// by the dead shard id — the compaction that keeps week-long
    /// autoscale churn from growing the per-shard tables.
    ///
    /// With a spill store configured, each released entry is demoted to
    /// disk first (best-effort) — the graceful-drain path that makes
    /// `--prefix-spill-dir` warm restarts work: the next incarnation
    /// reloads the store at startup and promotes instead of prefilling.
    pub fn clear_shard(&self, shard: usize, backend: &mut dyn Backend) {
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        for h in inner.pending_release.remove(&shard).unwrap_or_default() {
            let _ = backend.release_prefix(h);
        }
        let mut freed = 0u64;
        let mut spilled = 0u64;
        for (k, e) in inner.map.iter_mut() {
            if let Some(SlotState::Ready { handle, bytes }) = e.per_shard.remove(&shard) {
                freed += bytes;
                if let Some(spill) = inner.spill.as_mut() {
                    if let Some(payload) = backend.export_prefix(handle) {
                        if spill.put(*k, e.prompt_tokens, &payload).is_ok() {
                            spilled += 1;
                        }
                    }
                }
                let _ = backend.release_prefix(handle);
            }
        }
        inner.stats.spills += spilled;
        inner.bytes = inner.bytes.saturating_sub(freed);
        inner.map.retain(|_, e| !e.per_shard.is_empty());
        // a crashed shard may have died mid-fill: waiters latched on one
        // of its Pending slots (now removed) must re-check, not sleep on
        // a latch nobody will ever resolve
        self.filled.notify_all();
    }

    /// [`clear_shard`](Self::clear_shard) for a shard whose backend no
    /// longer exists (crash recovery, DESIGN.md §13): the handles died
    /// with the backend, so they are *forgotten* rather than released —
    /// including any `Pending` latch the shard held mid-fill, whose
    /// waiters are woken to re-check.
    pub fn drop_shard(&self, shard: usize) {
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        inner.pending_release.remove(&shard);
        let mut freed = 0u64;
        for e in inner.map.values_mut() {
            if let Some(SlotState::Ready { bytes, .. }) = e.per_shard.remove(&shard) {
                freed += bytes;
            }
        }
        inner.bytes = inner.bytes.saturating_sub(freed);
        inner.map.retain(|_, e| !e.per_shard.is_empty());
        self.filled.notify_all();
    }

    /// Live per-shard slots keyed by a given shard id — 0 once the
    /// shard has been cleared (compaction observable for tests).
    pub fn shard_slot_count(&self, shard: usize) -> usize {
        let inner = lock_ok(&self.inner);
        inner.map.values().filter(|e| e.per_shard.contains_key(&shard)).count()
            + inner.pending_release.get(&shard).map_or(0, |v| v.len())
    }
}

/// One shard's view of the tier — the [`PrefixProvider`] the scheduler
/// threads hand to `ProblemRun::start_with_cache`.
pub struct ShardPrefix<'a> {
    pub tier: &'a SharedPrefixTier,
    pub shard: usize,
}

impl PrefixProvider for ShardPrefix<'_> {
    fn acquire(
        &mut self,
        backend: &mut dyn Backend,
        problem: &Problem,
        use_draft: bool,
        want_scores: bool,
    ) -> Result<Acquired> {
        self.tier.acquire_for_shard(self.shard, backend, problem, use_draft, want_scores)
    }

    fn capacity(&self) -> usize {
        self.tier.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::model::tokenizer::builtin_vocab;
    use crate::workload::suites;

    fn problems() -> Vec<Problem> {
        let v = builtin_vocab();
        suites::generate(suites::spec("synth-math500").unwrap(), &v).problems
    }

    #[test]
    fn repeat_acquire_hits_and_skips_prefill() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 1).unwrap();
        let mut c = PrefixCache::new(8);
        let p = &problems()[0];
        let a1 = c.acquire(&mut b, p, true, true).unwrap();
        assert!(!a1.hit && a1.retained);
        let a2 = c.acquire(&mut b, p, true, false).unwrap();
        assert!(a2.hit, "second acquire of the same problem must hit");
        assert_eq!(a1.handle, a2.handle);
        assert_eq!((c.hits, c.misses), (1, 1));
        // exactly one backend prefill happened
        assert_eq!(b.prefill_stats().prefixes, 1);
    }

    #[test]
    fn draft_flag_is_part_of_the_key() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 2).unwrap();
        let mut c = PrefixCache::new(8);
        let p = &problems()[0];
        let a1 = c.acquire(&mut b, p, false, false).unwrap();
        let a2 = c.acquire(&mut b, p, true, false).unwrap();
        assert!(!a2.hit, "a draftless prefix must not serve a speculative fork");
        assert_ne!(a1.handle, a2.handle);
    }

    #[test]
    fn capacity_bound_evicts_lru_and_releases() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 3).unwrap();
        let mut c = PrefixCache::new(2);
        let ps = problems();
        let a0 = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _a1 = c.acquire(&mut b, &ps[1], false, false).unwrap();
        // touch p0 so p1 is the LRU victim when p2 arrives
        let _ = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _a2 = c.acquire(&mut b, &ps[2], false, false).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 1);
        // p0 survived the eviction (recently used, still a hit) ...
        let p0 = c.acquire(&mut b, &ps[0], false, false).unwrap();
        assert!(p0.hit);
        assert_eq!(p0.handle, a0.handle);
        // ... while p1 (the LRU) was evicted: re-acquiring misses
        let again = c.acquire(&mut b, &ps[1], false, false).unwrap();
        assert!(!again.hit);
    }

    #[test]
    fn byte_bound_evicts_alongside_entry_cap() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 11).unwrap();
        let ps = problems();
        // budget that fits roughly one calibrated prefix (~tokens*4+116)
        let one = {
            let mut probe = CalibratedBackend::for_suite("synth-math500", 11).unwrap();
            let h = probe.prefill_prefix(&ps[0], false, false).unwrap();
            probe.prefix_bytes(h)
        };
        let mut c = PrefixCache::with_limits(8, one + one / 2);
        let _ = c.acquire(&mut b, &ps[0], false, false).unwrap();
        assert_eq!(c.evictions, 0);
        let a1 = c.acquire(&mut b, &ps[1], false, false).unwrap();
        // over budget: the older entry was shed, the newcomer retained
        assert_eq!(c.evictions, 1, "byte budget never evicted");
        assert_eq!(c.len(), 1);
        assert!(c.bytes() <= one + one / 2);
        let again = c.acquire(&mut b, &ps[1], false, false).unwrap();
        assert!(again.hit);
        assert_eq!(again.handle, a1.handle);
        // the shed prefix really was released on the backend
        let back = c.acquire(&mut b, &ps[0], false, false).unwrap();
        assert!(!back.hit);
    }

    #[test]
    fn zero_capacity_passthrough_is_caller_owned() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 4).unwrap();
        let mut c = PrefixCache::new(0);
        let p = &problems()[0];
        let a = c.acquire(&mut b, p, false, false).unwrap();
        assert!(!a.retained && !a.hit);
        assert!(c.is_empty());
        b.release_prefix(a.handle).unwrap();
    }

    #[test]
    fn clear_releases_everything() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 5).unwrap();
        let mut c = PrefixCache::new(8);
        let ps = problems();
        let a = c.acquire(&mut b, &ps[0], false, false).unwrap();
        let _ = c.acquire(&mut b, &ps[1], false, false).unwrap();
        c.clear(&mut b);
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        // released on the backend: forking the old handle now fails
        assert!(b.fork_paths(a.handle, &[None], 1).is_err());
    }

    // --- shared tier -------------------------------------------------------
    //
    // The tier is exercised here with ONE backend playing every shard:
    // handle bookkeeping is per-shard-index, and the calibrated backend
    // issues process-unique handles, so the per-shard map semantics are
    // fully observable without threads.

    #[test]
    fn tier_refills_once_per_shard_then_hits() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 6).unwrap();
        let t = SharedPrefixTier::new(8, 0);
        let p = &problems()[0];
        let a0 = t.acquire_for_shard(0, &mut b, p, true, true).unwrap();
        assert!(!a0.hit && a0.retained);
        // same prompt, other shard: logical hit, one shard-local prefill
        let a1 = t.acquire_for_shard(1, &mut b, p, true, false).unwrap();
        assert!(!a1.hit, "a shard fill still prefills");
        assert_ne!(a0.handle, a1.handle, "shards must not share handles");
        // steady state: both shards hit their own handle
        let b0 = t.acquire_for_shard(0, &mut b, p, true, false).unwrap();
        let b1 = t.acquire_for_shard(1, &mut b, p, true, false).unwrap();
        assert!(b0.hit && b1.hit);
        assert_eq!(b0.handle, a0.handle);
        assert_eq!(b1.handle, a1.handle);
        let s = t.stats();
        assert_eq!((s.misses, s.shard_fills, s.hits), (1, 1, 3));
        assert_eq!(t.len(), 1, "one logical entry for one prompt");
        assert_eq!(b.prefill_stats().prefixes, 2, "exactly once per shard");
    }

    #[test]
    fn tier_eviction_parks_foreign_handles_until_owner_drains() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 7).unwrap();
        let t = SharedPrefixTier::new(1, 0);
        let ps = problems();
        let a0 = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        let a1 = t.acquire_for_shard(1, &mut b, &ps[0], false, false).unwrap();
        // shard 0 brings a second prompt: capacity 1 evicts prompt 0 —
        // shard 0's handle released inline, shard 1's parked
        let _ = t.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
        assert_eq!(t.stats().evictions, 1);
        assert!(b.fork_paths(a0.handle, &[None], 1).is_err(), "own-shard handle not released");
        assert!(b.fork_paths(a1.handle, &[None], 1).is_ok(), "parked handle released early");
        // shard 1's next call drains its pending queue
        let _ = t.acquire_for_shard(1, &mut b, &ps[1], false, false).unwrap();
        assert!(b.fork_paths(a1.handle, &[None], 1).is_err(), "pending release not drained");
    }

    #[test]
    fn tier_byte_budget_counts_all_shards() {
        let ps = problems();
        let one = {
            let mut probe = CalibratedBackend::for_suite("synth-math500", 8).unwrap();
            let h = probe.prefill_prefix(&ps[0], false, false).unwrap();
            probe.prefix_bytes(h)
        };
        let mut b = CalibratedBackend::for_suite("synth-math500", 8).unwrap();
        // budget fits one prompt on both shards, not two prompts
        let t = SharedPrefixTier::new(8, 2 * one + one / 2);
        let _ = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        let _ = t.acquire_for_shard(1, &mut b, &ps[0], false, false).unwrap();
        assert_eq!(t.stats().evictions, 0);
        let _ = t.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
        assert_eq!(t.stats().evictions, 1, "byte budget never evicted");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn tier_zero_capacity_passthrough() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 9).unwrap();
        let t = SharedPrefixTier::new(0, 0);
        let p = &problems()[0];
        let a = t.acquire_for_shard(1, &mut b, p, false, false).unwrap();
        assert!(!a.retained && !a.hit);
        assert!(t.is_empty());
        b.release_prefix(a.handle).unwrap();
    }

    #[test]
    fn tier_clear_shard_keeps_other_shards_entries() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 10).unwrap();
        let t = SharedPrefixTier::new(8, 0);
        let ps = problems();
        let a0 = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        let a1 = t.acquire_for_shard(1, &mut b, &ps[0], false, false).unwrap();
        let b0 = t.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
        t.clear_shard(0, &mut b);
        // shard 0's handles are gone from the backend
        assert!(b.fork_paths(a0.handle, &[None], 1).is_err());
        assert!(b.fork_paths(b0.handle, &[None], 1).is_err());
        // the prompt shard 1 also served survives as a logical entry...
        assert_eq!(t.len(), 1);
        let r1 = t.acquire_for_shard(1, &mut b, &ps[0], false, false).unwrap();
        assert!(r1.hit);
        assert_eq!(r1.handle, a1.handle);
        t.clear_shard(1, &mut b);
        assert!(t.is_empty());
        assert_eq!(t.bytes(), 0);
    }

    #[test]
    fn tier_holds_no_state_for_cleared_shard_ids() {
        // autoscale churn: shard ids are monotonic and never reused, so
        // cycling through 50 of them must leave NO per-id residue — the
        // dead-id compaction (ROADMAP item)
        let mut b = CalibratedBackend::for_suite("synth-math500", 14).unwrap();
        let t = SharedPrefixTier::new(8, 0);
        let ps = problems();
        for shard in 0..50usize {
            let a = t.acquire_for_shard(shard, &mut b, &ps[0], false, false).unwrap();
            assert!(a.retained);
            assert_eq!(t.shard_slot_count(shard), 1);
            t.clear_shard(shard, &mut b);
            assert_eq!(t.shard_slot_count(shard), 0, "shard {shard} left residue");
        }
        assert!(t.is_empty());
        assert_eq!(t.bytes(), 0);
    }

    // --- spill store + policies --------------------------------------------

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ssr-prefix-{}-{}", name, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn spill_store_round_trips_and_rebuilds_from_the_log() {
        let dir = tmp_dir("log");
        {
            let mut s = SpillStore::open(&dir, 0).unwrap();
            s.put(7, 9, b"payload-a").unwrap();
            s.put(8, 3, b"payload-b").unwrap();
            s.put(7, 9, b"payload-c").unwrap(); // re-put replaces
            assert_eq!(s.len(), 2);
            let _ = s.take(8).unwrap();
            assert_eq!(s.len(), 1);
        }
        // a stale/missing index must not matter: the log is the truth
        std::fs::remove_file(dir.join("spill.idx")).unwrap();
        let mut s = SpillStore::open(&dir, 0).unwrap();
        assert_eq!(s.len(), 1);
        assert!(!s.contains(8), "taken record resurrected by the log scan");
        let (payload, ptoks, warm) = s.take(7).unwrap();
        assert_eq!(payload, b"payload-c");
        assert_eq!(ptoks, 9);
        assert!(warm, "records loaded at open are the warm set");
        assert!(s.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_store_byte_budget_drops_oldest() {
        let dir = tmp_dir("budget");
        let mut s = SpillStore::open(&dir, 64).unwrap();
        s.put(1, 4, &[0u8; 40]).unwrap();
        assert_eq!(s.bytes_live(), 40);
        s.put(2, 4, &[1u8; 40]).unwrap(); // 80 > 64: key 1 is shed
        assert_eq!(s.len(), 1);
        assert!(!s.contains(1));
        let (payload, _, warm) = s.take(2).unwrap();
        assert_eq!(payload, vec![1u8; 40]);
        assert!(!warm);
        assert_eq!(s.bytes_live(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cost_policy_keeps_high_refork_entries_where_lru_would_not() {
        let ps = problems();
        // LRU control: p0 is hot (many reforks) but least recent once
        // p1 arrives, so the next miss evicts it
        let mut b = CalibratedBackend::for_suite("synth-math500", 16).unwrap();
        let lru = SharedPrefixTier::new(2, 0);
        for _ in 0..8 {
            let _ = lru.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        }
        let _ = lru.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
        let _ = lru.acquire_for_shard(0, &mut b, &ps[2], false, false).unwrap();
        assert_eq!(lru.stats().evictions, 1);
        let back = lru.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        assert!(!back.hit, "LRU control: the hot-but-older entry was kept");

        // cost policy: p0's refork count outweighs recency — the
        // single-use p1 is the cheaper loss
        let mut b = CalibratedBackend::for_suite("synth-math500", 16).unwrap();
        let t = SharedPrefixTier::with_options(2, 0, EvictPolicy::Cost, None);
        for _ in 0..8 {
            let _ = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        }
        let _ = t.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
        let _ = t.acquire_for_shard(0, &mut b, &ps[2], false, false).unwrap();
        assert_eq!(t.stats().evictions, 1);
        let kept = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        assert!(kept.hit, "cost policy must keep the frequently reforked entry");
    }

    #[test]
    fn spill_tier_demotes_promotes_and_survives_restart() {
        let dir = tmp_dir("warm");
        let ps = problems();
        let mut b = CalibratedBackend::for_suite("synth-math500", 15).unwrap();
        {
            let spill = SpillStore::open(&dir, 0).unwrap();
            let t = SharedPrefixTier::with_options(1, 0, EvictPolicy::Lru, Some(spill));
            let _ = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
            // capacity 1: p1 evicts p0, demoting it to the spill store
            let _ = t.acquire_for_shard(0, &mut b, &ps[1], false, false).unwrap();
            assert_eq!(t.stats().spills, 1);
            assert_eq!(t.spill_entries(), 1);
            // p0 comes back from disk: a promote, not a prefill (p1 is
            // demoted in turn by the capacity bound)
            let before = b.prefill_stats().prefixes;
            let a = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
            assert!(a.hit && a.retained);
            assert_eq!(b.prefill_stats().prefixes, before, "promotion must not prefill");
            let s = t.stats();
            assert_eq!(s.promotes, 1);
            assert_eq!(s.warm_hits, 0, "same-process promote is not a warm hit");
            // graceful drain demotes the survivor for the next incarnation
            t.clear_shard(0, &mut b);
            assert!(t.is_empty());
            assert_eq!(t.spill_entries(), 2);
        }
        assert_eq!(b.live_prefix_count(), 0, "drain leaked backend prefixes");
        // warm restart: a fresh tier over the same dir serves the old
        // working set without prefilling
        let spill = SpillStore::open(&dir, 0).unwrap();
        assert_eq!(spill.len(), 2);
        let t = SharedPrefixTier::with_options(8, 0, EvictPolicy::Lru, Some(spill));
        let before = b.prefill_stats().prefixes;
        let a = t.acquire_for_shard(0, &mut b, &ps[0], false, false).unwrap();
        assert!(a.hit);
        assert_eq!(b.prefill_stats().prefixes, before);
        let s = t.stats();
        assert_eq!((s.promotes, s.warm_hits), (1, 1));
        t.clear_shard(0, &mut b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tier_eviction_under_byte_pressure_with_concurrent_shard_churn() {
        use std::sync::Arc;
        // byte-budget eviction racing hot shard remove/re-add: parked
        // mid-release handles must neither leak nor double-release. One
        // calibrated backend PER THREAD (backends are thread-owned);
        // the tier is the only shared state.
        let ps = problems();
        let one = {
            let mut probe = CalibratedBackend::for_suite("synth-math500", 17).unwrap();
            let h = probe.prefill_prefix(&ps[0], false, false).unwrap();
            probe.prefix_bytes(h)
        };
        let t = Arc::new(SharedPrefixTier::with_options(64, 2 * one, EvictPolicy::Cost, None));
        let mut joins = Vec::new();
        for shard in 0..4usize {
            let t = Arc::clone(&t);
            let ps = ps.clone();
            joins.push(std::thread::spawn(move || {
                let mut b =
                    CalibratedBackend::for_suite("synth-math500", 20 + shard as u64).unwrap();
                for round in 0..40usize {
                    let p = &ps[(round + shard) % 6];
                    let a = t.acquire_for_shard(shard, &mut b, p, false, false).unwrap();
                    if !a.retained {
                        let _ = b.release_prefix(a.handle);
                    }
                    if round % 9 == 8 {
                        // hot remove + re-add of this shard id's state
                        t.clear_shard(shard, &mut b);
                    }
                }
                t.clear_shard(shard, &mut b);
                b
            }));
        }
        let backends: Vec<CalibratedBackend> =
            joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert!(t.is_empty(), "entries outlived every shard");
        assert_eq!(t.bytes(), 0);
        for (i, b) in backends.iter().enumerate() {
            assert_eq!(b.live_prefix_count(), 0, "shard {i} leaked prefix handles");
        }
        assert!(t.stats().evictions > 0, "budget pressure never evicted");
    }

    #[test]
    fn shard_prefix_provider_routes_to_its_shard() {
        let mut b = CalibratedBackend::for_suite("synth-math500", 12).unwrap();
        let t = SharedPrefixTier::new(8, 0);
        let p = &problems()[0];
        let a = {
            let mut v0 = ShardPrefix { tier: &t, shard: 0 };
            assert_eq!(v0.capacity(), 8);
            PrefixProvider::acquire(&mut v0, &mut b, p, false, false).unwrap()
        };
        let c = {
            let mut v1 = ShardPrefix { tier: &t, shard: 1 };
            PrefixProvider::acquire(&mut v1, &mut b, p, false, false).unwrap()
        };
        assert_ne!(a.handle, c.handle);
        assert_eq!(t.stats().shard_fills, 1);
    }
}
