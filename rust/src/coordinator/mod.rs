//! L3 coordinator — the paper's system contribution, as a serving stack:
//!
//! * [`spm`] — Selective Parallel Module (strategy selection, §3.1)
//! * [`engine`] — the SSD step loop, baselines, spec-reason, fast modes
//! * [`aggregation`] — majority + score-based voting (§3.2)
//! * [`flops`] — normalized-FLOPs gamma accounting (Appendix B)
//! * [`server`] — TCP front-end, FIFO scheduler, engine thread
//! * [`metrics`] — latency/throughput/score instrumentation

pub mod aggregation;
pub mod engine;
pub mod flops;
pub mod metrics;
pub mod server;
pub mod spm;

pub use engine::{Engine, Method, RunResult};
