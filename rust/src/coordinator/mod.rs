//! L3 coordinator — the paper's system contribution, as a serving stack:
//!
//! * [`spm`] — Selective Parallel Module (strategy selection, §3.1)
//! * [`engine`] — the resumable [`engine::ProblemRun`] step machine,
//!   the shared [`engine::step_tick`] batcher, baselines, spec-reason,
//!   fast modes, and the single-problem [`Engine`] wrapper
//! * [`aggregation`] — majority + score-based voting (§3.2)
//! * [`flops`] — normalized-FLOPs gamma accounting (Appendix B)
//! * [`scheduler`] — cross-request continuous batching: lane-pool
//!   admission + one shared step batch per tick over every in-flight
//!   problem (serving & scheduling design notes live in its docs)
//! * [`pool`] — the sharded execution layer: one scheduler thread per
//!   backend shard, least-loaded/affinity/round-robin placement over an
//!   immutable snapshot at submit, drain-on-shutdown across shards,
//!   live run migration on drain/steal (DESIGN.md §10, §12)
//! * [`autoscaler`] — queue-driven scale policy over the elastic pool:
//!   admission-wait/queue-depth EWMAs (plus the interactive-p99 SLO
//!   signal, bounded by the cost ceiling) with hysteresis and cooldown
//!   drive `add_shard`/`remove_shard` within `[min, max]` (§12)
//! * [`admission`] — overload protection at the intake boundary:
//!   per-tenant token buckets, per-class bounded queues with weighted
//!   dequeue, fair-share lane quotas, and SLO-driven shedding (§14)
//! * [`prefix`] — prefix reuse: the single-backend `PrefixCache` and
//!   the pool's `SharedPrefixTier` (one logical cache, per-shard handle
//!   maps); repeated problems skip prompt prefill entirely
//! * [`server`] — nonblocking TCP front-end feeding the pool: framed or
//!   JSON-lines transport, request multiplexing, and streamed progress
//!   (PROTOCOL.md, DESIGN.md §16)
//! * [`protocol`] — the versioned wire protocol: frame codec, error
//!   envelope, and the machine-readable error-code enum
//! * [`events`] — bounded drop-oldest stream taps routing step-boundary
//!   events from shard threads to connections ([`ReplySink`])
//! * [`metrics`] — latency/throughput/occupancy/shard instrumentation

pub mod admission;
pub mod aggregation;
pub mod autoscaler;
pub mod engine;
pub mod events;
pub mod flops;
pub mod metrics;
pub mod pool;
pub mod prefix;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod spm;

pub use admission::{AdmissionController, QosClass};
pub use autoscaler::Autoscaler;
pub use engine::{DetachedRun, Engine, Method, ProblemRun, RunResult};
pub use events::{EventTap, ReplySink};
pub use pool::{BackendPool, PoolHandle};
pub use prefix::{PrefixCache, SharedPrefixTier};
pub use scheduler::{Scheduler, SchedulerHandle, SolveRequest};
