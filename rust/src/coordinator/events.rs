//! Stream-event plumbing between shard threads and the serving front
//! end (DESIGN.md §16, PROTOCOL.md).
//!
//! A streamed solve (`"stream":true`) subscribes its connection to the
//! run's step-boundary events. The shard thread is the producer and
//! must NEVER block on a slow reader, so the channel is a bounded
//! ring with drop-oldest backpressure: [`EventTap::push_batch`] is
//! non-blocking, overflow evicts the oldest queued event and counts it
//! (`stream_drops` in `{"op":"stats"}`), and the terminal `result`
//! frame never travels through the tap at all — it rides the reply
//! channel, so backpressure can drop progress telemetry but never the
//! answer.
//!
//! [`ReplySink`] bundles the terminal reply sender with the optional
//! tap so the scheduler threads one handle through queueing, stealing,
//! migration and crash re-admission — a migrated or recovered run keeps
//! streaming to its original connection because the tap is an `Arc`
//! travelling inside its [`RunTicket`](super::scheduler) clone.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use crate::util::json::Value;
use crate::util::sync::lock_ok;

/// Bounded drop-oldest event buffer for one streamed solve. Cheap to
/// clone (shared state); producers and the consumer never block each
/// other beyond a short critical section.
#[derive(Clone)]
pub struct EventTap {
    state: Arc<TapState>,
}

struct TapState {
    buf: Mutex<VecDeque<Value>>,
    /// ring capacity (`--stream-buffer`); overflow evicts the oldest
    cap: usize,
    /// events evicted by overflow since the stream started
    dropped: AtomicU64,
    /// latched by the first `first_vote` emission (exactly-once)
    first_vote: AtomicBool,
    /// token total already announced via a `token_delta` event — lives
    /// on the shared tap state (not the scheduler's per-shard run
    /// bookkeeping) so a migrated run resumes its delta stream where
    /// the previous shard left off
    tokens_reported: AtomicU64,
    /// client `request_id`, stamped onto every queued event
    request_id: Option<Value>,
}

impl EventTap {
    pub fn new(cap: usize, request_id: Option<Value>) -> EventTap {
        EventTap {
            state: Arc::new(TapState {
                buf: Mutex::new(VecDeque::new()),
                cap: cap.max(1),
                dropped: AtomicU64::new(0),
                first_vote: AtomicBool::new(false),
                tokens_reported: AtomicU64::new(0),
                request_id,
            }),
        }
    }

    /// Queue a step boundary's events atomically (one lock: a consumer
    /// cannot observe half a boundary). Never blocks; when the batch
    /// overflows the ring the OLDEST events are evicted — fresh
    /// telemetry always wins. Returns how many events were dropped.
    pub fn push_batch(&self, events: Vec<Value>) -> u64 {
        let mut dropped = 0u64;
        let mut buf = lock_ok(&self.state.buf);
        for mut ev in events {
            if let (Some(id), Value::Obj(map)) = (&self.state.request_id, &mut ev) {
                map.insert("request_id".into(), id.clone());
            }
            while buf.len() >= self.state.cap {
                buf.pop_front();
                dropped += 1;
            }
            buf.push_back(ev);
        }
        drop(buf);
        if dropped > 0 {
            self.state.dropped.fetch_add(dropped, Ordering::Relaxed);
        }
        dropped
    }

    /// Take everything queued (consumer side; the server's event loop).
    pub fn drain(&self) -> Vec<Value> {
        lock_ok(&self.state.buf).drain(..).collect()
    }

    /// Total events evicted by backpressure since the stream started.
    pub fn dropped(&self) -> u64 {
        self.state.dropped.load(Ordering::Relaxed)
    }

    /// Latch the first-vote emission; true exactly once per run.
    pub fn mark_first_vote(&self) -> bool {
        !self.state.first_vote.swap(true, Ordering::Relaxed)
    }

    /// Advance the announced token total to `total`, returning how many
    /// tokens are newly accounted since the last call (0 when the total
    /// has not moved — emit nothing then). Totals are monotone per run,
    /// so the swap makes the sum of all emitted deltas equal the final
    /// total even across migration/steal re-homing.
    pub fn token_delta(&self, total: u64) -> u64 {
        total.saturating_sub(self.state.tokens_reported.swap(total, Ordering::Relaxed))
    }
}

/// The reply handle one solve carries through the scheduler: the
/// terminal reply sender plus the optional stream tap. Replaces the
/// bare `mpsc::Sender` so event routing survives every re-homing path
/// (steal, migration, crash re-admission) without extra plumbing.
#[derive(Clone)]
pub struct ReplySink {
    tx: mpsc::Sender<Result<Value>>,
    pub events: Option<EventTap>,
}

impl ReplySink {
    pub fn with_events(tx: mpsc::Sender<Result<Value>>, events: Option<EventTap>) -> ReplySink {
        ReplySink { tx, events }
    }

    /// Forward the terminal reply; same contract as `mpsc::Sender::send`
    /// (an error only means the requester is gone — callers ignore it).
    pub fn send(
        &self,
        v: Result<Value>,
    ) -> std::result::Result<(), mpsc::SendError<Result<Value>>> {
        self.tx.send(v)
    }
}

impl From<mpsc::Sender<Result<Value>>> for ReplySink {
    fn from(tx: mpsc::Sender<Result<Value>>) -> ReplySink {
        ReplySink { tx, events: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn ev(step: i64) -> Value {
        json::obj(vec![("event", json::s("progress")), ("steps", json::i(step))])
    }

    #[test]
    fn drop_oldest_under_overflow() {
        let tap = EventTap::new(2, None);
        assert_eq!(tap.push_batch(vec![ev(1), ev(2)]), 0);
        // cap 2: pushing a third evicts the oldest
        assert_eq!(tap.push_batch(vec![ev(3)]), 1);
        let got = tap.drain();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].get_i64("steps").unwrap(), 2);
        assert_eq!(got[1].get_i64("steps").unwrap(), 3);
        assert_eq!(tap.dropped(), 1);
    }

    #[test]
    fn batch_overflow_drops_within_one_lock() {
        // cap 1, batch of 2: the consumer can never observe the first
        // event — it is evicted before the lock is released. This is
        // the deterministic slow-consumer case the protocol tests use.
        let tap = EventTap::new(1, None);
        assert_eq!(tap.push_batch(vec![ev(1), ev(2)]), 1);
        let got = tap.drain();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].get_i64("steps").unwrap(), 2);
    }

    #[test]
    fn request_id_is_stamped_on_every_event() {
        let tap = EventTap::new(8, Some(json::s("req-7")));
        tap.push_batch(vec![ev(1), ev(2)]);
        for e in tap.drain() {
            assert_eq!(e.get_str("request_id").unwrap(), "req-7");
        }
    }

    #[test]
    fn first_vote_latches_once() {
        let tap = EventTap::new(8, None);
        assert!(tap.mark_first_vote());
        assert!(!tap.mark_first_vote());
        let clone = tap.clone();
        assert!(!clone.mark_first_vote(), "latch is shared state");
    }

    #[test]
    fn token_deltas_sum_to_the_final_total() {
        let tap = EventTap::new(8, None);
        assert_eq!(tap.token_delta(0), 0, "no tokens yet, nothing to announce");
        let mut announced = 0;
        for total in [3u64, 3, 10, 42] {
            announced += tap.token_delta(total);
        }
        assert_eq!(announced, 42);
        // a re-homed run keeps counting on the shared state
        assert_eq!(tap.clone().token_delta(50), 8);
    }

    #[test]
    fn reply_sink_forwards_and_survives_clone() {
        let (tx, rx) = mpsc::channel();
        let sink: ReplySink = tx.into();
        assert!(sink.events.is_none());
        let clone = sink.clone();
        clone.send(Ok(json::s("hi"))).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().str().unwrap(), "hi");
    }
}
