//! Selective Parallel Module (paper §3.1): rather than exhaustively
//! running all K = 12 strategies, ask the target model which n << K are
//! most promising for this problem and instantiate only those.
//!
//! The model-internal score is the target's next-token distribution over
//! the strategy tokens at the selection position (`Backend::
//! select_scores`) — the near-zero-cost control mechanism the paper
//! describes (one prompt prefill). Ablation modes: uniform random
//! (naive parallel with prompts) and the ground-truth aptitude oracle.

use anyhow::Result;

use crate::backend::{Backend, PrefixHandle};
use crate::config::Selection;
use crate::model::sampler;
use crate::util::rng::Rng;
use crate::workload::strategies::{self, NUM_REAL_STRATEGIES};
use crate::workload::Problem;

/// Pick `n` strategies from the first `pool_size` entries of the pool,
/// fetching model scores (only when the mode needs them) via `scores`.
fn choose(
    mode: Selection,
    pool_size: usize,
    n: usize,
    problem: &Problem,
    rng: &mut Rng,
    scores: &mut dyn FnMut() -> Result<Vec<f32>>,
) -> Result<Vec<usize>> {
    let k = pool_size.min(NUM_REAL_STRATEGIES);
    let n = n.min(k);
    Ok(match mode {
        Selection::ModelTopN => sampler::top_n(&scores()?[..k], n),
        Selection::ModelSample => sampler::sample_n_distinct(&scores()?[..k], n, 1.0, rng),
        Selection::Random => {
            let mut pool: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut pool);
            pool.truncate(n);
            pool
        }
        Selection::Oracle => {
            let meta = strategies::builtin_meta();
            strategies::oracle_ranking(&meta, problem.family)
                .into_iter()
                .filter(|&s| s < k)
                .take(n)
                .collect()
        }
    })
}

/// Pick `n` strategies from the first `pool_size` entries of the pool.
/// Model-scored modes run a standalone bare-prompt scoring prefill.
pub fn select(
    backend: &mut dyn Backend,
    problem: &Problem,
    pool_size: usize,
    n: usize,
    mode: Selection,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let mut get = || backend.select_scores(problem);
    choose(mode, pool_size, n, problem, rng, &mut get)
}

/// Like [`select`], but model-scored modes read the logits off an
/// already-prefilled shared prefix — the "SPM rides the prefix prefill"
/// half of the prefix-reuse tentpole: zero extra model passes.
pub fn select_prefixed(
    backend: &mut dyn Backend,
    handle: PrefixHandle,
    problem: &Problem,
    pool_size: usize,
    n: usize,
    mode: Selection,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let mut get = || backend.prefix_scores(handle);
    choose(mode, pool_size, n, problem, rng, &mut get)
}

/// Quality of a selection: mean aptitude of the chosen strategies for the
/// problem's family (diagnostic surfaced by the SPM ablation).
pub fn selection_quality(strats: &[usize], problem: &Problem) -> f64 {
    if strats.is_empty() {
        return 0.0;
    }
    let meta = strategies::builtin_meta();
    strats
        .iter()
        .map(|&s| strategies::aptitude(&meta, s, problem.family))
        .sum::<f64>()
        / strats.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::model::tokenizer::builtin_vocab as test_vocab;
    use crate::workload::suites;

    fn problems() -> Vec<Problem> {
        let v = test_vocab();
        suites::generate(suites::spec("synth-livemath").unwrap(), &v).problems
    }

    #[test]
    fn returns_n_distinct_in_pool() {
        let mut b = CalibratedBackend::for_suite("synth-livemath", 1).unwrap();
        let mut rng = Rng::new(2);
        for mode in
            [Selection::ModelTopN, Selection::ModelSample, Selection::Random, Selection::Oracle]
        {
            for p in problems().iter().take(5) {
                let s = select(&mut b, p, 12, 5, mode, &mut rng).unwrap();
                assert_eq!(s.len(), 5, "{mode:?}");
                let mut d = s.clone();
                d.sort_unstable();
                d.dedup();
                assert_eq!(d.len(), 5, "{mode:?} produced duplicates");
                assert!(s.iter().all(|&x| x < 12));
            }
        }
    }

    #[test]
    fn model_selection_beats_random_on_average() {
        // The SPM claim in miniature: model-internal scores pick
        // higher-aptitude strategies than uniform random.
        let mut b = CalibratedBackend::for_suite("synth-livemath", 3).unwrap();
        let mut rng = Rng::new(4);
        let ps = problems();
        let (mut q_model, mut q_rand) = (0.0, 0.0);
        for p in ps.iter().take(60) {
            let sm = select(&mut b, p, 12, 5, Selection::ModelTopN, &mut rng).unwrap();
            let sr = select(&mut b, p, 12, 5, Selection::Random, &mut rng).unwrap();
            q_model += selection_quality(&sm, p);
            q_rand += selection_quality(&sr, p);
        }
        assert!(
            q_model > q_rand + 1.0,
            "model {q_model:.2} should beat random {q_rand:.2}"
        );
    }

    #[test]
    fn oracle_is_upper_bound() {
        let mut b = CalibratedBackend::for_suite("synth-livemath", 5).unwrap();
        let mut rng = Rng::new(6);
        for p in problems().iter().take(30) {
            let so = select(&mut b, p, 12, 3, Selection::Oracle, &mut rng).unwrap();
            let sm = select(&mut b, p, 12, 3, Selection::ModelTopN, &mut rng).unwrap();
            assert!(selection_quality(&so, p) >= selection_quality(&sm, p) - 1e-9);
        }
    }

    #[test]
    fn prefixed_selection_matches_standalone() {
        // The SPM logits riding a shared prefix are the very numbers a
        // standalone scoring prefill would produce.
        for (i, p) in problems().iter().take(6).enumerate() {
            let mut a = CalibratedBackend::for_suite("synth-livemath", 40 + i as u64).unwrap();
            let mut b = CalibratedBackend::for_suite("synth-livemath", 40 + i as u64).unwrap();
            let mut rng_a = Rng::new(10);
            let mut rng_b = Rng::new(10);
            let sa = select(&mut a, p, 12, 5, Selection::ModelTopN, &mut rng_a).unwrap();
            let h = b.prefill_prefix(p, false, true).unwrap();
            let sb =
                select_prefixed(&mut b, h, p, 12, 5, Selection::ModelTopN, &mut rng_b).unwrap();
            b.release_prefix(h).unwrap();
            assert_eq!(sa, sb, "problem {i}");
            // and no standalone SPM prefill tokens were spent
            assert_eq!(b.prefill_stats().spm_prompt_tokens, 0);
            assert!(a.prefill_stats().spm_prompt_tokens > 0);
        }
    }

    #[test]
    fn n_clamped_to_pool() {
        let mut b = CalibratedBackend::for_suite("synth-livemath", 7).unwrap();
        let mut rng = Rng::new(8);
        let p = &problems()[0];
        let s = select(&mut b, p, 4, 9, Selection::Random, &mut rng).unwrap();
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|&x| x < 4));
    }
}
