//! `BackendPool`: the elastic sharded execution layer (DESIGN.md §10,
//! §11).
//!
//! One scheduler thread per backend shard, each owning its own
//! `Box<dyn Backend>` (PJRT wrapper types are not Send, so a backend
//! never leaves the thread that built it), its own lane pool, and a
//! *shared* admission queue slot (so idle shards can steal from it),
//! running the step-tick loop (`coordinator::scheduler::run_loop`).
//! Work is routed at submit time by a placement policy:
//!
//! * **least-loaded** (default) — argmin over the pool-wide load
//!   gauges (outstanding lane estimates, incremented at submit and
//!   returned on the terminal reply). Balances mixed loads; ties break
//!   to the lowest slot so single-stream traffic stays put.
//! * **affinity** — hash of the request expression mod live shards:
//!   every repeat of a prompt lands on the shard that already holds its
//!   prefilled prefix, maximizing tier hits at the cost of balance
//!   under skewed prompt distributions.
//! * **round-robin** — strict rotation (load-blind; the bench
//!   baseline).
//!
//! The shard set is **elastic** at runtime:
//!
//! * [`PoolHandle::add_shard`] spawns a new scheduler thread (its
//!   backend built by the pool's stored factory ON that thread),
//!   registers it with the placement table, and lets the shared prefix
//!   tier grow its per-shard tables on the shard's first acquisition.
//! * [`PoolHandle::remove_shard`] marks the shard draining and removes
//!   it from the placement table (no new placements, no stealing), re-
//!   places its queued-but-unstarted jobs onto the survivors, closes
//!   its channel, and blocks until the shard has finished its in-flight
//!   runs, released its prefix-tier handles, and flushed its clock
//!   gauges — all while the other shards keep serving. `min_shards`
//!   bounds how far the pool can drain.
//! * **Work stealing** (`steal_threshold > 0`): a shard whose occupancy
//!   stays below the threshold for a full tick pulls queued jobs from
//!   the most-loaded shard's admission queue ([`ShardRegistry::
//!   steal_into`]). Stolen runs re-derive their state from the
//!   placement-invariant run seed, so decisions are identical wherever
//!   a job lands (asserted in `tests/sharding.rs` and
//!   `benches/elastic_shards.rs`).
//!
//! The shards share ONE logical prefix cache
//! ([`SharedPrefixTier`](super::prefix::SharedPrefixTier)): a prompt
//! prefilled on shard A is admitted as a tier hit everywhere and
//! re-prefilled at most once per shard that serves it. Throughput
//! scales with shard count because each shard's backend clock advances
//! independently — `Metrics::model_secs_makespan` (max over shards) is
//! the virtual wall-clock the serving benches divide by.
//!
//! Shutdown / drain: dropping every [`PoolHandle`] clone closes every
//! shard's channel; each shard finishes its queued and in-flight work,
//! releases its tier handles, flushes its clock gauge, and exits —
//! `BackendPool::spawn`'s join handles complete in any order. Shard
//! threads hold only a `Weak` registry reference, so they never keep
//! their own channels alive.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::metrics::Metrics;
use super::prefix::SharedPrefixTier;
use super::scheduler::{self, lane_estimate, QueuedJob, ShardCtx, SolveRequest};
use crate::backend::Backend;
use crate::config::{PlacePolicy, SsrConfig};
use crate::runtime::Vocab;
use crate::util::hash;

/// Hard cap on concurrently live shards (matches `SsrConfig::validate`).
const MAX_SHARDS: usize = 64;

/// Try to hand `req` to the slot at `first`, rotating past dead shards
/// (closed channels) and moving `est` onto the accepting shard's load
/// gauge. Shared by `PoolHandle::submit` and the drain's job
/// re-placement so the fallback semantics cannot diverge. Returns false
/// when every slot's channel is gone.
fn send_with_fallback(slots: &[ShardSlot], first: usize, est: u64, req: SolveRequest) -> bool {
    let n = slots.len();
    let mut req = req;
    for attempt in 0..n {
        let s = &slots[(first + attempt) % n];
        s.load.fetch_add(est, Ordering::Relaxed);
        match s.tx.send(req) {
            Ok(()) => return true,
            Err(mpsc::SendError(returned)) => {
                s.load.fetch_sub(est, Ordering::Relaxed);
                req = returned;
            }
        }
    }
    false
}

type BackendFactory = dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync;

/// One live shard's registry entry. The queue / load / draining cells
/// are shared with the shard's own `ShardCtx`, which is what lets
/// submit, steal, and drain coordinate with the running loop.
pub(crate) struct ShardSlot {
    pub(crate) id: usize,
    tx: mpsc::Sender<SolveRequest>,
    pub(crate) queue: Arc<Mutex<VecDeque<QueuedJob>>>,
    pub(crate) load: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    /// closed (recv errors) when the shard thread has fully exited —
    /// after its drain flushed the final clock/tier gauges
    done_rx: mpsc::Receiver<()>,
    /// retained for hot-added shards so `remove_shard` can reap the
    /// thread after the done signal; initial shards hand their join
    /// handles to `BackendPool::spawn`'s caller instead
    join: Option<std::thread::JoinHandle<()>>,
}

/// Shared pool state: the live shard table plus everything needed to
/// spawn a new shard at runtime. Shard threads hold this only weakly.
pub(crate) struct ShardRegistry {
    cfg: SsrConfig,
    vocab: Vocab,
    metrics: Arc<Mutex<Metrics>>,
    tier: Arc<SharedPrefixTier>,
    factory: Box<BackendFactory>,
    next_id: AtomicUsize,
    pub(crate) slots: Mutex<Vec<ShardSlot>>,
}

impl ShardRegistry {
    /// Spawn one shard thread for `id` and return its registry slot —
    /// the caller inserts it into `slots`. The backend is built by the
    /// stored factory ON the new thread.
    fn spawn_shard(
        self: &Arc<Self>,
        id: usize,
    ) -> Result<(ShardSlot, std::thread::JoinHandle<()>)> {
        let (tx, rx) = mpsc::channel::<SolveRequest>();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let queue = Arc::new(Mutex::new(VecDeque::new()));
        let load = Arc::new(AtomicU64::new(0));
        let draining = Arc::new(AtomicBool::new(false));
        let ctx = ShardCtx {
            shard: id,
            tier: Arc::clone(&self.tier),
            load: Arc::clone(&load),
            queue: Arc::clone(&queue),
            draining: Arc::clone(&draining),
            registry: Arc::downgrade(self),
        };
        let cfg = self.cfg.clone();
        let vocab = self.vocab.clone();
        let metrics = Arc::clone(&self.metrics);
        let join = std::thread::Builder::new()
            .name(format!("ssr-shard-{id}"))
            .spawn(move || {
                // dropped when the thread exits — the drain signal
                let _done = done_tx;
                // build the backend via a briefly-upgraded registry ref,
                // then drop the strong ref before serving: a shard that
                // kept the registry alive would keep its own channel
                // sender alive and the pool could never drain
                let backend = match ctx.registry.upgrade() {
                    Some(reg) => (reg.factory)(id),
                    None => return,
                };
                match backend {
                    Ok(mut b) => {
                        scheduler::run_loop(b.as_mut(), &cfg, &vocab, rx, &metrics, &ctx)
                    }
                    Err(e) => log::error!("shard {id} backend init failed: {e:#}"),
                }
            })
            .with_context(|| format!("spawning scheduler shard {id}"))?;
        Ok((ShardSlot { id, tx, queue, load, draining, done_rx, join: None }, join))
    }

    /// Move queued-but-unstarted jobs from the most-loaded other shard
    /// into `ctx`'s queue, up to `room` lanes' worth. The thief steals
    /// from the back of the victim's deque (the owner admits from the
    /// front), and the jobs' lane estimates move between the load
    /// gauges with them. Returns the number of jobs moved.
    pub(crate) fn steal_into(&self, ctx: &ShardCtx, room: usize) -> usize {
        if room == 0 {
            return 0;
        }
        let slots = self.slots.lock().unwrap();
        // re-check under the lock: remove_shard flips the flag while
        // holding it, so a thief that raced past its loop's check must
        // not pull work into a shard that is already draining
        if ctx.draining.load(Ordering::Relaxed) {
            return 0;
        }
        let victim = slots
            .iter()
            .filter(|s| s.id != ctx.shard && !s.queue.lock().unwrap().is_empty())
            .max_by_key(|s| s.load.load(Ordering::Relaxed));
        let Some(victim) = victim else { return 0 };
        let mut vq = victim.queue.lock().unwrap();
        let mut moved = 0usize;
        let mut gained = 0usize;
        while gained < room {
            let Some(job) = vq.pop_back() else { break };
            victim.load.fetch_sub(job.lanes as u64, Ordering::Relaxed);
            ctx.load.fetch_add(job.lanes as u64, Ordering::Relaxed);
            gained += job.lanes.max(1);
            moved += 1;
            ctx.queue.lock().unwrap().push_back(job);
        }
        moved
    }
}

/// Cloneable submitter side of the pool: routes each request to a live
/// shard, tracks outstanding load, and manages the shard lifecycle
/// (`add_shard` / `remove_shard`). Dropping every clone lets every
/// shard drain and exit.
#[derive(Clone)]
pub struct PoolHandle {
    reg: Arc<ShardRegistry>,
    rr: Arc<AtomicUsize>,
}

impl PoolHandle {
    /// Live (non-draining) shards.
    pub fn shards(&self) -> usize {
        self.reg.slots.lock().unwrap().len()
    }

    /// Current outstanding lane estimate on shard `id` (telemetry);
    /// 0 for removed shards.
    pub fn load_of(&self, id: usize) -> u64 {
        self.reg
            .slots
            .lock()
            .unwrap()
            .iter()
            .find(|s| s.id == id)
            .map(|s| s.load.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Pick the slot position for one request (see the module docs for
    /// the policies). Caller holds the slots lock.
    fn place(&self, slots: &[ShardSlot], expr: &str) -> usize {
        let n = slots.len();
        if n == 1 {
            return 0;
        }
        match self.reg.cfg.placement {
            PlacePolicy::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % n,
            PlacePolicy::Affinity => (hash::fnv1a_str(expr) % n as u64) as usize,
            PlacePolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = u64::MAX;
                for (i, s) in slots.iter().enumerate() {
                    let v = s.load.load(Ordering::Relaxed);
                    if v < best_load {
                        best = i;
                        best_load = v;
                    }
                }
                best
            }
        }
    }

    /// Route and enqueue one request. The lane estimate joins the load
    /// gauge immediately (so a burst of submissions spreads before any
    /// shard has even started) and is returned by the owning shard on
    /// the terminal reply. A shard whose thread died (backend init
    /// failure) has a closed channel; submission falls back to the
    /// remaining shards in rotation before giving up, so one dead shard
    /// degrades capacity instead of failing a fraction of all traffic.
    pub fn submit(&self, req: SolveRequest) -> Result<()> {
        let slots = self.reg.slots.lock().unwrap();
        let n = slots.len();
        if n == 0 {
            bail!("no live scheduler shards");
        }
        let first = self.place(&slots, &req.expr);
        let est = lane_estimate(req.method, self.reg.cfg.pool_size) as u64;
        if send_with_fallback(&slots, first, est, req) {
            Ok(())
        } else {
            Err(anyhow!("all {n} scheduler shards gone"))
        }
    }

    /// Hot-add one shard: spawn its scheduler thread (backend built by
    /// the pool's stored factory on that thread) and register it with
    /// the placement table. Returns the new shard id. The shared prefix
    /// tier grows its per-shard tables on the shard's first
    /// acquisition.
    pub fn add_shard(&self) -> Result<usize> {
        let id = {
            // cap check and insertion under ONE lock acquisition, so
            // concurrent add_shard calls cannot race past the cap; the
            // brief spawn-under-lock only stalls submitters during the
            // rare lifecycle op
            let mut slots = self.reg.slots.lock().unwrap();
            if slots.len() >= MAX_SHARDS {
                bail!("shard cap ({MAX_SHARDS}) reached");
            }
            let id = self.reg.next_id.fetch_add(1, Ordering::Relaxed);
            let (mut slot, join) = self.reg.spawn_shard(id)?;
            // retain the join handle so remove_shard can reap the
            // thread after its done signal (initial shards are joined
            // by BackendPool::spawn's caller instead)
            slot.join = Some(join);
            slots.push(slot);
            id
        };
        self.reg.metrics.lock().unwrap().record_shard_added();
        Ok(id)
    }

    /// Hot-remove shard `id`: mark it draining and take it out of the
    /// placement table (no new placements, no stealing), re-place its
    /// queued-but-unstarted jobs onto the survivors, close its channel,
    /// and block until it has finished its in-flight runs, released its
    /// prefix-tier handles, and flushed its final gauges. Other shards
    /// keep serving throughout. Returns the drain duration in seconds.
    pub fn remove_shard(&self, id: usize) -> Result<f64> {
        let t0 = Instant::now();
        let slot = {
            let mut slots = self.reg.slots.lock().unwrap();
            let pos = slots
                .iter()
                .position(|s| s.id == id)
                .ok_or_else(|| anyhow!("no live shard {id}"))?;
            let min = self.reg.cfg.min_shards.max(1);
            if slots.len() <= min {
                bail!("cannot drain shard {id}: pool is at min_shards={min}");
            }
            let slot = slots.remove(pos);
            slot.draining.store(true, Ordering::Relaxed);
            // re-place queued-but-unstarted jobs by re-submitting them
            // through the survivors' channels (a parked shard wakes on
            // its channel, not on its queue); gauges move with the jobs
            let moved: Vec<QueuedJob> = slot.queue.lock().unwrap().drain(..).collect();
            for (i, job) in moved.into_iter().enumerate() {
                let est = job.lanes as u64;
                slot.load.fetch_sub(est, Ordering::Relaxed);
                if !send_with_fallback(&slots, i % slots.len(), est, job.req) {
                    // every survivor is dead: the reply sender drops and
                    // the client sees a disconnect
                    log::error!("drain of shard {id}: no survivor accepted a queued job");
                }
            }
            slot
        };
        // closing the channel is the drain signal: the shard finishes
        // its in-flight runs, releases its tier handles, flushes its
        // clock gauges, and drops its done sender
        let ShardSlot { tx, done_rx, join, .. } = slot;
        drop(tx);
        let _ = done_rx.recv();
        if let Some(j) = join {
            // hot-added shard: reap the thread so its final flush is
            // fully ordered before remove_shard returns
            let _ = j.join();
        }
        let secs = t0.elapsed().as_secs_f64();
        self.reg.metrics.lock().unwrap().record_shard_removed(secs);
        Ok(secs)
    }
}

pub struct BackendPool;

impl BackendPool {
    /// Spawn `cfg.shards` scheduler threads, each owning one backend
    /// built by `factory(shard)` ON that shard's thread. Returns the
    /// routing handle plus one join handle per initial shard (the
    /// server ignores them; benches join them to flush final clock
    /// metrics). The factory is retained by the pool so
    /// [`PoolHandle::add_shard`] can spawn more shards at runtime.
    pub fn spawn<F>(
        cfg: SsrConfig,
        vocab: Vocab,
        metrics: Arc<Mutex<Metrics>>,
        factory: F,
    ) -> Result<(PoolHandle, Vec<std::thread::JoinHandle<()>>)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let tier = Arc::new(SharedPrefixTier::new(
            shards,
            if cfg.prefix.enabled { cfg.prefix.capacity } else { 0 },
            cfg.prefix.max_bytes,
        ));
        metrics.lock().unwrap().init_shards(shards);
        let reg = Arc::new(ShardRegistry {
            cfg,
            vocab,
            metrics,
            tier,
            factory: Box::new(factory),
            next_id: AtomicUsize::new(0),
            slots: Mutex::new(Vec::with_capacity(shards)),
        });
        let mut joins = Vec::with_capacity(shards);
        for _ in 0..shards {
            let id = reg.next_id.fetch_add(1, Ordering::Relaxed);
            let (slot, join) = reg.spawn_shard(id)?;
            reg.slots.lock().unwrap().push(slot);
            joins.push(join);
        }
        Ok((PoolHandle { reg, rr: Arc::new(AtomicUsize::new(0)) }, joins))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::config::StopRule;
    use crate::coordinator::engine::Method;
    use crate::model::tokenizer;

    fn spawn_pool(
        shards: usize,
        placement: PlacePolicy,
    ) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
        let mut cfg = SsrConfig::default();
        cfg.shards = shards;
        cfg.placement = placement;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) =
            BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), |_s| {
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            })
            .unwrap();
        (handle, joins, metrics)
    }

    fn solve(
        handle: &PoolHandle,
        expr: &str,
        seed: u64,
    ) -> mpsc::Receiver<Result<crate::util::json::Value>> {
        let (rtx, rrx) = mpsc::channel();
        handle
            .submit(SolveRequest {
                expr: expr.to_string(),
                method: Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                seed,
                reply: rtx,
            })
            .unwrap();
        rrx
    }

    #[test]
    fn pool_completes_work_across_shards_and_drains() {
        // gate the shard backends so every submission lands (and the
        // load gauges fill) before any shard starts — the least-loaded
        // alternation the assertions rely on, without sleeps
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = Arc::new(Mutex::new(gate_rx));
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::LeastLoaded;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |_s| {
                let _ = gate.lock().unwrap().recv();
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        let replies: Vec<_> =
            (0..8).map(|i| solve(&handle, &format!("{}+{}", i + 1, i + 2), i as u64)).collect();
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        for (i, r) in replies.iter().enumerate() {
            let v = r.recv().unwrap().unwrap();
            assert_eq!(v.get_i64("gold").unwrap(), (2 * i + 3) as i64);
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
        assert_eq!(m.shard_requests.iter().sum::<u64>(), 8);
        // least-loaded spreads an 8-burst of equal jobs across 2 shards
        assert!(
            m.shard_requests.iter().all(|&r| r >= 2),
            "placement starved a shard: {:?}",
            m.shard_requests
        );
        assert_eq!(m.shard_clocks.len(), 2);
        assert!(m.model_secs_makespan() > 0.0);
        assert!(m.model_secs >= m.model_secs_makespan());
    }

    #[test]
    fn loads_return_to_zero_after_drain() {
        let (handle, joins, _metrics) = spawn_pool(2, PlacePolicy::RoundRobin);
        let replies: Vec<_> = (0..6).map(|i| solve(&handle, "3+4*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(handle.load_of(0) + handle.load_of(1), 0, "load gauge leaked");
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn affinity_pins_repeat_prompts_to_one_shard() {
        let (handle, joins, metrics) = spawn_pool(2, PlacePolicy::Affinity);
        for round in 0..3u64 {
            for expr in ["17+25*3", "4+5*6", "9+1*2", "8+8*8"] {
                let r = solve(&handle, expr, round);
                assert!(r.recv().unwrap().is_ok());
            }
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 12);
        // affinity: a prompt only ever visits one shard, so the tier
        // never has to re-prefill a known prompt on a second shard
        assert_eq!(m.prefix_misses, 4, "one miss per distinct prompt");
        assert_eq!(m.prefix_shard_fills, 0, "affinity re-prefilled a prompt");
        assert_eq!(m.prefix_hits, 8);
    }

    #[test]
    fn handle_clones_keep_the_pool_alive() {
        let (handle, joins, _metrics) = spawn_pool(1, PlacePolicy::LeastLoaded);
        let h2 = handle.clone();
        drop(handle);
        // a surviving clone still submits; shards only drain when the
        // last clone drops
        let r = solve(&h2, "1+2", 0);
        assert!(r.recv().unwrap().is_ok());
        drop(h2);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn add_shard_serves_and_remove_shard_respects_min() {
        let (handle, joins, metrics) = spawn_pool(1, PlacePolicy::RoundRobin);
        assert_eq!(handle.shards(), 1);
        let id = handle.add_shard().unwrap();
        assert_eq!(id, 1);
        assert_eq!(handle.shards(), 2);
        // round-robin over 2 live shards: both serve
        let replies: Vec<_> = (0..6).map(|i| solve(&handle, "5+6*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.shards_added, 1);
            assert!(
                m.shard_requests.len() >= 2 && m.shard_requests[1] > 0,
                "hot-added shard never served: {:?}",
                m.shard_requests
            );
        }
        // drain the added shard while the original keeps serving
        let secs = handle.remove_shard(id).unwrap();
        assert!(secs >= 0.0);
        assert_eq!(handle.shards(), 1);
        let r = solve(&handle, "2+2", 9);
        assert!(r.recv().unwrap().is_ok());
        // min_shards floor: the last shard cannot be drained
        assert!(handle.remove_shard(0).is_err());
        // removing a removed shard errors cleanly
        assert!(handle.remove_shard(id).is_err());
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.shards_removed, 1);
            assert_eq!(m.drains, 1);
            assert!(m.drain_secs_max >= 0.0);
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }
}
