//! `BackendPool`: the sharded execution layer (DESIGN.md §10).
//!
//! One scheduler thread per backend shard, each owning its own
//! `Box<dyn Backend>` (PJRT wrapper types are not Send, so a backend
//! never leaves the thread that built it), its own lane pool and
//! admission queue, and its own step-tick loop
//! (`coordinator::scheduler::run_loop`). Work is routed at submit time
//! by a placement policy:
//!
//! * **least-loaded** (default) — argmin over the pool-wide load
//!   gauges (outstanding lane estimates, incremented at submit and
//!   returned on the terminal reply). Balances mixed loads; ties break
//!   to the lowest shard id so single-stream traffic stays put.
//! * **affinity** — hash of the request expression mod shards: every
//!   repeat of a prompt lands on the shard that already holds its
//!   prefilled prefix, maximizing tier hits at the cost of balance
//!   under skewed prompt distributions.
//! * **round-robin** — strict rotation (load-blind; the bench
//!   baseline).
//!
//! The shards share ONE logical prefix cache
//! ([`SharedPrefixTier`](super::prefix::SharedPrefixTier)): a prompt
//! prefilled on shard A is admitted as a tier hit everywhere and
//! re-prefilled at most once per shard that serves it. Throughput
//! scales with shard count because each shard's backend clock advances
//! independently — `Metrics::model_secs_makespan` (max over shards) is
//! the virtual wall-clock the `serving_scheduler` bench divides by.
//!
//! Shutdown / drain: dropping every [`PoolHandle`] clone closes every
//! shard's channel; each shard finishes its queued and in-flight work,
//! releases its tier handles, flushes its clock gauge, and exits —
//! `BackendPool::spawn`'s join handles complete in any order.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::metrics::Metrics;
use super::prefix::SharedPrefixTier;
use super::scheduler::{self, lane_estimate, ShardCtx, SolveRequest};
use crate::backend::Backend;
use crate::config::{PlacePolicy, SsrConfig};
use crate::runtime::Vocab;
use crate::util::hash;

/// Cloneable submitter side of the pool: routes each request to a
/// shard and tracks outstanding load. Dropping every clone lets every
/// shard drain and exit.
#[derive(Clone)]
pub struct PoolHandle {
    txs: Vec<mpsc::Sender<SolveRequest>>,
    loads: Arc<Vec<AtomicU64>>,
    placement: PlacePolicy,
    rr: Arc<AtomicUsize>,
    pool_size: usize,
}

impl PoolHandle {
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Pick the shard for one request (see the module docs for the
    /// policies).
    fn place(&self, expr: &str) -> usize {
        let n = self.txs.len();
        if n == 1 {
            return 0;
        }
        match self.placement {
            PlacePolicy::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % n,
            PlacePolicy::Affinity => (hash::fnv1a_str(expr) % n as u64) as usize,
            PlacePolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = u64::MAX;
                for (i, l) in self.loads.iter().enumerate() {
                    let v = l.load(Ordering::Relaxed);
                    if v < best_load {
                        best = i;
                        best_load = v;
                    }
                }
                best
            }
        }
    }

    /// Route and enqueue one request. The lane estimate joins the load
    /// gauge immediately (so a burst of submissions spreads before any
    /// shard has even started) and is returned by the shard on the
    /// terminal reply. A shard whose thread died (backend init failure)
    /// has a closed channel; submission falls back to the remaining
    /// shards in rotation before giving up, so one dead shard degrades
    /// capacity instead of failing a fraction of all traffic.
    pub fn submit(&self, req: SolveRequest) -> Result<()> {
        let first = self.place(&req.expr);
        let n = self.txs.len();
        let est = lane_estimate(req.method, self.pool_size) as u64;
        let mut req = req;
        for attempt in 0..n {
            let shard = (first + attempt) % n;
            self.loads[shard].fetch_add(est, Ordering::Relaxed);
            match self.txs[shard].send(req) {
                Ok(()) => return Ok(()),
                Err(mpsc::SendError(returned)) => {
                    self.loads[shard].fetch_sub(est, Ordering::Relaxed);
                    req = returned;
                }
            }
        }
        Err(anyhow!("all {n} scheduler shards gone"))
    }

    /// Current outstanding lane estimate on one shard (telemetry).
    pub fn load_of(&self, shard: usize) -> u64 {
        self.loads[shard].load(Ordering::Relaxed)
    }
}

pub struct BackendPool;

impl BackendPool {
    /// Spawn `cfg.shards` scheduler threads, each owning one backend
    /// built by `factory(shard)` ON that shard's thread. Returns the
    /// routing handle plus one join handle per shard (the server
    /// ignores them; benches join them to flush final clock metrics).
    pub fn spawn<F>(
        cfg: SsrConfig,
        vocab: Vocab,
        metrics: Arc<Mutex<Metrics>>,
        factory: F,
    ) -> Result<(PoolHandle, Vec<std::thread::JoinHandle<()>>)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let tier = Arc::new(SharedPrefixTier::new(
            shards,
            if cfg.prefix.enabled { cfg.prefix.capacity } else { 0 },
            cfg.prefix.max_bytes,
        ));
        let loads: Arc<Vec<AtomicU64>> =
            Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect());
        metrics.lock().unwrap().init_shards(shards);
        let factory = Arc::new(factory);

        let mut txs = Vec::with_capacity(shards);
        let mut joins = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::channel::<SolveRequest>();
            let cfg = cfg.clone();
            let vocab = vocab.clone();
            let metrics = Arc::clone(&metrics);
            let ctx = ShardCtx { shard, tier: Arc::clone(&tier), loads: Arc::clone(&loads) };
            let factory = Arc::clone(&factory);
            let join = std::thread::Builder::new()
                .name(format!("ssr-shard-{shard}"))
                .spawn(move || match (factory.as_ref())(shard) {
                    Ok(mut backend) => {
                        scheduler::run_loop(backend.as_mut(), &cfg, &vocab, rx, &metrics, &ctx)
                    }
                    Err(e) => log::error!("shard {shard} backend init failed: {e:#}"),
                })
                .with_context(|| format!("spawning scheduler shard {shard}"))?;
            txs.push(tx);
            joins.push(join);
        }
        Ok((
            PoolHandle {
                txs,
                loads,
                placement: cfg.placement,
                rr: Arc::new(AtomicUsize::new(0)),
                pool_size: cfg.pool_size,
            },
            joins,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::config::StopRule;
    use crate::coordinator::engine::Method;
    use crate::model::tokenizer;

    fn spawn_pool(
        shards: usize,
        placement: PlacePolicy,
    ) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
        let mut cfg = SsrConfig::default();
        cfg.shards = shards;
        cfg.placement = placement;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) =
            BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), |_s| {
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            })
            .unwrap();
        (handle, joins, metrics)
    }

    fn solve(
        handle: &PoolHandle,
        expr: &str,
        seed: u64,
    ) -> mpsc::Receiver<Result<crate::util::json::Value>> {
        let (rtx, rrx) = mpsc::channel();
        handle
            .submit(SolveRequest {
                expr: expr.to_string(),
                method: Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                seed,
                reply: rtx,
            })
            .unwrap();
        rrx
    }

    #[test]
    fn pool_completes_work_across_shards_and_drains() {
        // gate the shard backends so every submission lands (and the
        // load gauges fill) before any shard starts — the least-loaded
        // alternation the assertions rely on, without sleeps
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = Arc::new(Mutex::new(gate_rx));
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::LeastLoaded;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |_s| {
                let _ = gate.lock().unwrap().recv();
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        let replies: Vec<_> =
            (0..8).map(|i| solve(&handle, &format!("{}+{}", i + 1, i + 2), i as u64)).collect();
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        for (i, r) in replies.iter().enumerate() {
            let v = r.recv().unwrap().unwrap();
            assert_eq!(v.get_i64("gold").unwrap(), (2 * i + 3) as i64);
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
        assert_eq!(m.shard_requests.iter().sum::<u64>(), 8);
        // least-loaded spreads an 8-burst of equal jobs across 2 shards
        assert!(
            m.shard_requests.iter().all(|&r| r >= 2),
            "placement starved a shard: {:?}",
            m.shard_requests
        );
        assert_eq!(m.shard_clocks.len(), 2);
        assert!(m.model_secs_makespan() > 0.0);
        assert!(m.model_secs >= m.model_secs_makespan());
    }

    #[test]
    fn loads_return_to_zero_after_drain() {
        let (handle, joins, _metrics) = spawn_pool(2, PlacePolicy::RoundRobin);
        let replies: Vec<_> = (0..6).map(|i| solve(&handle, "3+4*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(handle.load_of(0) + handle.load_of(1), 0, "load gauge leaked");
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn affinity_pins_repeat_prompts_to_one_shard() {
        let (handle, joins, metrics) = spawn_pool(2, PlacePolicy::Affinity);
        for round in 0..3u64 {
            for expr in ["17+25*3", "4+5*6", "9+1*2", "8+8*8"] {
                let r = solve(&handle, expr, round);
                assert!(r.recv().unwrap().is_ok());
            }
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 12);
        // affinity: a prompt only ever visits one shard, so the tier
        // never has to re-prefill a known prompt on a second shard
        assert_eq!(m.prefix_misses, 4, "one miss per distinct prompt");
        assert_eq!(m.prefix_shard_fills, 0, "affinity re-prefilled a prompt");
        assert_eq!(m.prefix_hits, 8);
    }

    #[test]
    fn handle_clones_keep_the_pool_alive() {
        let (handle, joins, _metrics) = spawn_pool(1, PlacePolicy::LeastLoaded);
        let h2 = handle.clone();
        drop(handle);
        // a surviving clone still submits; shards only drain when the
        // last clone drops
        let r = solve(&h2, "1+2", 0);
        assert!(r.recv().unwrap().is_ok());
        drop(h2);
        for j in joins {
            j.join().unwrap();
        }
    }
}
