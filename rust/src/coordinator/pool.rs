//! `BackendPool`: the elastic sharded execution layer (DESIGN.md §10,
//! §11, §12).
//!
//! One scheduler thread per backend shard, each owning its own
//! `Box<dyn Backend>` (PJRT wrapper types are not Send, so a backend
//! never leaves the thread that built it), its own lane pool, and a
//! *shared* admission queue slot (so idle shards can steal from it),
//! running the step-tick loop (`coordinator::scheduler::run_loop`).
//! Work is routed at submit time by a placement policy:
//!
//! * **least-loaded** (default) — argmin over the pool-wide load
//!   gauges (outstanding lane estimates, incremented at submit and
//!   returned on the terminal reply). Balances mixed loads; ties break
//!   first to a shard whose last-accepted batch shape (lane estimate)
//!   matches the incoming request — keeping equal-width lanes together
//!   so step batches stay dense (`placement_shape_hits` counts these) —
//!   then to the lowest slot so single-stream traffic stays put.
//! * **affinity** — hash of the request expression mod live shards:
//!   every repeat of a prompt lands on the shard that already holds its
//!   prefilled prefix, maximizing tier hits at the cost of balance
//!   under skewed prompt distributions.
//! * **round-robin** — strict rotation (load-blind; the bench
//!   baseline).
//!
//! **Lock-free submit hot path.** The placement table is an immutable
//! snapshot (`RwLock<Arc<Vec<ShardSlot>>>`): `submit` clones the `Arc`
//! under an uncontended read lock and routes over the frozen slice —
//! submitters never serialize against each other. Only the rare
//! lifecycle ops (`add_shard` / `remove_shard`) rebuild the snapshot,
//! serialized by the lifecycle mutex. A submitter racing a removal may
//! still send into the draining shard's channel; the draining loop
//! migrates (or finishes) such stragglers, so nothing is lost.
//!
//! The shard set is **elastic** at runtime:
//!
//! * [`PoolHandle::add_shard`] spawns a new scheduler thread (its
//!   backend built by the pool's stored factory ON that thread),
//!   publishes a new placement snapshot, and lets the shared prefix
//!   tier grow its per-shard tables on the shard's first acquisition.
//! * [`PoolHandle::remove_shard`] publishes a snapshot without the
//!   shard and marks it draining (no new placements, no stealing),
//!   re-places its queued-but-unstarted jobs onto the survivors, closes
//!   its channel, and blocks until the shard has quiesced. With
//!   `migration` enabled (default) the draining shard detaches its
//!   in-flight runs at the next step boundary and hands them to the
//!   survivors as `DetachedRun`s — drain time is O(one step), not
//!   O(one solve). `min_shards` bounds how far the pool can drain.
//! * **Work stealing** (`steal_threshold > 0`): a shard whose occupancy
//!   stays below the threshold pulls queued jobs from the most-loaded
//!   shard's admission queue ([`ShardRegistry::steal_into`]); when the
//!   victim's queue is empty but its lanes are saturated, the thief
//!   posts a *shed request* and the victim migrates whole in-flight
//!   runs to it at its next step boundary. Stolen and migrated runs
//!   stay decision-equivalent (placement-invariant run seed + the
//!   LaneSnapshot contract, DESIGN.md §12), asserted in
//!   `tests/sharding.rs`, `tests/migration.rs` and the benches.
//!
//! **Idle wakeups.** Idle steal-mode shards park on the pool-wide
//! [`WorkSignal`] condvar; every enqueue (submit, re-placement, shed
//! handoff) bumps it, so an idle pool burns no CPU instead of polling
//! every 500 µs (ROADMAP item). A long safety timeout bounds shutdown
//! latency.
//!
//! The shards share ONE logical prefix cache
//! ([`SharedPrefixTier`](super::prefix::SharedPrefixTier)): a prompt
//! prefilled on shard A is admitted as a tier hit everywhere and
//! re-prefilled at most once per shard that serves it. Throughput
//! scales with shard count because each shard's backend clock advances
//! independently — `Metrics::model_secs_makespan` (max over shards) is
//! the virtual wall-clock the serving benches divide by.
//!
//! Shutdown / drain: dropping every [`PoolHandle`] clone closes every
//! shard's channel; each shard finishes its queued and in-flight work,
//! releases its tier handles, flushes its clock gauge, and exits —
//! `BackendPool::spawn`'s join handles complete in any order. Shard
//! threads hold only a `Weak` registry reference, so they never keep
//! their own channels alive.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::admission::QosClass;
use super::metrics::Metrics;
use super::prefix::{SharedPrefixTier, SpillStore};
use super::scheduler::{
    self, lane_estimate, QueuedJob, RunTicket, ShardCtx, ShardMsg, SolveRequest, TicketMap, Work,
};
use crate::backend::Backend;
use crate::config::{PlacePolicy, ShardClass, SsrConfig};
use crate::runtime::Vocab;
use crate::util::hash;
use crate::util::sync::{lock_ok, read_ok, write_ok};

/// Hard cap on concurrently live shards (matches `SsrConfig::validate`).
const MAX_SHARDS: usize = 64;

/// Pool-wide enqueue signal: idle steal-mode shards park here instead
/// of polling. The epoch counter closes the lost-wakeup race — a
/// sleeper records the epoch *before* scanning its wake sources and
/// parks only while the epoch is unchanged. The bump side is a single
/// atomic add when nobody is parked (the submit hot path must not take
/// a shared mutex — with `steal_threshold = 0` nothing ever parks, so
/// submits pay one uncontended atomic and nothing else).
pub(crate) struct WorkSignal {
    epoch: AtomicU64,
    waiters: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl WorkSignal {
    fn new() -> Self {
        WorkSignal {
            epoch: AtomicU64::new(0),
            waiters: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Something was enqueued somewhere: wake every parked shard.
    /// SeqCst ordering makes the waiter==0 fast path sound: a waiter
    /// this bump misses registered after the epoch moved, and its
    /// registration (under the lock) precedes its epoch re-check, so
    /// it observes the new epoch and never sleeps on it.
    pub(crate) fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // enter/exit the lock so a waiter between its epoch check
            // and cv.wait cannot miss the notify
            drop(lock_ok(&self.lock));
            self.cv.notify_all();
        }
    }

    pub(crate) fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Park until the epoch moves past `seen` (or the safety timeout).
    pub(crate) fn wait_past(&self, seen: u64, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        let mut guard = lock_ok(&self.lock);
        self.waiters.fetch_add(1, Ordering::SeqCst);
        while self.epoch.load(Ordering::SeqCst) == seen {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            guard = g;
        }
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A thief's request that a loaded shard migrate some in-flight lanes
/// to it (work stealing past the queue; DESIGN.md §12).
#[derive(Debug, Clone, Copy)]
pub(crate) struct ShedRequest {
    /// requesting shard id (the migration target)
    pub(crate) thief: usize,
    /// free lane capacity the thief had when it asked
    pub(crate) lanes: usize,
}

/// Cap on queued shed requests per shard: one slow victim must not
/// accumulate an unbounded backlog of stale thief requests.
const MAX_SHED_REQUESTS: usize = 4;

/// Bounded LRU set of poison run seeds (DESIGN.md §13, §14): under a
/// sustained crash storm the quarantine list must not grow without
/// bound, so at `quarantine_cap` entries the least-recently-touched
/// seed is evicted (and counted in the metrics) — a hard memory bound
/// traded against a tiny chance of re-admitting a long-dormant poison
/// run, which the retry budget would re-catch anyway.
pub(crate) struct QuarantineLru {
    cap: usize,
    /// monotone touch counter: higher = more recently seen
    seq: u64,
    /// run seed -> last-touched sequence number
    map: HashMap<u64, u64>,
}

impl QuarantineLru {
    fn new(cap: usize) -> Self {
        QuarantineLru { cap: cap.max(1), seq: 0, map: HashMap::new() }
    }

    /// Membership test; refreshes recency on hit (a seed that keeps
    /// being refused at admission is exactly the one worth keeping).
    fn contains(&mut self, seed: u64) -> bool {
        self.seq += 1;
        let seq = self.seq;
        match self.map.get_mut(&seed) {
            Some(s) => {
                *s = seq;
                true
            }
            None => false,
        }
    }

    /// Insert a seed, evicting least-recently-touched entries past the
    /// cap. Returns the number of evictions (for the stats counter).
    fn insert(&mut self, seed: u64) -> u64 {
        self.seq += 1;
        self.map.insert(seed, self.seq);
        let mut evicted = 0u64;
        while self.map.len() > self.cap {
            // O(cap) scan: inserts only happen on shard crashes, never
            // on the serving hot path, and the cap is small
            let victim = self.map.iter().min_by_key(|&(_, &s)| s).map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    self.map.remove(&k);
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.map.len()
    }
}

/// One live shard's entry in the placement snapshot. Cloned wholesale
/// when the snapshot is rebuilt; the queue / load / draining / shed
/// cells are shared with the shard's own `ShardCtx`, which is what lets
/// submit, steal, shed and drain coordinate with the running loop.
/// Deliberately `Sync`-only state (the done-channel and join handle
/// live in the registry's lifecycle table instead).
#[derive(Clone)]
pub(crate) struct ShardSlot {
    pub(crate) id: usize,
    /// the shard's hardware class (DESIGN.md §15): a cost/capacity
    /// profile applied to its backend at spawn, never a decision input
    pub(crate) class: ShardClass,
    tx: mpsc::Sender<ShardMsg>,
    pub(crate) queue: Arc<Mutex<VecDeque<QueuedJob>>>,
    pub(crate) load: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    pub(crate) shed: Arc<Mutex<Vec<ShedRequest>>>,
    /// lane estimate of the last job this shard accepted — the
    /// batch-shape placement hint: least-loaded ties break toward a
    /// shard already running this width (0 = no job accepted yet)
    pub(crate) shape: Arc<AtomicU64>,
    /// the shard's admitted-run re-admission tickets (crash recovery,
    /// DESIGN.md §13)
    tickets: TicketMap,
    /// set the instant the shard thread panics, before recovery
    /// unpublishes the slot: placement, routing fallback and the
    /// autoscaler's signals all skip dead slots, so the crash window
    /// degrades capacity instead of routing into a corpse
    dead: Arc<AtomicBool>,
}

impl ShardSlot {
    fn healthy(&self) -> bool {
        !self.dead.load(Ordering::SeqCst)
    }
}

/// Per-shard teardown state, kept out of the (Sync) placement snapshot:
/// the done channel closes when the shard thread has fully exited, and
/// hot-added shards retain their join handle so `remove_shard` can reap
/// the thread (initial shards hand theirs to `BackendPool::spawn`'s
/// caller instead).
struct ShardHook {
    done_rx: mpsc::Receiver<()>,
    join: Option<std::thread::JoinHandle<()>>,
}

type BackendFactory = dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync;

/// Try to hand `msg` to the slot at `first`, rotating past dead shards
/// (closed channels) and moving `est` onto the accepting shard's load
/// gauge. Shared by `PoolHandle::submit`, the drain's job re-placement
/// and in-flight migration so the fallback semantics cannot diverge.
/// Returns the message back when every slot's channel is gone.
fn send_with_fallback(
    slots: &[ShardSlot],
    first: usize,
    est: u64,
    msg: ShardMsg,
) -> std::result::Result<(), ShardMsg> {
    let n = slots.len();
    let mut msg = msg;
    for attempt in 0..n {
        let s = &slots[(first + attempt) % n];
        // a crashed shard's channel may still accept sends (its rx
        // outlives the panic for recovery draining) — skip it outright
        if !s.healthy() {
            continue;
        }
        s.load.fetch_add(est, Ordering::Relaxed);
        match s.tx.send(msg) {
            Ok(()) => {
                s.shape.store(est, Ordering::Relaxed);
                return Ok(());
            }
            Err(mpsc::SendError(returned)) => {
                s.load.fetch_sub(est, Ordering::Relaxed);
                msg = returned;
            }
        }
    }
    Err(msg)
}

/// Shared pool state: the immutable placement snapshot plus everything
/// needed to spawn a new shard at runtime. Shard threads hold this only
/// weakly.
pub(crate) struct ShardRegistry {
    cfg: SsrConfig,
    vocab: Vocab,
    metrics: Arc<Mutex<Metrics>>,
    tier: Arc<SharedPrefixTier>,
    factory: Box<BackendFactory>,
    next_id: AtomicUsize,
    rr: AtomicUsize,
    /// the placement snapshot: readers clone the Arc (uncontended read
    /// lock) and route over the frozen slice; only add/remove/drain
    /// rebuild it under the lifecycle mutex
    slots: RwLock<Arc<Vec<ShardSlot>>>,
    /// serializes lifecycle ops and owns each shard's teardown state
    lifecycle: Mutex<HashMap<usize, ShardHook>>,
    /// placement-invariant run seeds of poison runs: work that crashed
    /// its shard more than `recover_retries` times is refused at
    /// admission instead of taking down another shard (DESIGN.md §13).
    /// LRU-bounded at `cfg.quarantine_cap` (DESIGN.md §14)
    quarantine: Mutex<QuarantineLru>,
    pub(crate) signal: Arc<WorkSignal>,
    /// least-loaded placements whose tie-break matched the incoming
    /// request's batch shape (lock-free: the submit hot path must not
    /// touch the metrics mutex)
    shape_hits: AtomicU64,
}

impl ShardRegistry {
    /// The current immutable placement snapshot.
    pub(crate) fn snapshot(&self) -> Arc<Vec<ShardSlot>> {
        Arc::clone(&read_ok(&self.slots))
    }

    /// Is this placement-invariant run seed on the poison list?
    /// (Touches the LRU recency on hit.)
    pub(crate) fn is_quarantined(&self, run_seed: u64) -> bool {
        lock_ok(&self.quarantine).contains(run_seed)
    }

    /// Spawn one shard thread for `id` with hardware class `class` and
    /// return its snapshot slot + teardown hook — the caller publishes
    /// the slot. The backend is built by the stored factory ON the new
    /// thread, then gets the class's cost profile applied (clock-only;
    /// decisions are class-invariant by the Backend contract).
    fn spawn_shard(
        self: &Arc<Self>,
        id: usize,
        class: ShardClass,
    ) -> Result<(ShardSlot, ShardHook, std::thread::JoinHandle<()>)> {
        let (tx, rx) = mpsc::channel::<ShardMsg>();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let queue = Arc::new(Mutex::new(VecDeque::new()));
        let load = Arc::new(AtomicU64::new(0));
        let draining = Arc::new(AtomicBool::new(false));
        let shed = Arc::new(Mutex::new(Vec::new()));
        let tickets: TicketMap = Arc::new(Mutex::new(HashMap::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let shape = Arc::new(AtomicU64::new(0));
        let ctx = ShardCtx {
            shard: id,
            class,
            tier: Arc::clone(&self.tier),
            load: Arc::clone(&load),
            queue: Arc::clone(&queue),
            draining: Arc::clone(&draining),
            shed: Arc::clone(&shed),
            tickets: Arc::clone(&tickets),
            signal: Arc::clone(&self.signal),
            registry: Arc::downgrade(self),
        };
        let cfg = self.cfg.clone();
        let vocab = self.vocab.clone();
        let metrics = Arc::clone(&self.metrics);
        let dead_flag = Arc::clone(&dead);
        let join = std::thread::Builder::new()
            .name(format!("ssr-shard-{id}"))
            .spawn(move || {
                // dropped when the thread exits — the drain signal.
                // Held through crash recovery too, so a concurrent
                // remove_shard keeps blocking until the dead shard's
                // work has been re-homed.
                let _done = done_tx;
                // build the backend via a briefly-upgraded registry ref,
                // then drop the strong ref before serving: a shard that
                // kept the registry alive would keep its own channel
                // sender alive and the pool could never drain
                let backend = match ctx.registry.upgrade() {
                    Some(reg) => (reg.factory)(id),
                    None => return,
                };
                let mut b = match backend {
                    Ok(b) => b,
                    Err(e) => {
                        log::error!("shard {id} backend init failed: {e:#}");
                        dead_flag.store(true, Ordering::SeqCst);
                        return;
                    }
                };
                // apply the class's virtual-clock profile before any
                // work runs (Balanced is (1.0, 1.0), a numeric no-op)
                let (draft_mult, target_mult) = class.cost_profile();
                b.set_cost_profile(draft_mult, target_mult);
                // supervision (DESIGN.md §13): a panic on the shard
                // thread — injected, shard-fatal escalation, or a plain
                // bug — is caught here and recovery runs on this same
                // thread: mark dead, respawn a replacement, re-admit
                // the lost work onto the survivors
                let crashed = catch_unwind(AssertUnwindSafe(|| {
                    scheduler::run_loop(b.as_mut(), &cfg, &vocab, &rx, &metrics, &ctx);
                }))
                .is_err();
                if crashed {
                    dead_flag.store(true, Ordering::SeqCst);
                    drop(b); // the backend's state is suspect: discard
                    if let Some(reg) = ctx.registry.upgrade() {
                        reg.recover_shard(id, &ctx, &rx);
                    }
                }
            })
            .with_context(|| format!("spawning scheduler shard {id}"))?;
        let slot = ShardSlot { id, class, tx, queue, load, draining, shed, tickets, dead, shape };
        Ok((slot, ShardHook { done_rx, join: None }, join))
    }

    /// Crash recovery, run ON the dying shard's own thread after
    /// `catch_unwind` caught its panic (DESIGN.md §13):
    ///
    /// 1. unpublish the dead slot and drop its lifecycle hook;
    /// 2. respawn a replacement shard via the stored factory (skipped
    ///    when the shard was draining on purpose, or at the shard cap);
    /// 3. re-home everything the dead shard held: messages trapped in
    ///    its channel, queued-but-unstarted jobs, and admitted runs
    ///    rebuilt from their re-admission tickets — checkpointed runs
    ///    resume bit-identically, the rest replay from the placement-
    ///    invariant run seed. A run that has already crashed
    ///    `recover_retries` shards is poison: its seed joins the
    ///    quarantine list and its client gets an error reply.
    fn recover_shard(self: &Arc<Self>, id: usize, ctx: &ShardCtx, rx: &mpsc::Receiver<ShardMsg>) {
        log::error!("shard {id}: thread panicked; recovering its work");
        {
            let mut m = lock_ok(&self.metrics);
            m.shard_crashes += 1;
            // fold the dead id's gauge columns into the retired
            // accumulators, as remove_shard does
            m.retire_shard(id);
        }
        // the dead shard's backend Box was dropped with the panic, so
        // its tier handles are unreleasable: forget them (and wake any
        // waiter latched on one of its mid-fill Pending slots)
        self.tier.drop_shard(id);
        let draining = ctx.draining.load(Ordering::SeqCst);
        {
            let mut lc = lock_ok(&self.lifecycle);
            let cur = self.snapshot();
            if cur.iter().any(|s| s.id == id) {
                let v: Vec<ShardSlot> =
                    cur.iter().filter(|s| s.id != id).cloned().collect();
                *write_ok(&self.slots) = Arc::new(v);
            }
            // drop the dead shard's teardown hook (a no-op when a
            // concurrent remove_shard already claimed it — that caller
            // holds done_rx and keeps blocking until this thread exits)
            lc.remove(&id);
            if !draining {
                // the replacement inherits the dead shard's class so a
                // crash storm cannot silently skew the capacity mix
                match self.respawn_locked(&mut lc, Some(ctx.class)) {
                    Ok(nid) => log::warn!("shard {id}: respawned as shard {nid}"),
                    Err(e) => log::error!("shard {id}: respawn failed: {e:#}"),
                }
            }
        }
        // re-route the dead shard's work; the replacement (and every
        // survivor) is published by now, so nothing re-lands here
        let mut stranded = 0usize;
        let slots = self.snapshot();
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ShardMsg::Solve(req) => {
                    let est = lane_estimate(req.method, self.cfg.pool_size) as u64;
                    let first = self.rr.fetch_add(1, Ordering::Relaxed) % slots.len().max(1);
                    if send_with_fallback(&slots, first, est, ShardMsg::Solve(req)).is_err() {
                        stranded += 1;
                    }
                }
                ShardMsg::Job(job) => {
                    if self.resubmit(job).is_err() {
                        stranded += 1;
                    }
                }
            }
        }
        let queued: Vec<QueuedJob> = lock_ok(&ctx.queue).drain(..).collect();
        for job in queued {
            if self.resubmit(job).is_err() {
                stranded += 1;
            }
        }
        let tickets: Vec<RunTicket> = lock_ok(&ctx.tickets).drain().map(|(_, t)| t).collect();
        for t in tickets {
            let RunTicket {
                problem,
                method,
                wire_seed,
                gold,
                est,
                enqueued,
                deadline,
                retries,
                class,
                checkpoint,
                reply,
            } = t;
            if retries >= self.cfg.recover_retries {
                let mut evicted = 0u64;
                if let Some(p) = &problem {
                    let seed = wire_seed ^ hash::fnv1a_i32(&p.tokens);
                    evicted = lock_ok(&self.quarantine).insert(seed);
                }
                let mut m = lock_ok(&self.metrics);
                m.quarantined += 1;
                m.quarantine_evictions += evicted;
                m.errors += 1;
                drop(m);
                let _ = reply.send(Err(anyhow!(
                    "run quarantined after crashing {} shards",
                    retries + 1
                )));
                continue;
            }
            let work = match (checkpoint, problem) {
                (Some(run), _) => {
                    lock_ok(&self.metrics).runs_recovered += 1;
                    Work::Resume { run, method, gold, reply }
                }
                (None, Some(problem)) => {
                    let mut m = lock_ok(&self.metrics);
                    m.runs_recovered += 1;
                    m.runs_replayed += 1;
                    drop(m);
                    Work::Fresh { problem, method, seed: wire_seed, reply }
                }
                (None, None) => {
                    // can't happen by construction; never drop a reply
                    let _ = reply
                        .send(Err(anyhow!("shard {id} crashed; run state unrecoverable")));
                    continue;
                }
            };
            let job = QueuedJob {
                lanes: est,
                enqueued,
                queued_at: Instant::now(),
                deadline,
                retries: retries + 1,
                class,
                work,
            };
            if self.resubmit(job).is_err() {
                stranded += 1;
            }
        }
        if stranded > 0 {
            // no survivor accepted (respawn failed AND the pool is
            // empty): the dropped reply senders surface as disconnects
            log::error!("shard {id}: {stranded} work item(s) lost — no live shard left");
            lock_ok(&self.metrics).errors += stranded as u64;
        }
        self.signal.bump();
    }

    /// `add_shard` minus the handle: spawn and publish a replacement
    /// shard under the already-held lifecycle lock. `class` overrides
    /// the config pattern (crash respawns and class-targeted scale-ups
    /// must not drift with the monotone id counter).
    fn respawn_locked(
        self: &Arc<Self>,
        lc: &mut HashMap<usize, ShardHook>,
        class: Option<ShardClass>,
    ) -> Result<usize> {
        let cur = self.snapshot();
        if cur.len() >= MAX_SHARDS {
            bail!("shard cap ({MAX_SHARDS}) reached");
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let class = class.unwrap_or_else(|| self.cfg.class_of(id));
        let (slot, mut hook, join) = self.spawn_shard(id, class)?;
        hook.join = Some(join);
        lc.insert(id, hook);
        let mut v: Vec<ShardSlot> = cur.iter().cloned().collect();
        v.push(slot);
        *write_ok(&self.slots) = Arc::new(v);
        Ok(id)
    }

    /// Move queued-but-unstarted jobs from the most-loaded other shard
    /// into `ctx`'s queue, up to `room` lanes' worth. The thief steals
    /// from the back of the victim's deque (the owner admits from the
    /// front), and the jobs' lane estimates move between the load
    /// gauges with them. When nothing is queued anywhere but a loaded
    /// shard has its lanes saturated, a shed request is posted instead:
    /// the victim migrates in-flight runs to the thief at its next step
    /// boundary (`migration` enabled). Returns the number of jobs
    /// moved (shed handoffs arrive later through the thief's channel).
    pub(crate) fn steal_into(&self, ctx: &ShardCtx, room: usize) -> usize {
        if room == 0 {
            return 0;
        }
        // a thief that raced past its loop's check must not pull work
        // into a shard that is already draining
        if ctx.draining.load(Ordering::Relaxed) {
            return 0;
        }
        let slots = self.snapshot();
        let victim = slots
            .iter()
            .filter(|s| {
                s.id != ctx.shard && s.healthy() && !lock_ok(&s.queue).is_empty()
            })
            .max_by_key(|s| s.load.load(Ordering::Relaxed));
        if let Some(victim) = victim {
            let mut vq = lock_ok(&victim.queue);
            let mut moved = 0usize;
            let mut gained = 0usize;
            while gained < room {
                // steal the lowest QoS class first (best_effort, then
                // batch, then interactive): re-queueing costs the job a
                // fresh head-of-line wait on the thief, so the churn
                // lands on the class with the loosest latency contract.
                // Within a class, take from the back of the deque (the
                // owner admits from the front). Decision-equivalence is
                // unaffected — the run seed is placement-invariant.
                let Some(pos) = [QosClass::BestEffort, QosClass::Batch, QosClass::Interactive]
                    .iter()
                    .find_map(|c| vq.iter().rposition(|j| j.class == *c))
                else {
                    break;
                };
                let Some(job) = vq.remove(pos) else { break };
                victim.load.fetch_sub(job.lanes as u64, Ordering::Relaxed);
                ctx.load.fetch_add(job.lanes as u64, Ordering::Relaxed);
                gained += job.lanes.max(1);
                moved += 1;
                lock_ok(&ctx.queue).push_back(job);
            }
            if moved > 0 {
                return moved;
            }
        }
        // no queue to raid: ask the most-loaded busy shard to shed an
        // in-flight run our way (live migration, DESIGN.md §12). Only
        // when the imbalance is real — the victim at least twice as
        // loaded as the thief — so two lightly-loaded shards cannot
        // ping-pong runs between themselves; the victim additionally
        // caps its grant at half its lanes (see `shed_to_thieves`), so
        // one handoff converges toward balance instead of inverting it.
        if self.cfg.migration {
            let my_load = ctx.load.load(Ordering::Relaxed);
            let busy = slots
                .iter()
                .filter(|s| {
                    s.id != ctx.shard
                        && s.healthy()
                        && !s.draining.load(Ordering::Relaxed)
                        && s.load.load(Ordering::Relaxed) >= 2 * (my_load + 1)
                })
                .max_by_key(|s| s.load.load(Ordering::Relaxed));
            if let Some(victim) = busy {
                let mut shed = lock_ok(&victim.shed);
                let already = shed.iter().any(|r| r.thief == ctx.shard);
                if !already && shed.len() < MAX_SHED_REQUESTS {
                    shed.push(ShedRequest { thief: ctx.shard, lanes: room });
                }
            }
        }
        0
    }

    /// Hand a queued/detached job to any live shard except the caller's
    /// (drain-via-migration re-placement). Returns the job back when no
    /// survivor accepted it.
    pub(crate) fn resubmit(&self, job: QueuedJob) -> std::result::Result<(), QueuedJob> {
        let slots = self.snapshot();
        if slots.is_empty() {
            return Err(job);
        }
        let est = job.lanes as u64;
        let first = self.rr.fetch_add(1, Ordering::Relaxed) % slots.len();
        match send_with_fallback(&slots, first, est, ShardMsg::Job(job)) {
            Ok(()) => {
                self.signal.bump();
                Ok(())
            }
            Err(ShardMsg::Job(job)) => Err(job),
            Err(_) => unreachable!("resubmit sent a Job"),
        }
    }

    /// Hand a detached job directly to shard `thief` (shed handoff).
    /// Returns the job back when the thief is gone or draining.
    pub(crate) fn send_to(
        &self,
        thief: usize,
        job: QueuedJob,
    ) -> std::result::Result<(), QueuedJob> {
        let slots = self.snapshot();
        let Some(slot) = slots.iter().find(|s| s.id == thief) else {
            return Err(job);
        };
        if slot.draining.load(Ordering::Relaxed) {
            return Err(job);
        }
        let est = job.lanes as u64;
        slot.load.fetch_add(est, Ordering::Relaxed);
        match slot.tx.send(ShardMsg::Job(job)) {
            Ok(()) => {
                self.signal.bump();
                Ok(())
            }
            Err(mpsc::SendError(ShardMsg::Job(job))) => {
                slot.load.fetch_sub(est, Ordering::Relaxed);
                Err(job)
            }
            Err(_) => unreachable!("send_to sent a Job"),
        }
    }

    /// Least-loaded live shard of the first class in `pref` that has
    /// any healthy non-draining candidate, excluding `exclude` — the
    /// gamma-driven migration destination picker (DESIGN.md §15). The
    /// preference list encodes the fallback chain (e.g. a high-gamma
    /// run prefers `DraftHeavy`, falls back to `Balanced`).
    pub(crate) fn pick_shard_of_class(
        &self,
        exclude: usize,
        pref: &[ShardClass],
    ) -> Option<usize> {
        let slots = self.snapshot();
        for &want in pref {
            let best = slots
                .iter()
                .filter(|s| {
                    s.id != exclude
                        && s.class == want
                        && s.healthy()
                        && !s.draining.load(Ordering::Relaxed)
                })
                .min_by_key(|s| s.load.load(Ordering::Relaxed));
            if let Some(s) = best {
                return Some(s.id);
            }
        }
        None
    }
}

/// Cloneable submitter side of the pool: routes each request to a live
/// shard over the immutable placement snapshot, tracks outstanding
/// load, and manages the shard lifecycle (`add_shard` /
/// `remove_shard`). Dropping every clone lets every shard drain and
/// exit.
#[derive(Clone)]
pub struct PoolHandle {
    reg: Arc<ShardRegistry>,
}

impl Drop for PoolHandle {
    fn drop(&mut self) {
        // wake parked shards so a dropped last handle (whose registry —
        // and thus every channel sender — is about to die) is noticed
        // without waiting out the park timeout
        self.reg.signal.bump();
    }
}

impl PoolHandle {
    /// Live healthy shards (dead-but-not-yet-recovered slots excluded —
    /// the autoscaler must not count a corpse as capacity).
    pub fn shards(&self) -> usize {
        self.reg.snapshot().iter().filter(|s| s.healthy()).count()
    }

    /// Current outstanding lane estimate on shard `id` (telemetry);
    /// 0 for removed shards.
    pub fn load_of(&self, id: usize) -> u64 {
        self.reg
            .snapshot()
            .iter()
            .find(|s| s.id == id)
            .map(|s| s.load.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// (shard id, outstanding lane estimate) per live healthy shard —
    /// the autoscaler's scale-down victim input (a dead shard must
    /// never be picked as a drain victim).
    pub fn shard_loads(&self) -> Vec<(usize, u64)> {
        self.reg
            .snapshot()
            .iter()
            .filter(|s| s.healthy())
            .map(|s| (s.id, s.load.load(Ordering::Relaxed)))
            .collect()
    }

    /// Queued-but-unstarted jobs across all live healthy shards
    /// (autoscaler queue-depth signal).
    pub fn queued_jobs(&self) -> usize {
        self.reg
            .snapshot()
            .iter()
            .filter(|s| s.healthy())
            .map(|s| lock_ok(&s.queue).len())
            .sum()
    }

    /// Outstanding lane estimate across all live healthy shards.
    pub fn outstanding_lanes(&self) -> u64 {
        self.reg
            .snapshot()
            .iter()
            .filter(|s| s.healthy())
            .map(|s| s.load.load(Ordering::Relaxed))
            .sum()
    }

    /// Seconds the oldest queued-but-unstarted job has been waiting in
    /// its current queue — the live head-of-line admission-wait signal
    /// the autoscaler tracks (0.0 with empty queues). Uses the
    /// per-queue stamp, not the original submit time, so a migrated
    /// mid-solve run doesn't read as a huge admission backlog.
    pub fn oldest_queue_wait_s(&self) -> f64 {
        let mut oldest: Option<Instant> = None;
        for s in self.reg.snapshot().iter().filter(|s| s.healthy()) {
            if let Some(job) = lock_ok(&s.queue).front() {
                oldest = Some(match oldest {
                    Some(t) if t <= job.queued_at => t,
                    _ => job.queued_at,
                });
            }
        }
        oldest.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// One internally-consistent sample of the autoscaler's signals —
    /// `(live healthy shards, queued jobs, oldest head-of-line wait
    /// seconds, outstanding lanes)` — from a single placement snapshot
    /// and ONE pass over each shard's queue mutex, so depth and wait
    /// cannot disagree and the per-interval lock traffic on the hot
    /// scheduler queues stays at one acquisition per shard. Dead /
    /// respawning shards are excluded from every component: the policy
    /// must neither count a corpse as capacity nor read its queue.
    pub fn sample_signals(&self) -> (usize, usize, f64, u64) {
        let slots = self.reg.snapshot();
        let mut healthy = 0usize;
        let mut queued = 0usize;
        let mut oldest: Option<Instant> = None;
        let mut lanes = 0u64;
        for s in slots.iter() {
            if !s.healthy() {
                continue;
            }
            healthy += 1;
            let q = lock_ok(&s.queue);
            queued += q.len();
            if let Some(job) = q.front() {
                oldest = Some(match oldest {
                    Some(t) if t <= job.queued_at => t,
                    _ => job.queued_at,
                });
            }
            drop(q);
            lanes += s.load.load(Ordering::Relaxed);
        }
        let wait = oldest.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        (healthy, queued, wait, lanes)
    }

    /// Pick the slot position for one request (see the module docs for
    /// the policies) over a frozen snapshot. `est` is the request's
    /// lane estimate — least-loaded ties break toward a shard whose
    /// last-accepted batch had the same shape, so equal-width lanes
    /// pack into dense step batches instead of fragmenting.
    fn place(&self, slots: &[ShardSlot], expr: &str, est: u64) -> usize {
        let n = slots.len();
        if n == 1 {
            return 0;
        }
        match self.reg.cfg.placement {
            PlacePolicy::RoundRobin => self.reg.rr.fetch_add(1, Ordering::Relaxed) % n,
            PlacePolicy::Affinity => (hash::fnv1a_str(expr) % n as u64) as usize,
            PlacePolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = u64::MAX;
                let mut best_shape = false;
                for (i, s) in slots.iter().enumerate() {
                    let v = s.load.load(Ordering::Relaxed);
                    let shape = s.shape.load(Ordering::Relaxed) == est;
                    if v < best_load || (v == best_load && shape && !best_shape) {
                        best = i;
                        best_load = v;
                        best_shape = shape;
                    }
                }
                if best_shape {
                    self.reg.shape_hits.fetch_add(1, Ordering::Relaxed);
                }
                best
            }
        }
    }

    /// Route and enqueue one request over the immutable placement
    /// snapshot — no lock is shared with other submitters (ROADMAP
    /// item: the hot path is back to atomics). The lane estimate joins
    /// the load gauge immediately (so a burst of submissions spreads
    /// before any shard has even started) and is returned by the owning
    /// shard on the terminal reply. A shard whose thread died (backend
    /// init failure) has a closed channel; submission falls back to the
    /// remaining shards in rotation before giving up, so one dead shard
    /// degrades capacity instead of failing a fraction of all traffic.
    pub fn submit(&self, req: SolveRequest) -> Result<()> {
        let slots = self.reg.snapshot();
        let n = slots.len();
        if n == 0 {
            bail!("no live scheduler shards");
        }
        let est = lane_estimate(req.method, self.reg.cfg.pool_size) as u64;
        let first = self.place(&slots, &req.expr, est);
        match send_with_fallback(&slots, first, est, ShardMsg::Solve(req)) {
            Ok(()) => {
                // wake parked steal-mode shards: intake goes through the
                // channel, which a signal-parked shard is not watching
                self.reg.signal.bump();
                Ok(())
            }
            Err(_) => Err(anyhow!("all {n} scheduler shards gone")),
        }
    }

    /// Hot-add one shard: spawn its scheduler thread (backend built by
    /// the pool's stored factory on that thread) and publish a new
    /// placement snapshot including it. Returns the new shard id. The
    /// shared prefix tier grows its per-shard tables on the shard's
    /// first acquisition.
    pub fn add_shard(&self) -> Result<usize> {
        let id = {
            // lifecycle ops are serialized; submitters never block here.
            // respawn_locked retains the join handle so remove_shard can
            // reap the thread after its done signal (initial shards are
            // joined by BackendPool::spawn's caller instead)
            let mut lc = lock_ok(&self.reg.lifecycle);
            self.reg.respawn_locked(&mut lc, None)?
        };
        lock_ok(&self.reg.metrics).record_shard_added();
        Ok(id)
    }

    /// Hot-add one shard of a specific hardware class (the class-scoped
    /// autoscaler's scale-up path — the config pattern indexes by shard
    /// id, which drifts monotonically under churn, so a targeted
    /// scale-up must pin the class explicitly).
    pub fn add_shard_of(&self, class: ShardClass) -> Result<usize> {
        let id = {
            let mut lc = lock_ok(&self.reg.lifecycle);
            self.reg.respawn_locked(&mut lc, Some(class))?
        };
        lock_ok(&self.reg.metrics).record_shard_added();
        Ok(id)
    }

    /// Live healthy shards of `class`.
    pub fn shards_of(&self, class: ShardClass) -> usize {
        self.reg
            .snapshot()
            .iter()
            .filter(|s| s.healthy() && s.class == class)
            .count()
    }

    /// The hardware class of live shard `id` (None once removed).
    pub fn class_of_shard(&self, id: usize) -> Option<ShardClass> {
        self.reg.snapshot().iter().find(|s| s.id == id).map(|s| s.class)
    }

    /// `(shard id, outstanding lane estimate)` per live healthy shard
    /// of `class` — the class-scoped autoscaler's victim input.
    pub fn shard_loads_of(&self, class: ShardClass) -> Vec<(usize, u64)> {
        self.reg
            .snapshot()
            .iter()
            .filter(|s| s.healthy() && s.class == class)
            .map(|s| (s.id, s.load.load(Ordering::Relaxed)))
            .collect()
    }

    /// Least-loaded placements that landed on a shard whose last batch
    /// shape matched the request (the batch-shape placement hint).
    pub fn placement_shape_hits(&self) -> u64 {
        self.reg.shape_hits.load(Ordering::Relaxed)
    }

    /// One consistent [`PoolHandle::sample_signals`]-shaped sample per
    /// hardware class in the configured pattern (deduped; `[Balanced]`
    /// for a uniform pool) — the class-scoped autoscaler's input. A
    /// class every shard of which has drained away still reports a row
    /// (all zeros), so its policy can scale it back up.
    pub fn sample_class_signals(&self) -> Vec<(ShardClass, (usize, usize, f64, u64))> {
        let mut classes: Vec<ShardClass> = self.reg.cfg.shard_classes.clone();
        classes.sort();
        classes.dedup();
        if classes.is_empty() {
            classes.push(ShardClass::Balanced);
        }
        let slots = self.reg.snapshot();
        classes
            .into_iter()
            .map(|c| {
                let mut healthy = 0usize;
                let mut queued = 0usize;
                let mut oldest: Option<Instant> = None;
                let mut lanes = 0u64;
                for s in slots.iter().filter(|s| s.class == c && s.healthy()) {
                    healthy += 1;
                    let q = lock_ok(&s.queue);
                    queued += q.len();
                    if let Some(job) = q.front() {
                        oldest = Some(match oldest {
                            Some(t) if t <= job.queued_at => t,
                            _ => job.queued_at,
                        });
                    }
                    drop(q);
                    lanes += s.load.load(Ordering::Relaxed);
                }
                let wait = oldest.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                (c, (healthy, queued, wait, lanes))
            })
            .collect()
    }

    /// Hot-remove shard `id`: publish a snapshot without it and mark it
    /// draining (no new placements, no stealing), re-place its
    /// queued-but-unstarted jobs onto the survivors, close its channel,
    /// and block until it has quiesced. With `migration` enabled the
    /// shard detaches its in-flight runs at the next step boundary and
    /// re-homes them on the survivors, so the wait is O(one step);
    /// otherwise it finishes them locally (O(one solve)). Other shards
    /// keep serving throughout. Returns the drain duration in seconds.
    pub fn remove_shard(&self, id: usize) -> Result<f64> {
        let t0 = Instant::now();
        let (slot, hook) = {
            let mut lc = lock_ok(&self.reg.lifecycle);
            let cur = self.reg.snapshot();
            let pos = cur
                .iter()
                .position(|s| s.id == id)
                .ok_or_else(|| anyhow!("no live shard {id}"))?;
            let min = self.reg.cfg.min_shards.max(1);
            // the floor is on HEALTHY shards: with a crashed slot still
            // in the snapshot, draining a healthy one could leave the
            // pool serving on corpses alone
            let healthy = cur.iter().filter(|s| s.healthy()).count();
            let victim_healthy = cur[pos].healthy();
            if victim_healthy && healthy <= min {
                bail!("cannot drain shard {id}: pool is at min_shards={min}");
            }
            // with a heterogeneous fleet the floor holds PER CLASS: a
            // class drained to zero could never be scaled back up from
            // load alone, and losing the last target-capable shard
            // would strand every speculative run's verify/rewrite work
            // on hostile cost profiles (DESIGN.md §15)
            if victim_healthy && !self.reg.cfg.shard_classes.is_empty() {
                let vclass = cur[pos].class;
                let same_class =
                    cur.iter().filter(|s| s.healthy() && s.class == vclass).count();
                if same_class <= 1 {
                    bail!(
                        "cannot drain shard {id}: last healthy {} shard",
                        vclass.name()
                    );
                }
                if vclass.target_capable() {
                    let capable = cur
                        .iter()
                        .filter(|s| s.healthy() && s.class.target_capable())
                        .count();
                    if capable <= 1 {
                        bail!(
                            "cannot drain shard {id}: last target-capable shard"
                        );
                    }
                }
            }
            let mut v: Vec<ShardSlot> = cur.iter().cloned().collect();
            let slot = v.remove(pos);
            *write_ok(&self.reg.slots) = Arc::new(v);
            slot.draining.store(true, Ordering::SeqCst);
            let hook = lc.remove(&id).expect("every live shard has a lifecycle hook");
            (slot, hook)
        };
        // re-place queued-but-unstarted jobs by re-submitting them
        // through the survivors' channels (a parked shard wakes on its
        // channel or the signal); gauges move with the jobs. In-flight
        // runs are migrated by the shard's own loop when it observes
        // the draining flag (it owns the backend).
        let survivors = self.reg.snapshot();
        let moved: Vec<QueuedJob> = lock_ok(&slot.queue).drain(..).collect();
        for (i, job) in moved.into_iter().enumerate() {
            let est = job.lanes as u64;
            slot.load.fetch_sub(est, Ordering::Relaxed);
            if send_with_fallback(&survivors, i % survivors.len(), est, ShardMsg::Job(job))
                .is_err()
            {
                // every survivor is dead: the reply sender drops and
                // the client sees a disconnect
                log::error!("drain of shard {id}: no survivor accepted a queued job");
            }
        }
        self.reg.signal.bump();
        // closing the channel is the quiesce signal: the shard migrates
        // (or finishes) its in-flight runs, releases its tier handles,
        // flushes its clock gauges, and drops its done sender
        drop(slot);
        self.reg.signal.bump();
        let ShardHook { done_rx, join } = hook;
        let _ = done_rx.recv();
        if let Some(j) = join {
            // hot-added shard: reap the thread so its final flush is
            // fully ordered before remove_shard returns
            let _ = j.join();
        }
        let secs = t0.elapsed().as_secs_f64();
        {
            let mut m = lock_ok(&self.reg.metrics);
            m.record_shard_removed(secs);
            // fold the dead id's gauge columns into the retired
            // accumulators (autoscale churn must not grow them forever)
            m.retire_shard(id);
        }
        Ok(secs)
    }
}

pub struct BackendPool;

impl BackendPool {
    /// Spawn `cfg.shards` scheduler threads, each owning one backend
    /// built by `factory(shard)` ON that shard's thread. Returns the
    /// routing handle plus one join handle per initial shard (the
    /// server ignores them; benches join them to flush final clock
    /// metrics). The factory is retained by the pool so
    /// [`PoolHandle::add_shard`] can spawn more shards at runtime.
    pub fn spawn<F>(
        cfg: SsrConfig,
        vocab: Vocab,
        metrics: Arc<Mutex<Metrics>>,
        factory: F,
    ) -> Result<(PoolHandle, Vec<std::thread::JoinHandle<()>>)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        // the spill store opens before any shard spawns: a warm restart
        // reloads the prior process's demoted prefixes, and an unusable
        // spill dir fails pool construction instead of surfacing as
        // silent cache misses later
        let spill = cfg
            .prefix
            .spill_dir
            .as_ref()
            .map(|d| SpillStore::open(d, cfg.prefix.spill_bytes))
            .transpose()
            .context("opening prefix spill store")?;
        let tier = Arc::new(SharedPrefixTier::with_options(
            if cfg.prefix.enabled { cfg.prefix.capacity } else { 0 },
            cfg.prefix.max_bytes,
            cfg.prefix.evict,
            spill,
        ));
        lock_ok(&metrics).init_shards(shards);
        let qcap = cfg.quarantine_cap;
        let reg = Arc::new(ShardRegistry {
            cfg,
            vocab,
            metrics,
            tier,
            factory: Box::new(factory),
            next_id: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            slots: RwLock::new(Arc::new(Vec::new())),
            lifecycle: Mutex::new(HashMap::new()),
            quarantine: Mutex::new(QuarantineLru::new(qcap)),
            signal: Arc::new(WorkSignal::new()),
            shape_hits: AtomicU64::new(0),
        });
        let mut joins = Vec::with_capacity(shards);
        let mut v = Vec::with_capacity(shards);
        for _ in 0..shards {
            let id = reg.next_id.fetch_add(1, Ordering::Relaxed);
            let class = reg.cfg.class_of(id);
            let (slot, hook, join) = reg.spawn_shard(id, class)?;
            lock_ok(&reg.lifecycle).insert(id, hook);
            v.push(slot);
            joins.push(join);
        }
        *write_ok(&reg.slots) = Arc::new(v);
        Ok((PoolHandle { reg }, joins))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::config::StopRule;
    use crate::coordinator::engine::Method;
    use crate::model::tokenizer;

    fn spawn_pool(
        shards: usize,
        placement: PlacePolicy,
    ) -> (PoolHandle, Vec<std::thread::JoinHandle<()>>, Arc<Mutex<Metrics>>) {
        let mut cfg = SsrConfig::default();
        cfg.shards = shards;
        cfg.placement = placement;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) =
            BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), |_s| {
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            })
            .unwrap();
        (handle, joins, metrics)
    }

    fn solve(
        handle: &PoolHandle,
        expr: &str,
        seed: u64,
    ) -> mpsc::Receiver<Result<crate::util::json::Value>> {
        let (rtx, rrx) = mpsc::channel();
        handle
            .submit(SolveRequest {
                expr: expr.to_string(),
                method: Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                seed,
                deadline_ms: 0,
                class: QosClass::default(),
                reply: rtx.into(),
            })
            .unwrap();
        rrx
    }

    #[test]
    fn pool_completes_work_across_shards_and_drains() {
        // gate the shard backends so every submission lands (and the
        // load gauges fill) before any shard starts — the least-loaded
        // alternation the assertions rely on, without sleeps
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = Arc::new(Mutex::new(gate_rx));
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::LeastLoaded;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |_s| {
                let _ = gate.lock().unwrap().recv();
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        let replies: Vec<_> =
            (0..8).map(|i| solve(&handle, &format!("{}+{}", i + 1, i + 2), i as u64)).collect();
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        for (i, r) in replies.iter().enumerate() {
            let v = r.recv().unwrap().unwrap();
            assert_eq!(v.get_i64("gold").unwrap(), (2 * i + 3) as i64);
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 8);
        assert_eq!(m.errors, 0);
        assert_eq!(m.total_shard_requests(), 8);
        // least-loaded spreads an 8-burst of equal jobs across 2 shards
        assert!(
            m.shard_requests.values().all(|&r| r >= 2),
            "placement starved a shard: {:?}",
            m.shard_requests
        );
        assert_eq!(m.shard_clocks.len(), 2);
        assert!(m.model_secs_makespan() > 0.0);
        assert!(m.model_secs >= m.model_secs_makespan());
    }

    #[test]
    fn loads_return_to_zero_after_drain() {
        let (handle, joins, _metrics) = spawn_pool(2, PlacePolicy::RoundRobin);
        let replies: Vec<_> = (0..6).map(|i| solve(&handle, "3+4*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(handle.load_of(0) + handle.load_of(1), 0, "load gauge leaked");
        assert_eq!(handle.outstanding_lanes(), 0);
        assert_eq!(handle.queued_jobs(), 0);
        assert_eq!(handle.oldest_queue_wait_s(), 0.0);
        let (shards, queued, wait, lanes) = handle.sample_signals();
        assert_eq!((shards, queued, lanes), (2, 0, 0));
        assert_eq!(wait, 0.0);
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn affinity_pins_repeat_prompts_to_one_shard() {
        let (handle, joins, metrics) = spawn_pool(2, PlacePolicy::Affinity);
        for round in 0..3u64 {
            for expr in ["17+25*3", "4+5*6", "9+1*2", "8+8*8"] {
                let r = solve(&handle, expr, round);
                assert!(r.recv().unwrap().is_ok());
            }
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 12);
        // affinity: a prompt only ever visits one shard, so the tier
        // never has to re-prefill a known prompt on a second shard
        assert_eq!(m.prefix_misses, 4, "one miss per distinct prompt");
        assert_eq!(m.prefix_shard_fills, 0, "affinity re-prefilled a prompt");
        assert_eq!(m.prefix_hits, 8);
    }

    #[test]
    fn handle_clones_keep_the_pool_alive() {
        let (handle, joins, _metrics) = spawn_pool(1, PlacePolicy::LeastLoaded);
        let h2 = handle.clone();
        drop(handle);
        // a surviving clone still submits; shards only drain when the
        // last clone drops
        let r = solve(&h2, "1+2", 0);
        assert!(r.recv().unwrap().is_ok());
        drop(h2);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn add_shard_serves_and_remove_shard_respects_min() {
        let (handle, joins, metrics) = spawn_pool(1, PlacePolicy::RoundRobin);
        assert_eq!(handle.shards(), 1);
        let id = handle.add_shard().unwrap();
        assert_eq!(id, 1);
        assert_eq!(handle.shards(), 2);
        // round-robin over 2 live shards: both serve
        let replies: Vec<_> = (0..6).map(|i| solve(&handle, "5+6*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.shards_added, 1);
            assert!(
                m.shard_requests.get(&1).copied().unwrap_or(0) > 0,
                "hot-added shard never served: {:?}",
                m.shard_requests
            );
        }
        // drain the added shard while the original keeps serving
        let secs = handle.remove_shard(id).unwrap();
        assert!(secs >= 0.0);
        assert_eq!(handle.shards(), 1);
        let r = solve(&handle, "2+2", 9);
        assert!(r.recv().unwrap().is_ok());
        // min_shards floor: the last shard cannot be drained
        assert!(handle.remove_shard(0).is_err());
        // removing a removed shard errors cleanly
        assert!(handle.remove_shard(id).is_err());
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.shards_removed, 1);
            assert_eq!(m.drains, 1);
            assert!(m.drain_secs_max >= 0.0);
            // the dead id's gauge columns were folded away (compaction)
            assert!(!m.shard_requests.contains_key(&1), "dead-id column retained");
            assert!(!m.shard_clocks.contains_key(&1), "dead-id clock retained");
            assert_eq!(m.total_shard_requests(), 7, "retired requests lost");
        }
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn shard_classes_floor_and_targeted_scale_up() {
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::RoundRobin;
        cfg.shard_classes = vec![ShardClass::DraftHeavy, ShardClass::TargetHeavy];
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) =
            BackendPool::spawn(cfg, tokenizer::builtin_vocab(), Arc::clone(&metrics), |_s| {
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            })
            .unwrap();
        assert_eq!(handle.class_of_shard(0), Some(ShardClass::DraftHeavy));
        assert_eq!(handle.class_of_shard(1), Some(ShardClass::TargetHeavy));
        // classes shape clocks and capacity, never decisions: both serve
        let replies: Vec<_> = (0..4).map(|i| solve(&handle, "3+4*2", i as u64)).collect();
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
        // per-class floor: neither shard is removable while it is the
        // last healthy member of its class
        assert!(handle.remove_shard(0).is_err(), "drained last draft_heavy");
        assert!(handle.remove_shard(1).is_err(), "drained last target-capable");
        // targeted scale-up pins the class (the id-indexed pattern would
        // have made shard 2 draft_heavy)
        let id = handle.add_shard_of(ShardClass::TargetHeavy).unwrap();
        assert_eq!(id, 2);
        assert_eq!(handle.class_of_shard(2), Some(ShardClass::TargetHeavy));
        assert_eq!(handle.shards_of(ShardClass::TargetHeavy), 2);
        // with a second target-capable shard live, the first can drain
        assert!(handle.remove_shard(1).is_ok());
        assert_eq!(handle.shards_of(ShardClass::TargetHeavy), 1);
        let sig = handle.sample_class_signals();
        assert_eq!(sig.len(), 2, "one signal row per configured class");
        assert_eq!(sig[0].0, ShardClass::DraftHeavy);
        assert_eq!(sig[1].0, ShardClass::TargetHeavy);
        assert_eq!((sig[0].1 .0, sig[1].1 .0), (1, 1), "healthy counts");
        let loads = handle.shard_loads_of(ShardClass::TargetHeavy);
        assert_eq!(loads.len(), 1);
        assert_eq!(loads[0], (2, 0));
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn least_loaded_tie_breaks_on_batch_shape() {
        // gate the backends so both submissions queue (and stamp the
        // slots' shape hints) before either shard starts serving
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = Arc::new(Mutex::new(gate_rx));
        let mut cfg = SsrConfig::default();
        cfg.shards = 2;
        cfg.placement = PlacePolicy::LeastLoaded;
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, joins) = BackendPool::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move |_s| {
                let _ = gate.lock().unwrap().recv();
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        let solve_n = |n: usize, seed: u64| {
            let (rtx, rrx) = mpsc::channel();
            handle
                .submit(SolveRequest {
                    expr: "3+4*2".to_string(),
                    method: Method::Ssr { n, tau: 7, stop: StopRule::Full },
                    seed,
                    deadline_ms: 0,
                    class: QosClass::default(),
                    reply: rtx.into(),
                })
                .unwrap();
            rrx
        };
        // empty pool: est 3 -> slot 0 (lowest), est 5 -> slot 1 (less
        // loaded); each send stamps the slot's shape hint
        let r0 = solve_n(3, 1);
        let r1 = solve_n(5, 2);
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert!(r0.recv().unwrap().is_ok());
        assert!(r1.recv().unwrap().is_ok());
        assert_eq!(handle.load_of(0) + handle.load_of(1), 0);
        assert_eq!(handle.placement_shape_hits(), 0, "no tie matched yet");
        // drained pool, loads tied at 0: the 5-lane repeat prefers the
        // shard whose last batch was 5 lanes wide instead of slot 0
        let r2 = solve_n(5, 3);
        assert!(r2.recv().unwrap().is_ok());
        assert_eq!(handle.placement_shape_hits(), 1);
        drop(handle);
        for j in joins {
            j.join().unwrap();
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.shard_requests.get(&1).copied().unwrap_or(0), 2);
        assert_eq!(m.placement_shape_hits, 0, "metrics gauge synced by stats op only");
    }

    #[test]
    fn quarantine_lru_bounds_and_evicts_oldest() {
        let mut q = QuarantineLru::new(3);
        assert_eq!(q.insert(1), 0);
        assert_eq!(q.insert(2), 0);
        assert_eq!(q.insert(3), 0);
        assert_eq!(q.len(), 3);
        // touch 1 so 2 becomes the LRU victim
        assert!(q.contains(1));
        assert_eq!(q.insert(4), 1, "cap overflow evicts exactly one");
        assert_eq!(q.len(), 3);
        assert!(!q.contains(2), "least-recently-touched seed evicted");
        assert!(q.contains(1) && q.contains(3) && q.contains(4));
        // re-inserting a present seed never evicts
        assert_eq!(q.insert(4), 0);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn work_signal_epoch_round_trip() {
        let s = WorkSignal::new();
        let e0 = s.epoch();
        s.bump();
        assert_eq!(s.epoch(), e0 + 1);
        // a stale epoch returns immediately (no timeout wait)
        let t0 = Instant::now();
        s.wait_past(e0, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_secs(1));
        // a current epoch waits out the (short) timeout
        let t0 = Instant::now();
        s.wait_past(s.epoch(), Duration::from_millis(20));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
