//! The versioned wire protocol the serving front end speaks
//! (PROTOCOL.md is the normative schema reference; DESIGN.md §16 the
//! design notes). This module owns the pieces both the server and its
//! clients (tests, benches) need:
//!
//! * the protocol version and feature list the `hello` op advertises,
//! * the length-delimited frame codec (`--transport framed`): 4-byte
//!   big-endian payload length + UTF-8 JSON payload, bounded by
//!   [`MAX_FRAME_BYTES`] on both sides,
//! * the machine-readable [`ErrorCode`] enum and the [`WireError`]
//!   envelope, rendered per transport — the framed envelope is
//!   `{"ok":false,"error":{"code":...,"message":...}}`; jsonl keeps
//!   the legacy top-level shapes for one release (`"error":<string>`,
//!   and `"err":"overloaded"` with `reason`/`retry_after_ms`) with the
//!   `code` field added alongside so clients can migrate early.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::config::Transport;
use crate::util::json::{self, Value};

/// Wire protocol version, advertised by `{"op":"hello"}` and included
/// in `{"op":"stats"}`. Bumped only on breaking changes; additive
/// fields and events do NOT bump it (PROTOCOL.md versioning policy).
pub const PROTO_VERSION: i64 = 1;

/// Capabilities advertised by the `hello` handshake.
pub const FEATURES: [&str; 2] = ["streaming", "framed"];

/// Hard cap on one request/reply payload, both transports: a framed
/// header declaring more is answered with an `oversized` error and the
/// payload is discarded without buffering it; a JSON line past this is
/// drained the same way (the historical `MAX_LINE_BYTES`).
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Machine-readable error class, carried as `code` on every error
/// reply (framed: inside the `error` envelope; jsonl: a top-level
/// field next to the legacy shape).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// unparseable JSON, bad field types, unknown method, bad values
    Malformed,
    /// request line/frame exceeded [`MAX_FRAME_BYTES`]
    Oversized,
    /// connection idle past `--conn-idle-timeout-ms` (then closed)
    IdleTimeout,
    /// intake refused by admission control; `reason` and
    /// `retry_after_ms` say why and when to retry (DESIGN.md §14)
    Overloaded,
    /// poison run refused after exhausting its crash-retry budget
    Quarantined,
    /// unknown `op` value (the handshake lists what this server speaks)
    UnsupportedOp,
    /// caught panic or non-classifiable scheduler failure
    Internal,
}

impl ErrorCode {
    pub const ALL: [ErrorCode; 7] = [
        ErrorCode::Malformed,
        ErrorCode::Oversized,
        ErrorCode::IdleTimeout,
        ErrorCode::Overloaded,
        ErrorCode::Quarantined,
        ErrorCode::UnsupportedOp,
        ErrorCode::Internal,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::Oversized => "oversized",
            ErrorCode::IdleTimeout => "idle_timeout",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Quarantined => "quarantined",
            ErrorCode::UnsupportedOp => "unsupported_op",
            ErrorCode::Internal => "internal",
        }
    }

    /// Classify a scheduler/pool error message bubbling up the reply
    /// channel. Quarantine refusals are the one machine-actionable
    /// case (the client must change its request, not retry it);
    /// everything else from that path is an internal serving failure.
    pub fn classify(msg: &str) -> ErrorCode {
        if msg.contains("quarantined") {
            ErrorCode::Quarantined
        } else {
            ErrorCode::Internal
        }
    }
}

/// A structured error reply, transport-agnostic until rendered.
#[derive(Debug, Clone)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
    /// overload backoff hint (DESIGN.md §14); `overloaded` only
    pub retry_after_ms: Option<u64>,
    /// which intake gate refused (`rate_limited` | `queue_full` |
    /// `lane_quota` | `shed`); `overloaded` only
    pub reason: Option<String>,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into(), retry_after_ms: None, reason: None }
    }

    /// An admission-control refusal with its gate and backoff hint.
    pub fn overloaded(reason: &str, retry_after_ms: u64) -> WireError {
        WireError {
            code: ErrorCode::Overloaded,
            message: format!("overloaded ({reason})"),
            retry_after_ms: Some(retry_after_ms),
            reason: Some(reason.to_string()),
        }
    }

    /// Classify an error that came up the scheduler reply channel.
    pub fn from_scheduler(e: &anyhow::Error) -> WireError {
        let msg = format!("{e:#}");
        WireError::new(ErrorCode::classify(&msg), msg)
    }

    /// Render the reply object for `transport`. Framed always uses the
    /// envelope; jsonl reproduces the legacy shapes exactly (plus the
    /// additive `code` field) so pre-PR-9 clients keep parsing.
    pub fn render(&self, transport: Transport) -> Value {
        match transport {
            Transport::Framed => {
                let mut e = vec![
                    ("code", json::s(self.code.name())),
                    ("message", json::s(self.message.clone())),
                ];
                if let Some(r) = &self.reason {
                    e.push(("reason", json::s(r.clone())));
                }
                if let Some(ms) = self.retry_after_ms {
                    e.push(("retry_after_ms", json::i(ms as i64)));
                }
                json::obj(vec![("ok", Value::Bool(false)), ("error", json::obj(e))])
            }
            Transport::Jsonl => {
                if self.code == ErrorCode::Overloaded {
                    json::obj(vec![
                        ("ok", Value::Bool(false)),
                        ("err", json::s("overloaded")),
                        ("code", json::s(self.code.name())),
                        ("reason", json::s(self.reason.clone().unwrap_or_default())),
                        ("retry_after_ms", json::i(self.retry_after_ms.unwrap_or(0) as i64)),
                    ])
                } else {
                    json::obj(vec![
                        ("ok", Value::Bool(false)),
                        ("error", json::s(self.message.clone())),
                        ("code", json::s(self.code.name())),
                    ])
                }
            }
        }
    }
}

/// The `{"op":"hello"}` handshake reply.
pub fn hello_reply() -> Value {
    json::obj(vec![
        ("ok", Value::Bool(true)),
        ("proto", json::i(PROTO_VERSION)),
        ("features", json::arr(FEATURES.iter().map(|f| json::s(*f)).collect())),
    ])
}

/// Length-prefix a payload. Fails (rather than truncates) on payloads
/// past [`MAX_FRAME_BYTES`] — the server never produces one; a client
/// asking us to is a bug at the call site.
pub fn encode_frame(payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() > MAX_FRAME_BYTES {
        bail!("frame payload of {} bytes exceeds {MAX_FRAME_BYTES}", payload.len());
    }
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// One step of incremental frame decoding over a connection's read
/// buffer (the server's event loop calls this until `NeedMore`).
#[derive(Debug, PartialEq, Eq)]
pub enum FrameDecode {
    /// header or payload incomplete — read more bytes first
    NeedMore,
    /// one complete payload, drained from the buffer
    Frame(Vec<u8>),
    /// header declared more than [`MAX_FRAME_BYTES`]: the header was
    /// drained; the caller must discard this many payload bytes as
    /// they arrive (keeping the connection alive), then resume decoding
    Oversized(usize),
}

/// Try to decode one frame from the front of `buf`, draining consumed
/// bytes. Declared-oversized frames consume only the header — see
/// [`FrameDecode::Oversized`].
pub fn decode_frame(buf: &mut Vec<u8>) -> FrameDecode {
    if buf.len() < 4 {
        return FrameDecode::NeedMore;
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME_BYTES {
        buf.drain(..4);
        return FrameDecode::Oversized(len);
    }
    if buf.len() < 4 + len {
        return FrameDecode::NeedMore;
    }
    let payload = buf[4..4 + len].to_vec();
    buf.drain(..4 + len);
    FrameDecode::Frame(payload)
}

/// Client-side helper (tests, benches): write one framed request.
pub fn write_frame(w: &mut impl Write, payload: &str) -> Result<()> {
    w.write_all(&encode_frame(payload.as_bytes())?)?;
    w.flush()?;
    Ok(())
}

/// Client-side helper (tests, benches): read one framed reply.
pub fn read_frame(r: &mut impl Read) -> Result<String> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("reading frame header")?;
    let len = u32::from_be_bytes(hdr) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("peer declared a {len}-byte frame (cap {MAX_FRAME_BYTES})");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    String::from_utf8(payload).context("frame payload is not valid UTF-8")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_through_the_incremental_decoder() {
        let a = encode_frame(br#"{"op":"hello"}"#).unwrap();
        let b = encode_frame(br#"{"op":"stats"}"#).unwrap();
        // two frames, delivered byte-by-byte: decoder yields each
        // exactly once, in order
        let mut buf = Vec::new();
        let mut got = Vec::new();
        for byte in a.iter().chain(b.iter()) {
            buf.push(*byte);
            while let FrameDecode::Frame(p) = decode_frame(&mut buf) {
                got.push(String::from_utf8(p).unwrap());
            }
        }
        assert_eq!(got, vec![r#"{"op":"hello"}"#.to_string(), r#"{"op":"stats"}"#.to_string()]);
        assert!(buf.is_empty());
    }

    #[test]
    fn oversized_header_consumes_only_the_header() {
        let mut buf = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(b"xxxx");
        assert_eq!(decode_frame(&mut buf), FrameDecode::Oversized(MAX_FRAME_BYTES + 1));
        // the 4 garbage payload bytes are still there for the caller's
        // discard accounting
        assert_eq!(buf, b"xxxx");
        assert!(encode_frame(&vec![0u8; MAX_FRAME_BYTES + 1]).is_err());
    }

    #[test]
    fn client_helpers_roundtrip() {
        let mut wire = Vec::new();
        write_frame(&mut wire, r#"{"op":"hello"}"#).unwrap();
        let got = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!(got, r#"{"op":"hello"}"#);
    }

    #[test]
    fn error_codes_have_stable_names() {
        let names: Vec<&str> = ErrorCode::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(
            names,
            vec![
                "malformed",
                "oversized",
                "idle_timeout",
                "overloaded",
                "quarantined",
                "unsupported_op",
                "internal"
            ]
        );
        assert_eq!(ErrorCode::classify("run is quarantined (...)"), ErrorCode::Quarantined);
        assert_eq!(ErrorCode::classify("scheduler tick failed"), ErrorCode::Internal);
    }

    #[test]
    fn framed_errors_use_the_envelope() {
        let v = WireError::overloaded("rate_limited", 125).render(Transport::Framed);
        assert!(!v.get("ok").unwrap().bool().unwrap());
        let e = v.get("error").unwrap();
        assert_eq!(e.get_str("code").unwrap(), "overloaded");
        assert_eq!(e.get_str("reason").unwrap(), "rate_limited");
        assert_eq!(e.get_i64("retry_after_ms").unwrap(), 125);
        assert!(v.get("err").is_err(), "legacy key must not leak into framed mode");

        let v = WireError::new(ErrorCode::Malformed, "bad json").render(Transport::Framed);
        assert_eq!(v.get("error").unwrap().get_str("message").unwrap(), "bad json");
    }

    #[test]
    fn jsonl_errors_keep_the_legacy_shapes_plus_code() {
        // overload: the historical {"err":"overloaded",...} shape
        let v = WireError::overloaded("queue_full", 40).render(Transport::Jsonl);
        assert_eq!(v.get_str("err").unwrap(), "overloaded");
        assert_eq!(v.get_str("reason").unwrap(), "queue_full");
        assert_eq!(v.get_i64("retry_after_ms").unwrap(), 40);
        assert_eq!(v.get_str("code").unwrap(), "overloaded");

        // everything else: the historical flat {"error":<string>} —
        // and never an `err` key (clients key "back off" on it)
        let v = WireError::new(ErrorCode::Malformed, "parsing request: x").render(Transport::Jsonl);
        assert_eq!(v.get_str("error").unwrap(), "parsing request: x");
        assert_eq!(v.get_str("code").unwrap(), "malformed");
        assert!(v.get("err").is_err());
    }

    #[test]
    fn hello_advertises_version_and_features() {
        let v = hello_reply();
        assert!(v.get("ok").unwrap().bool().unwrap());
        assert_eq!(v.get_i64("proto").unwrap(), PROTO_VERSION);
        let feats: Vec<&str> =
            v.get("features").unwrap().arr().unwrap().iter().map(|f| f.str().unwrap()).collect();
        assert_eq!(feats, vec!["streaming", "framed"]);
    }
}
