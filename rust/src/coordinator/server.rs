//! TCP serving front-end: JSON-lines protocol over a router that feeds a
//! dedicated engine thread (PJRT wrapper types are not Send, and the
//! testbed is single-core, so one model-executor thread is the right
//! topology; the listener and connection handlers run on the pool).
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"solve", "expr":"(17+25)*3", "method":"ssr", "paths":5,
//!       "tau":7}
//!   <- {"ok":true, "answer":126, "method":"ssr-m5", "steps":9,
//!       "rewrites":2, "latency_s":0.41, "trace":"Q(17+25)*3;..."}
//!   -> {"op":"stats"}
//!   <- {"ok":true, "requests":..., "p50_s":..., ...}
//!   -> {"op":"shutdown"}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::engine::{Engine, Method};
use super::metrics::Metrics;
use crate::backend::Backend;
use crate::config::{SsrConfig, StopRule};
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;
use crate::workload::problems::problem_from_text;

/// A queued unit of work: one solve request and its reply slot.
pub struct WorkItem {
    pub expr: String,
    pub method: Method,
    pub seed: u64,
    pub reply: mpsc::Sender<Result<Value>>,
}

/// Parse the request's method field (mirrors `Method::name`).
pub fn parse_method(v: &Value, default_paths: usize, default_tau: u8) -> Result<Method> {
    let name = v.opt("method").map(|m| m.str()).transpose()?.unwrap_or("ssr");
    let n = v.opt("paths").map(|x| x.usize()).transpose()?.unwrap_or(default_paths);
    let tau = v.opt("tau").map(|x| x.i64()).transpose()?.unwrap_or(default_tau as i64) as u8;
    Ok(match name {
        "baseline" => Method::Baseline,
        "parallel" => Method::Parallel { n, spm: false },
        "parallel-spm" => Method::Parallel { n, spm: true },
        "spec-reason" => Method::SpecReason { tau },
        "ssr" => Method::Ssr { n, tau, stop: StopRule::Full },
        "ssr-fast1" => Method::Ssr { n, tau, stop: StopRule::Fast1 },
        "ssr-fast2" => Method::Ssr { n, tau, stop: StopRule::Fast2 },
        other => bail!("unknown method `{other}`"),
    })
}

/// The engine thread: owns the backend, drains the queue in arrival
/// order (FIFO scheduler), records metrics.
fn engine_loop(
    mut backend: Box<dyn Backend>,
    cfg: SsrConfig,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    vocab: crate::runtime::Vocab,
) {
    let mut seq = 0u64;
    while let Ok(item) = rx.recv() {
        let t0 = Instant::now();
        seq += 1;
        let result = (|| -> Result<Value> {
            let problem = problem_from_text(&vocab, &item.expr)?;
            let mut engine = Engine::new(backend.as_mut(), cfg.clone());
            let r = engine.run(&problem, item.method, item.seed ^ seq)?;
            let latency = t0.elapsed().as_secs_f64();
            {
                let mut m = metrics.lock().unwrap();
                m.record_request(latency, r.answer().is_some());
                m.record_tokens(r.draft_tokens, r.target_tokens, r.steps, r.rewrites);
            }
            Ok(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("answer", r.answer().map(json::i).unwrap_or(Value::Null)),
                ("gold", json::i(problem.answer)),
                ("correct", Value::Bool(r.answer() == Some(problem.answer))),
                ("method", json::s(item.method.name())),
                ("steps", json::i(r.steps as i64)),
                ("rewrites", json::i(r.rewrites as i64)),
                ("draft_tokens", json::i(r.draft_tokens as i64)),
                ("target_tokens", json::i(r.target_tokens as i64)),
                ("latency_s", json::n(latency)),
            ]))
        })();
        if result.is_err() {
            metrics.lock().unwrap().errors += 1;
        }
        let _ = item.reply.send(result);
    }
}

pub struct Server {
    pub addr: String,
    tx: mpsc::Sender<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    cfg: SsrConfig,
}

impl Server {
    /// Spawn the engine thread and bind the listener. `backend_factory`
    /// runs on the engine thread (PJRT types are not Send).
    pub fn start<F>(
        host: &str,
        port: u16,
        cfg: SsrConfig,
        vocab: crate::runtime::Vocab,
        backend_factory: F,
    ) -> Result<(Server, TcpListener)>
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let m2 = Arc::clone(&metrics);
        let cfg2 = cfg.clone();
        std::thread::Builder::new()
            .name("ssr-engine".into())
            .spawn(move || match backend_factory() {
                Ok(backend) => engine_loop(backend, cfg2, rx, m2, vocab),
                Err(e) => log::error!("backend init failed: {e:#}"),
            })
            .context("spawning engine thread")?;

        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("binding {host}:{port}"))?;
        let addr = listener.local_addr()?.to_string();
        log::info!("ssr server listening on {addr}");
        Ok((
            Server {
                addr,
                tx,
                metrics,
                started: Instant::now(),
                shutdown: Arc::new(AtomicBool::new(false)),
                cfg,
            },
            listener,
        ))
    }

    /// Accept-loop; blocks until a shutdown request arrives.
    pub fn serve(&self, listener: TcpListener, pool: &ThreadPool) -> Result<()> {
        listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::debug!("connection from {peer}");
                    let tx = self.tx.clone();
                    let metrics = Arc::clone(&self.metrics);
                    let started = self.started;
                    let shutdown = Arc::clone(&self.shutdown);
                    let cfg = self.cfg.clone();
                    pool.execute(move || {
                        if let Err(e) =
                            handle_conn(stream, tx, metrics, started, shutdown, cfg)
                        {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        pool.join();
        Ok(())
    }

    pub fn metrics(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    cfg: SsrConfig,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match process_line(&line, &tx, &metrics, started, &shutdown, &cfg) {
            Ok(v) => v,
            Err(e) => json::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", json::s(format!("{e:#}"))),
            ]),
        };
        out.write_all(reply.print().as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
        if shutdown.load(Ordering::Acquire) {
            return Ok(());
        }
    }
}

fn process_line(
    line: &str,
    tx: &mpsc::Sender<WorkItem>,
    metrics: &Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: &Arc<AtomicBool>,
    cfg: &SsrConfig,
) -> Result<Value> {
    let req = Value::parse(line).context("parsing request")?;
    match req.get_str("op")? {
        "solve" => {
            let expr = req.get_str("expr")?.to_string();
            let method = parse_method(&req, cfg.n_paths, cfg.tau)?;
            let seed = req.opt("seed").map(|s| s.i64()).transpose()?.unwrap_or(0) as u64;
            let (rtx, rrx) = mpsc::channel();
            tx.send(WorkItem { expr, method, seed, reply: rtx })
                .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
            rrx.recv().context("engine reply")??.pipe_ok()
        }
        "stats" => {
            let m = metrics.lock().unwrap();
            let mut v = m.summary_json(started.elapsed().as_secs_f64());
            if let Value::Obj(ref mut map) = v {
                map.insert("ok".into(), Value::Bool(true));
            }
            Ok(v)
        }
        "shutdown" => {
            shutdown.store(true, Ordering::Release);
            Ok(json::obj(vec![("ok", Value::Bool(true)), ("bye", Value::Bool(true))]))
        }
        other => bail!("unknown op `{other}`"),
    }
}

trait PipeOk {
    fn pipe_ok(self) -> Result<Value>;
}

impl PipeOk for Value {
    fn pipe_ok(self) -> Result<Value> {
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_method_variants() {
        let v = Value::parse(r#"{"op":"solve","method":"parallel-spm","paths":3}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::Parallel { n: 3, spm: true });
        let v = Value::parse(r#"{"op":"solve"}"#).unwrap();
        assert_eq!(
            parse_method(&v, 5, 7).unwrap(),
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }
        );
        let v = Value::parse(r#"{"op":"solve","method":"nope"}"#).unwrap();
        assert!(parse_method(&v, 5, 7).is_err());
    }

    #[test]
    fn parse_method_tau_override() {
        let v = Value::parse(r#"{"method":"spec-reason","tau":9}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::SpecReason { tau: 9 });
    }
}
