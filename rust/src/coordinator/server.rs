//! TCP serving front end: a single nonblocking event loop multiplexing
//! many concurrent connections over a versioned wire protocol
//! (PROTOCOL.md is the normative schema reference; DESIGN.md §16 the
//! design notes).
//!
//! Two transports carry the same JSON payloads (`--transport`):
//! newline-delimited JSON (`jsonl`, the compat default — one release of
//! legacy error shapes) and a 4-byte big-endian length-delimited framed
//! codec (`framed`, the structured error envelope). Ops: `hello`
//! (version/feature handshake), `solve` (optionally `"stream":true`),
//! `stats`, `add_shard`, `remove_shard`, `shutdown`.
//!
//! **Multiplexing.** A connection may have any number of `solve`s in
//! flight; each request may carry a client `request_id`, echoed on
//! every reply (and stamped onto every stream event), so replies can
//! return out of order. The old thread-per-connection handler blocked
//! in `rrx.recv()` inside the permit span; the event loop instead
//! registers a pending entry per submitted solve and polls its reply
//! channel, so one stalled solve never pins a thread or a connection.
//!
//! **Streaming.** `"stream":true` subscribes the connection to
//! `progress` events (step count, live gamma/spec_depth) and a
//! once-per-run `first_vote` early answer, followed by a terminal
//! `result` frame that is byte-identical to the blocking reply
//! (streaming observes runs, never steers them — the determinism
//! contract is untouched). Events ride bounded drop-oldest taps
//! (`--stream-buffer`; drops counted in `stream_drops`), so a slow
//! reader costs telemetry, never shard time; the terminal reply rides
//! the reply channel and is never dropped. A connection whose unsent
//! backlog passes a hard cap is disconnected (slow-consumer guard);
//! its admission permit is held until the run's terminal reply so
//! lanes stay accounted, then released with `stream_disconnects` /
//! `AdmissionController::note_disconnect` accounting.
//!
//! **Robustness.** Malformed, oversized (> 1 MiB), non-UTF-8 and
//! unknown-op requests are answered with structured errors and the
//! connection stays open; a panic while serving one request is caught
//! and answered the same way. A connection idle past
//! `--conn-idle-timeout-ms` (default 30s; 0 disables) with nothing in
//! flight gets an `idle_timeout` error and is closed. Overload
//! protection (DESIGN.md §14) runs at intake exactly as before:
//! `tenant`/`class` gates refuse with a structured `overloaded` reply
//! before a shed request costs any shard work.
//!
//! Serving stays deterministic: identical (expr, method, seed)
//! requests return identical answers regardless of arrival order,
//! shard placement, migration, or whether anyone was streaming
//! (DESIGN.md §10). `latency_s` is enqueue-to-reply; `queue_wait_s`
//! reported separately.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::admission::{AdmissionController, Permit, QosClass, RejectReason};
use super::autoscaler::Autoscaler;
use super::engine::Method;
use super::events::{EventTap, ReplySink};
use super::metrics::Metrics;
use super::pool::{BackendPool, PoolHandle};
use super::protocol::{self, ErrorCode, FrameDecode, WireError, MAX_FRAME_BYTES};
use super::scheduler::{lane_estimate, SolveRequest};
use crate::backend::Backend;
use crate::config::{SsrConfig, StopRule, Transport};
use crate::util::json::{self, Value};
use crate::util::sync::lock_ok;
use crate::util::threadpool::ThreadPool;
use crate::workload::trace::{TraceEntry, TraceWriter};

/// Event-loop idle sleep when no connection made progress.
const IDLE_SLEEP: Duration = Duration::from_micros(500);
/// Per-iteration read chunk.
const READ_CHUNK: usize = 16 * 1024;
/// Stop queueing stream events to a connection whose unsent backlog
/// passes this (events then age out in the tap's drop-oldest ring).
const OUT_SOFT_CAP: usize = 64 * 1024;
/// Disconnect a consumer whose unsent backlog passes this — it is not
/// reading at all, and unsent terminal replies must not grow unbounded.
const OUT_HARD_CAP: usize = 8 * 1024 * 1024;
/// Grace period for flushing remaining output after shutdown.
const SHUTDOWN_FLUSH: Duration = Duration::from_secs(1);

/// Parse the request's method field (mirrors `Method::name`). The
/// wire-supplied `paths` count is bounded like `SsrConfig::n_paths`
/// (1..=16) so a single request cannot open an unbounded lane group.
pub fn parse_method(v: &Value, default_paths: usize, default_tau: u8) -> Result<Method> {
    let name = v.opt("method").map(|m| m.str()).transpose()?.unwrap_or("ssr");
    let n = v.opt("paths").map(|x| x.usize()).transpose()?.unwrap_or(default_paths);
    let tau = v.opt("tau").map(|x| x.i64()).transpose()?.unwrap_or(default_tau as i64) as u8;
    let method = match name {
        "baseline" => Method::Baseline,
        "parallel" => Method::Parallel { n, spm: false },
        "parallel-spm" => Method::Parallel { n, spm: true },
        "spec-reason" => Method::SpecReason { tau },
        "ssr" => Method::Ssr { n, tau, stop: StopRule::Full },
        "ssr-fast1" => Method::Ssr { n, tau, stop: StopRule::Fast1 },
        "ssr-fast2" => Method::Ssr { n, tau, stop: StopRule::Fast2 },
        other => bail!("unknown method `{other}`"),
    };
    match method {
        Method::Parallel { n, .. } | Method::Ssr { n, .. } if n == 0 || n > 16 => {
            bail!("paths must be in 1..=16, got {n}")
        }
        _ => {}
    }
    Ok(method)
}

pub struct Server {
    pub addr: String,
    sched: PoolHandle,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    cfg: SsrConfig,
    /// intake gates (token buckets / class queues / lane quotas / SLO
    /// shed, DESIGN.md §14) — consulted before any job touches the pool
    admission: Arc<AdmissionController>,
    /// the policy loop when `--autoscale on`; stopped (and its pool
    /// handle released) when the server shuts down
    autoscaler: Option<Autoscaler>,
    /// serving-trace appender behind `--trace-record` (DESIGN.md §17):
    /// every ADMITTED solve is logged with its arrival offset so the
    /// workload can be replayed decision-for-decision offline
    trace: Option<Mutex<TraceWriter>>,
}

impl Server {
    /// Spawn the backend pool (`cfg.shards` scheduler threads) and bind
    /// the listener. `backend_factory(shard)` runs ON that shard's
    /// thread (PJRT types are not Send) — once per shard.
    pub fn start<F>(
        host: &str,
        port: u16,
        cfg: SsrConfig,
        vocab: crate::runtime::Vocab,
        backend_factory: F,
    ) -> Result<(Server, TcpListener)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (sched, _joins) =
            BackendPool::spawn(cfg.clone(), vocab, Arc::clone(&metrics), backend_factory)?;
        let autoscaler = cfg
            .autoscale
            .enabled
            .then(|| Autoscaler::spawn(sched.clone(), Arc::clone(&metrics), &cfg));
        // fair-share lane quotas are sized against the pool's nominal
        // lane capacity at start (autoscale growth only adds headroom)
        let lane_capacity = cfg.shards.max(1) * cfg.max_lanes.max(1);
        let admission = Arc::new(AdmissionController::new(cfg.qos.clone(), lane_capacity));

        // the trace log opens before the listener: an unwritable path
        // fails startup instead of silently recording nothing
        let trace = cfg
            .trace_record
            .as_ref()
            .map(|p| TraceWriter::create(p).map(Mutex::new))
            .transpose()
            .context("opening trace log")?;

        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("binding {host}:{port}"))?;
        let addr = listener.local_addr()?.to_string();
        log::info!(
            "ssr server listening on {addr} ({} shard(s), transport={}, autoscale={})",
            sched.shards(),
            cfg.transport.name(),
            cfg.autoscale.enabled
        );
        Ok((
            Server {
                addr,
                sched,
                metrics,
                started: Instant::now(),
                shutdown: Arc::new(AtomicBool::new(false)),
                cfg,
                admission,
                autoscaler,
                trace,
            },
            listener,
        ))
    }

    /// The front-end event loop; blocks until a shutdown request
    /// arrives and every in-flight request has replied. `pool` runs
    /// blocking admin work (`remove_shard` drains) off the loop.
    pub fn serve(&self, listener: TcpListener, pool: &ThreadPool) -> Result<()> {
        listener.set_nonblocking(true)?;
        let mut el = EventLoop {
            sched: &self.sched,
            metrics: &self.metrics,
            started: self.started,
            shutdown: &self.shutdown,
            cfg: &self.cfg,
            admission: &self.admission,
            trace: self.trace.as_ref(),
            conns: HashMap::new(),
            pendings: Vec::new(),
            next_conn: 0,
        };
        el.run(&listener, pool)?;
        pool.join();
        Ok(())
    }

    /// Stop the autoscaler loop (releases its pool handle). Called on
    /// shutdown; also runs on drop.
    pub fn stop_autoscaler(&mut self) {
        if let Some(mut a) = self.autoscaler.take() {
            a.stop();
        }
    }

    pub fn metrics(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }
}

/// One connection's buffers and framing state.
struct Conn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    out_pos: usize,
    last_activity: Instant,
    /// jsonl: discarding an oversized line up to its next newline
    discard_line: bool,
    /// framed: payload bytes of a declared-oversized frame to skip
    discard_bytes: usize,
    /// requests submitted and not yet terminally replied
    pending: usize,
    /// peer half-closed its write side; close once we finish replying
    eof: bool,
    /// reply queued that ends the connection (idle timeout / shutdown)
    close_after_flush: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            out_pos: 0,
            last_activity: Instant::now(),
            discard_line: false,
            discard_bytes: 0,
            pending: 0,
            eof: false,
            close_after_flush: false,
        }
    }

    fn backlog(&self) -> usize {
        self.outbuf.len() - self.out_pos
    }
}

/// One submitted request awaiting its terminal reply.
struct Pending {
    conn: u64,
    request_id: Option<Value>,
    rx: mpsc::Receiver<Result<Value>>,
    kind: PendingKind,
}

enum PendingKind {
    Solve {
        /// held until the terminal reply: the run occupies lanes until
        /// it retires whether or not anyone is still listening
        permit: Option<Permit>,
        tap: Option<EventTap>,
        stream: bool,
    },
    /// blocking admin op (remove_shard) running on the thread pool
    Admin,
}

/// What processing one request decided.
enum Action {
    Reply(Value),
    Solve {
        rx: mpsc::Receiver<Result<Value>>,
        permit: Permit,
        tap: Option<EventTap>,
        stream: bool,
    },
    Admin { rx: mpsc::Receiver<Result<Value>> },
    Shutdown(Value),
}

/// One decoded inbound message (or framing-layer defect) — produced by
/// the transport extractors, consumed by the dispatcher.
enum InMsg {
    Payload(String),
    BadUtf8,
    OversizedLine,
    OversizedFrame(usize),
}

/// Echo the client's `request_id` onto a reply object.
fn stamp_request_id(v: &mut Value, id: &Option<Value>) {
    if let (Some(id), Value::Obj(map)) = (id, v) {
        map.insert("request_id".into(), id.clone());
    }
}

struct EventLoop<'a> {
    sched: &'a PoolHandle,
    metrics: &'a Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: &'a Arc<AtomicBool>,
    cfg: &'a SsrConfig,
    admission: &'a Arc<AdmissionController>,
    trace: Option<&'a Mutex<TraceWriter>>,
    conns: HashMap<u64, Conn>,
    pendings: Vec<Pending>,
    next_conn: u64,
}

impl EventLoop<'_> {
    fn run(&mut self, listener: &TcpListener, pool: &ThreadPool) -> Result<()> {
        let mut flush_deadline: Option<Instant> = None;
        loop {
            let mut progress = false;
            let shutting_down = self.shutdown.load(Ordering::Acquire);

            // --- accept -----------------------------------------------
            if !shutting_down {
                loop {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::debug!("connection from {peer}");
                            stream.set_nonblocking(true)?;
                            let id = self.next_conn;
                            self.next_conn += 1;
                            self.conns.insert(id, Conn::new(stream));
                            progress = true;
                        }
                        Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) => return Err(e.into()),
                    }
                }
            }

            // --- read + dispatch --------------------------------------
            let ids: Vec<u64> = self.conns.keys().copied().collect();
            for id in ids {
                progress |= self.pump_conn(id, pool);
            }

            // --- poll pending replies ---------------------------------
            let mut k = 0;
            while k < self.pendings.len() {
                match self.pendings[k].rx.try_recv() {
                    Ok(result) => {
                        let p = self.pendings.swap_remove(k);
                        self.complete(p, result);
                        progress = true;
                    }
                    Err(mpsc::TryRecvError::Empty) => k += 1,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        // replier died without a terminal reply (pool
                        // torn down mid-request): still answer
                        let p = self.pendings.swap_remove(k);
                        self.complete(
                            p,
                            Err(anyhow::anyhow!("scheduler dropped the request")),
                        );
                        progress = true;
                    }
                }
            }

            // --- stream events -> output buffers ----------------------
            progress |= self.drain_taps();

            // --- flush + reap -----------------------------------------
            progress |= self.flush_and_reap();

            // --- idle timeouts ----------------------------------------
            self.fire_idle_timeouts();

            // --- shutdown drain ---------------------------------------
            if shutting_down && self.pendings.is_empty() {
                let flushed = self.conns.values().all(|c| c.backlog() == 0);
                match flush_deadline {
                    _ if flushed => return Ok(()),
                    None => flush_deadline = Some(Instant::now() + SHUTDOWN_FLUSH),
                    Some(d) if Instant::now() >= d => return Ok(()),
                    Some(_) => {}
                }
            }

            if !progress {
                std::thread::sleep(IDLE_SLEEP);
            }
        }
    }

    /// Read whatever the connection has, extract complete requests for
    /// the active transport, dispatch each. Returns true on progress.
    fn pump_conn(&mut self, id: u64, pool: &ThreadPool) -> bool {
        let mut progress = false;
        let mut dead = false;
        let transport = self.cfg.transport;
        let msgs = {
            let Some(conn) = self.conns.get_mut(&id) else { return false };
            if conn.close_after_flush {
                return false;
            }
            // bounded read: one oversized request is handled (discard
            // mode) before buffering more of it
            let mut chunk = [0u8; READ_CHUNK];
            while !conn.eof && conn.inbuf.len() <= MAX_FRAME_BYTES + 4 {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => conn.eof = true,
                    Ok(n) => {
                        conn.inbuf.extend_from_slice(&chunk[..n]);
                        conn.last_activity = Instant::now();
                        progress = true;
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => {
                        log::debug!("conn {id}: read error: {e}");
                        dead = true;
                        break;
                    }
                }
            }
            if dead {
                Vec::new()
            } else {
                match transport {
                    Transport::Jsonl => extract_jsonl(conn),
                    Transport::Framed => extract_framed(conn),
                }
            }
        };
        if dead {
            self.drop_conn(id);
            return true;
        }
        for msg in msgs {
            progress = true;
            self.dispatch(id, msg, pool);
        }
        progress
    }

    /// Handle one inbound message on connection `id`.
    fn dispatch(&mut self, id: u64, msg: InMsg, pool: &ThreadPool) {
        let transport = self.cfg.transport;
        let payload = match msg {
            InMsg::Payload(p) => p,
            InMsg::BadUtf8 => {
                let text = match transport {
                    Transport::Jsonl => "request line is not valid UTF-8",
                    Transport::Framed => "frame payload is not valid UTF-8",
                };
                let reply = WireError::new(ErrorCode::Malformed, text).render(transport);
                self.queue_reply(id, &reply);
                return;
            }
            InMsg::OversizedLine => {
                let reply = WireError::new(
                    ErrorCode::Oversized,
                    format!("request line exceeds {MAX_FRAME_BYTES} bytes"),
                )
                .render(transport);
                self.queue_reply(id, &reply);
                return;
            }
            InMsg::OversizedFrame(n) => {
                let reply = WireError::new(
                    ErrorCode::Oversized,
                    format!("frame of {n} bytes exceeds {MAX_FRAME_BYTES} bytes"),
                )
                .render(transport);
                self.queue_reply(id, &reply);
                return;
            }
        };
        let req = match Value::parse(&payload) {
            Ok(v) => v,
            Err(e) => {
                let reply = WireError::new(ErrorCode::Malformed, format!("parsing request: {e:#}"))
                    .render(transport);
                self.queue_reply(id, &reply);
                return;
            }
        };
        let request_id = req.opt("request_id").cloned();
        // a panic while serving one request must not kill the front end
        let action = match catch_unwind(AssertUnwindSafe(|| self.handle_op(&req, pool))) {
            Ok(Ok(a)) => a,
            Ok(Err(e)) => Action::Reply(
                WireError::new(ErrorCode::Malformed, format!("{e:#}")).render(transport),
            ),
            Err(_) => Action::Reply(
                WireError::new(ErrorCode::Internal, "internal error serving request")
                    .render(transport),
            ),
        };
        match action {
            Action::Reply(mut v) => {
                stamp_request_id(&mut v, &request_id);
                self.queue_reply(id, &v);
            }
            Action::Shutdown(mut v) => {
                stamp_request_id(&mut v, &request_id);
                self.queue_reply(id, &v);
                if let Some(c) = self.conns.get_mut(&id) {
                    c.close_after_flush = true;
                }
                self.shutdown.store(true, Ordering::Release);
            }
            Action::Solve { rx, permit, tap, stream } => {
                if let Some(c) = self.conns.get_mut(&id) {
                    c.pending += 1;
                }
                self.pendings.push(Pending {
                    conn: id,
                    request_id,
                    rx,
                    kind: PendingKind::Solve { permit: Some(permit), tap, stream },
                });
            }
            Action::Admin { rx } => {
                if let Some(c) = self.conns.get_mut(&id) {
                    c.pending += 1;
                }
                self.pendings.push(Pending { conn: id, request_id, rx, kind: PendingKind::Admin });
            }
        }
    }

    /// Dispatch one parsed request object — the op surface of
    /// PROTOCOL.md. Errors become `malformed` replies at the caller.
    fn handle_op(&self, req: &Value, pool: &ThreadPool) -> Result<Action> {
        let cfg = self.cfg;
        match req.get_str("op").context("request needs an `op`")? {
            "hello" => Ok(Action::Reply(protocol::hello_reply())),
            "solve" => {
                let expr = req.get_str("expr")?.to_string();
                let method = parse_method(req, cfg.n_paths, cfg.tau)?;
                let seed = req.opt("seed").map(|s| s.i64()).transpose()?.unwrap_or(0) as u64;
                let deadline_ms =
                    req.opt("deadline_ms").map(|x| x.i64()).transpose()?.unwrap_or(0).max(0)
                        as u64;
                // type errors here (numeric tenant, object class, ...)
                // are `malformed` replies, NOT `overloaded` — the
                // client sent a bad request, not excess load
                let tenant =
                    req.opt("tenant").map(|v| v.str()).transpose().context("`tenant` field")?;
                let class = req
                    .opt("class")
                    .map(|v| v.str())
                    .transpose()
                    .context("`class` field")?
                    .map(QosClass::parse)
                    .transpose()?
                    .unwrap_or_default();
                let stream = req
                    .opt("stream")
                    .map(|v| v.bool())
                    .transpose()
                    .context("`stream` field")?
                    .unwrap_or(false);
                // intake gates (DESIGN.md §14) — consulted BEFORE the
                // job touches the pool, so a shed costs no shard work
                let p99 = lock_ok(self.metrics).class_p99(QosClass::Interactive);
                let lanes = lane_estimate(method, cfg.pool_size);
                let permit = match self.admission.admit(tenant, class, lanes, p99) {
                    Ok(p) => p,
                    Err(rej) => {
                        lock_ok(self.metrics).record_reject(
                            tenant,
                            rej.reason == RejectReason::Shed,
                            rej.retry_after_ms,
                        );
                        return Ok(Action::Reply(
                            WireError::overloaded(rej.reason.name(), rej.retry_after_ms)
                                .render(cfg.transport),
                        ));
                    }
                };
                lock_ok(self.metrics).record_tenant_admit(tenant);
                // trace admitted requests only (rejects cost no shard
                // work and carry no replayable decision state). The
                // record keeps the RAW wire method fields — the exact
                // inputs `parse_method` read — so replay re-derives the
                // identical `Method` from the log alone.
                if let Some(tr) = self.trace {
                    let rec = TraceEntry {
                        offset_ms: self.started.elapsed().as_millis() as u64,
                        tenant: tenant.map(String::from),
                        expr: expr.clone(),
                        method: req
                            .opt("method")
                            .map(|m| m.str())
                            .transpose()?
                            .unwrap_or("ssr")
                            .to_string(),
                        paths: req
                            .opt("paths")
                            .map(|x| x.usize())
                            .transpose()?
                            .unwrap_or(cfg.n_paths),
                        tau: req
                            .opt("tau")
                            .map(|x| x.i64())
                            .transpose()?
                            .unwrap_or(cfg.tau as i64) as u8,
                        seed,
                        class: class.name().to_string(),
                        deadline_ms,
                    };
                    // best-effort: a full disk degrades to a truncated
                    // (still replayable) trace, never a failed solve
                    if let Err(e) = lock_ok(tr).record(&rec) {
                        log::warn!("trace record failed: {e:#}");
                    }
                }
                let request_id = req.opt("request_id").cloned();
                let tap = stream.then(|| EventTap::new(cfg.stream_buffer, request_id));
                let (rtx, rrx) = mpsc::channel();
                self.sched.submit(SolveRequest {
                    expr,
                    method,
                    seed,
                    deadline_ms,
                    class,
                    reply: ReplySink::with_events(rtx, tap.clone()),
                })?;
                if stream {
                    lock_ok(self.metrics).streams_active += 1;
                }
                Ok(Action::Solve { rx: rrx, permit, tap, stream })
            }
            "stats" => {
                let mut v = {
                    let mut m = lock_ok(self.metrics);
                    // the pool owns the live lock-free shape-hit
                    // counter (the submit hot path never takes this
                    // mutex); sync it into the snapshot
                    m.set_placement_shape_hits(self.sched.placement_shape_hits());
                    m.stream_disconnects = self.admission.disconnects();
                    m.summary_json(self.started.elapsed().as_secs_f64())
                };
                if let Value::Obj(ref mut map) = v {
                    map.insert("ok".into(), Value::Bool(true));
                    map.insert("proto".into(), json::i(protocol::PROTO_VERSION));
                    map.insert("shards_live".into(), json::i(self.sched.shards() as i64));
                }
                Ok(Action::Reply(v))
            }
            "add_shard" => {
                let id = self.sched.add_shard()?;
                log::info!("hot-added shard {id} ({} live)", self.sched.shards());
                Ok(Action::Reply(json::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("shard", json::i(id as i64)),
                    ("shards_live", json::i(self.sched.shards() as i64)),
                ])))
            }
            "remove_shard" => {
                let id = req.get("shard").context("remove_shard needs a `shard` id")?.usize()?;
                // draining a shard blocks until its in-flight runs are
                // re-homed or finished: run it on the thread pool so
                // every other connection keeps being served meanwhile
                let sched = self.sched.clone();
                let (rtx, rrx) = mpsc::channel();
                pool.execute(move || {
                    let result = sched.remove_shard(id).map(|drain_s| {
                        log::info!(
                            "drained shard {id} in {drain_s:.3}s ({} live)",
                            sched.shards()
                        );
                        json::obj(vec![
                            ("ok", Value::Bool(true)),
                            ("drained", json::i(id as i64)),
                            ("drain_s", json::n(drain_s)),
                            ("shards_live", json::i(sched.shards() as i64)),
                        ])
                    });
                    let _ = rtx.send(result);
                });
                Ok(Action::Admin { rx: rrx })
            }
            "shutdown" => Ok(Action::Shutdown(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("bye", Value::Bool(true)),
            ]))),
            other => Ok(Action::Reply(
                WireError::new(ErrorCode::UnsupportedOp, format!("unknown op `{other}`"))
                    .render(cfg.transport),
            )),
        }
    }

    /// A pending request reached its terminal reply.
    fn complete(&mut self, p: Pending, result: Result<Value>) {
        let alive = self.conns.contains_key(&p.conn);
        if let PendingKind::Solve { permit, tap, stream } = p.kind {
            // flush any still-queued events BEFORE the terminal frame
            // (the scheduler pushed them before replying, so ordering
            // holds end to end)
            if alive {
                if let Some(tap) = &tap {
                    for ev in tap.drain() {
                        self.queue_reply(p.conn, &ev);
                    }
                }
            }
            if stream {
                let mut m = lock_ok(self.metrics);
                m.streams_active = m.streams_active.saturating_sub(1);
            }
            if !alive {
                // requester vanished mid-solve: the run still ran to
                // its terminal reply (lanes were occupied throughout),
                // so the permit releases only now — with accounting
                self.admission.note_disconnect();
            }
            drop(permit);
        }
        if alive {
            let transport = self.cfg.transport;
            let mut reply = match result {
                Ok(v) => v,
                Err(e) => WireError::from_scheduler(&e).render(transport),
            };
            stamp_request_id(&mut reply, &p.request_id);
            self.queue_reply(p.conn, &reply);
        }
        if let Some(c) = self.conns.get_mut(&p.conn) {
            c.pending = c.pending.saturating_sub(1);
        }
    }

    /// Move queued stream events into connection output buffers —
    /// unless the connection is already backlogged past the soft cap,
    /// in which case events keep aging out in their bounded taps
    /// (drop-oldest) instead of growing the buffer.
    fn drain_taps(&mut self) -> bool {
        let mut queued: Vec<(u64, Value)> = Vec::new();
        for p in &self.pendings {
            let PendingKind::Solve { tap: Some(tap), .. } = &p.kind else { continue };
            let Some(conn) = self.conns.get(&p.conn) else { continue };
            if conn.backlog() > OUT_SOFT_CAP {
                continue;
            }
            for ev in tap.drain() {
                queued.push((p.conn, ev));
            }
        }
        let progress = !queued.is_empty();
        for (id, ev) in queued {
            self.queue_reply(id, &ev);
        }
        progress
    }

    /// Write what we can, then reap connections that are finished
    /// (EOF/close-after-flush with nothing left to say), dead (write
    /// error) or hopeless (backlog past the hard cap).
    fn flush_and_reap(&mut self) -> bool {
        let mut progress = false;
        let mut dead: Vec<u64> = Vec::new();
        for (&id, conn) in self.conns.iter_mut() {
            while conn.backlog() > 0 {
                match conn.stream.write(&conn.outbuf[conn.out_pos..]) {
                    Ok(0) => {
                        dead.push(id);
                        break;
                    }
                    Ok(n) => {
                        conn.out_pos += n;
                        progress = true;
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => {
                        log::debug!("conn {id}: write error: {e}");
                        dead.push(id);
                        break;
                    }
                }
            }
            if conn.backlog() == 0 {
                conn.outbuf.clear();
                conn.out_pos = 0;
            } else if conn.out_pos > OUT_SOFT_CAP {
                conn.outbuf.drain(..conn.out_pos);
                conn.out_pos = 0;
            }
            if conn.backlog() > OUT_HARD_CAP {
                log::warn!(
                    "conn {id}: slow consumer ({} bytes unsent), disconnecting",
                    conn.backlog()
                );
                dead.push(id);
            } else if conn.backlog() == 0
                && (conn.close_after_flush || (conn.eof && conn.pending == 0))
            {
                dead.push(id);
            }
        }
        for id in dead {
            progress = true;
            self.drop_conn(id);
        }
        progress
    }

    /// Close idle connections (no bytes, nothing in flight) past the
    /// configured timeout, with a structured goodbye.
    fn fire_idle_timeouts(&mut self) {
        if self.cfg.conn_idle_timeout_ms == 0 {
            return;
        }
        let limit = Duration::from_millis(self.cfg.conn_idle_timeout_ms);
        let transport = self.cfg.transport;
        let mut fired: Vec<u64> = Vec::new();
        for (&id, conn) in self.conns.iter() {
            if conn.pending == 0
                && !conn.close_after_flush
                && conn.backlog() == 0
                && conn.last_activity.elapsed() >= limit
            {
                fired.push(id);
            }
        }
        for id in fired {
            let reply = WireError::new(
                ErrorCode::IdleTimeout,
                format!("idle timeout after {}ms", self.cfg.conn_idle_timeout_ms),
            )
            .render(transport);
            self.queue_reply(id, &reply);
            if let Some(c) = self.conns.get_mut(&id) {
                c.close_after_flush = true;
            }
        }
    }

    /// Serialize one reply/event for the active transport onto a
    /// connection's output buffer.
    fn queue_reply(&mut self, id: u64, reply: &Value) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        let text = reply.print();
        match self.cfg.transport {
            Transport::Jsonl => {
                conn.outbuf.extend_from_slice(text.as_bytes());
                conn.outbuf.push(b'\n');
            }
            Transport::Framed => match protocol::encode_frame(text.as_bytes()) {
                Ok(frame) => conn.outbuf.extend_from_slice(&frame),
                Err(e) => log::error!("conn {id}: unencodable reply dropped: {e:#}"),
            },
        }
        conn.last_activity = Instant::now();
    }

    /// Remove a connection. Its pending requests stay registered: their
    /// permits release (with disconnect accounting) when each terminal
    /// reply arrives, because the runs occupy lanes until then.
    fn drop_conn(&mut self, id: u64) {
        self.conns.remove(&id);
    }
}

/// Extract complete JSON-lines requests from a connection's read
/// buffer, honoring oversized-line discard mode.
fn extract_jsonl(conn: &mut Conn) -> Vec<InMsg> {
    let mut out = Vec::new();
    loop {
        if conn.discard_line {
            match conn.inbuf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    conn.inbuf.drain(..=pos);
                    conn.discard_line = false;
                }
                None => {
                    conn.inbuf.clear();
                    return out;
                }
            }
        }
        match conn.inbuf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                let line: Vec<u8> = conn.inbuf.drain(..=pos).collect();
                match std::str::from_utf8(&line) {
                    Ok(s) if s.trim().is_empty() => {}
                    Ok(s) => out.push(InMsg::Payload(s.trim().to_string())),
                    Err(_) => out.push(InMsg::BadUtf8),
                }
            }
            None if conn.inbuf.len() >= MAX_FRAME_BYTES => {
                // line too long to ever complete within the cap:
                // answer now, discard through its eventual newline
                conn.inbuf.clear();
                conn.discard_line = true;
                out.push(InMsg::OversizedLine);
            }
            None => return out,
        }
    }
}

/// Extract complete framed requests from a connection's read buffer,
/// honoring declared-oversized skip mode.
fn extract_framed(conn: &mut Conn) -> Vec<InMsg> {
    let mut out = Vec::new();
    loop {
        if conn.discard_bytes > 0 {
            let k = conn.discard_bytes.min(conn.inbuf.len());
            conn.inbuf.drain(..k);
            conn.discard_bytes -= k;
            if conn.discard_bytes > 0 {
                return out;
            }
        }
        match protocol::decode_frame(&mut conn.inbuf) {
            FrameDecode::NeedMore => return out,
            FrameDecode::Oversized(n) => {
                conn.discard_bytes = n;
                out.push(InMsg::OversizedFrame(n));
            }
            FrameDecode::Frame(p) => match String::from_utf8(p) {
                Ok(s) if s.trim().is_empty() => {}
                Ok(s) => out.push(InMsg::Payload(s)),
                Err(_) => out.push(InMsg::BadUtf8),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_method_variants() {
        let v = Value::parse(r#"{"op":"solve","method":"parallel-spm","paths":3}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::Parallel { n: 3, spm: true });
        let v = Value::parse(r#"{"op":"solve"}"#).unwrap();
        assert_eq!(
            parse_method(&v, 5, 7).unwrap(),
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }
        );
        let v = Value::parse(r#"{"op":"solve","method":"nope"}"#).unwrap();
        assert!(parse_method(&v, 5, 7).is_err());
    }

    #[test]
    fn parse_method_tau_override() {
        let v = Value::parse(r#"{"method":"spec-reason","tau":9}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::SpecReason { tau: 9 });
    }

    #[test]
    fn parse_method_bounds_wire_paths() {
        for bad in [r#"{"method":"parallel","paths":100000000}"#, r#"{"method":"ssr","paths":0}"#]
        {
            let v = Value::parse(bad).unwrap();
            assert!(parse_method(&v, 5, 7).is_err(), "accepted {bad}");
        }
        let v = Value::parse(r#"{"method":"parallel","paths":16}"#).unwrap();
        assert!(parse_method(&v, 5, 7).is_ok());
    }

    #[test]
    fn request_id_stamping() {
        let mut v = json::obj(vec![("ok", Value::Bool(true))]);
        stamp_request_id(&mut v, &Some(json::s("r1")));
        assert_eq!(v.get_str("request_id").unwrap(), "r1");
        let mut v = json::obj(vec![("ok", Value::Bool(true))]);
        stamp_request_id(&mut v, &None);
        assert!(v.get("request_id").is_err());
    }

    fn test_conn() -> Conn {
        // a socket pair just for the struct; framing helpers only touch
        // the buffers
        let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let s = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        Conn::new(s)
    }

    #[test]
    fn jsonl_extractor_handles_split_lines_and_oversize() {
        let mut c = test_conn();
        c.inbuf.extend_from_slice(b"{\"op\":\"hello\"}\n{\"op\":");
        let got = extract_jsonl(&mut c);
        assert_eq!(got.len(), 1);
        assert!(matches!(&got[0], InMsg::Payload(p) if p == "{\"op\":\"hello\"}"));
        // the partial line stays buffered until its newline arrives
        c.inbuf.extend_from_slice(b"\"stats\"}\n");
        let got = extract_jsonl(&mut c);
        assert!(matches!(&got[0], InMsg::Payload(p) if p == "{\"op\":\"stats\"}"));

        // oversized: answered once, then discarded through the newline
        c.inbuf = vec![b'x'; MAX_FRAME_BYTES + 10];
        let got = extract_jsonl(&mut c);
        assert!(matches!(got[0], InMsg::OversizedLine));
        assert!(c.discard_line);
        c.inbuf.extend_from_slice(b"tail\n{\"op\":\"hello\"}\n");
        let got = extract_jsonl(&mut c);
        assert_eq!(got.len(), 1, "the oversized tail is skipped, the next line parses");
        assert!(matches!(&got[0], InMsg::Payload(p) if p == "{\"op\":\"hello\"}"));
    }

    #[test]
    fn framed_extractor_skips_declared_oversize() {
        let mut c = test_conn();
        c.inbuf.extend_from_slice(&((MAX_FRAME_BYTES + 5) as u32).to_be_bytes());
        let got = extract_framed(&mut c);
        assert!(matches!(got[0], InMsg::OversizedFrame(n) if n == MAX_FRAME_BYTES + 5));
        // payload arrives in chunks and is skipped without buffering
        c.inbuf = vec![0u8; MAX_FRAME_BYTES];
        assert!(extract_framed(&mut c).is_empty());
        c.inbuf.extend_from_slice(&[0u8; 5]);
        c.inbuf.extend_from_slice(&protocol::encode_frame(b"{\"op\":\"hello\"}").unwrap());
        let got = extract_framed(&mut c);
        assert_eq!(got.len(), 1);
        assert!(matches!(&got[0], InMsg::Payload(p) if p == "{\"op\":\"hello\"}"));
        assert_eq!(c.discard_bytes, 0);
    }
}
