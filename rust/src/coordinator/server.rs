//! TCP serving front-end: JSON-lines protocol over a router that feeds
//! the sharded backend pool (PJRT wrapper types are not Send, so each
//! model-executor thread owns its shard's backend; the listener and
//! connection handlers run on the thread pool and submit work items
//! that the placement policy routes to a shard and each shard's
//! scheduler multiplexes into shared step batches — see
//! `coordinator::pool` and `coordinator::scheduler` for the design
//! notes). `--shards N` scales throughput with backend count;
//! `{"op":"stats"}` adds `shards`, `shard_requests`,
//! `model_secs_makespan` and `prefix_shard_fills` gauges.
//!
//! Protocol (one JSON object per line):
//!   -> {"op":"solve", "expr":"(17+25)*3", "method":"ssr", "paths":5,
//!       "tau":7}       // optional: "seed", "deadline_ms",
//!                      //           "tenant", "class"
//!   <- {"ok":true, "degraded":false, "answer":126, "method":"ssr-m5",
//!       "steps":9, "rewrites":2, "latency_s":0.41, "queue_wait_s":0.02,
//!       "gamma":0.81,        // measured acceptance rate (null when the
//!                            // method never speculated, e.g. baseline)
//!       "spec_depth":1,      // final controller depth (DESIGN.md §15)
//!       "target_only":false} // gamma collapsed -> draft retired
//!   <- {"ok":false, "err":"overloaded", "reason":"rate_limited",
//!       "retry_after_ms":125}         // intake shed (DESIGN.md §14)
//!   -> {"op":"stats"}
//!   <- {"ok":true, "requests":..., "p50_s":..., "p99_s":...,
//!       "throughput_rps":..., "backend_calls":...,
//!       "mean_batch_occupancy":...,   // lanes per backend step call
//!       "queue_depth_mean":..., "queue_depth_max":...,
//!       "admission_wait_mean_s":..., "admission_wait_p99_s":...,
//!       "prefix_hits":..., "prefix_misses":...,   // prefix-reuse cache
//!       "prefix_evictions":..., "prefix_hit_rate":...,
//!       "steals":..., "shards_added":..., "shards_removed":...,
//!       "drain_mean_s":..., "drain_max_s":...,    // shard lifecycle
//!       "shards_live":...,
//!       "shard_crashes":..., "runs_recovered":...,  // fault tolerance
//!       "runs_replayed":..., "retries":..., "quarantined":...,
//!       "quarantine_evictions":...,
//!       "deadline_expirations":..., "degraded_replies":...,
//!       "rejected":..., "shed":...,   // overload protection (§14)
//!       "retry_after_hints":..., "retry_after_hint_mean_ms":...,
//!       "class_requests":[...],       // [interactive, batch, best_effort]
//!       "interactive_p50_s":..., "interactive_p99_s":...,
//!       "batch_p50_s":..., "batch_p99_s":...,
//!       "best_effort_p50_s":..., "best_effort_p99_s":...,
//!       "tenant_requests":{...}, "tenant_rejected":{...},
//!       "model_secs":...,             // backend model-clock
//!       "model_secs_draft":..., "model_secs_target":...,  // §15 split
//!       "gamma_overall":...,          // pooled acceptance rate
//!       "gamma_draft_heavy":..., "gamma_balanced":...,
//!       "gamma_target_heavy":...,     // per shard class
//!       "spec_depth_mean":..., "spec_depth_hist":[...],
//!       "target_only_runs":...,
//!       "gamma_migrations":...,       // class rebalance moves
//!       "placement_shape_hits":...}   // batch-shape tie-breaks
//!   -> {"op":"add_shard"}             // hot-add one backend shard
//!   <- {"ok":true, "shard":2, "shards_live":3}
//!   -> {"op":"remove_shard", "shard":2}   // drain + remove at runtime
//!   <- {"ok":true, "drained":2, "drain_s":0.18, "shards_live":2}
//!   -> {"op":"shutdown"}
//!
//! **Overload protection (DESIGN.md §14).** A `solve` may carry a
//! `tenant` (any string; rate-limit identity) and a `class`
//! (`interactive` | `batch` | `best_effort`, default `interactive`).
//! Intake passes four gates — SLO shed, the tenant's token bucket,
//! the class's bounded queue, the tenant's fair-share lane quota —
//! before the job touches the pool; a gate failure is answered
//! immediately with the structured `overloaded` reply above, and the
//! connection stays open. Class affects dequeue order and shed/steal
//! preference only, NEVER run decisions (the determinism contract).
//! In-flight work is never dropped by overload — only new intake.
//!
//! **Slow-loris guard.** A connection that stays silent mid-line for
//! `--conn-idle-timeout-ms` (default 30s; 0 disables) gets a
//! structured `{"ok":false,"error":"idle timeout..."}` reply and is
//! closed, so stalled sockets cannot pin handler threads.
//!
//! With `--autoscale on` a policy loop (`coordinator::autoscaler`)
//! drives add/remove automatically from queue-depth and admission-wait
//! EWMAs within `[--min-shards, --max-shards]`; its decisions surface
//! as `scale_ups`/`scale_downs` in `{"op":"stats"}`, and live run
//! migration (`--migrate`, default on) keeps its scale-down drains
//! O(one step) (`migrations`/`migration_bytes` gauges).
//!
//! `latency_s` is enqueue-to-reply (it includes queue wait, reported
//! separately as `queue_wait_s`). Concurrent `solve` requests from any
//! number of connections interleave at step granularity and share
//! backend batches.
//!
//! Serving is deterministic: identical (expr, method, seed) requests
//! return identical answers regardless of arrival order or shard
//! placement (DESIGN.md §10). Independent resamples of one problem
//! (pass@k) must therefore vary the wire `seed` field — repeats with
//! one seed are replays, not fresh samples.
//!
//! Fault tolerance (DESIGN.md §13): a `solve` may carry `deadline_ms`
//! (overriding `--deadline-ms`; 0 = none). On expiry the run is
//! finalized from the votes accumulated so far and the reply carries
//! `"degraded":true` — still `"ok":true`. Shard crashes are recovered
//! transparently (re-admission on survivors); a run that crashes more
//! than `--recover-retries` shards is quarantined and answered with
//! `"ok":false`. The connection handler never drops the line protocol
//! on bad input: a malformed or oversized (> 1 MiB) request line gets
//! an `{"ok":false,"error":...}` reply and the connection stays open,
//! and a panic while serving one request is caught and answered the
//! same way rather than killing the handler thread.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::admission::{AdmissionController, QosClass, Reject, RejectReason};
use super::autoscaler::Autoscaler;
use super::engine::Method;
use super::metrics::Metrics;
use super::pool::{BackendPool, PoolHandle};
use super::scheduler::{lane_estimate, SolveRequest};
use crate::backend::Backend;
use crate::config::{SsrConfig, StopRule};
use crate::util::json::{self, Value};
use crate::util::sync::lock_ok;
use crate::util::threadpool::ThreadPool;

/// Hard cap on one request line; anything longer is drained and
/// answered with an error instead of buffering without bound.
const MAX_LINE_BYTES: u64 = 1 << 20;

/// Parse the request's method field (mirrors `Method::name`). The
/// wire-supplied `paths` count is bounded like `SsrConfig::n_paths`
/// (1..=16) so a single request cannot open an unbounded lane group.
pub fn parse_method(v: &Value, default_paths: usize, default_tau: u8) -> Result<Method> {
    let name = v.opt("method").map(|m| m.str()).transpose()?.unwrap_or("ssr");
    let n = v.opt("paths").map(|x| x.usize()).transpose()?.unwrap_or(default_paths);
    let tau = v.opt("tau").map(|x| x.i64()).transpose()?.unwrap_or(default_tau as i64) as u8;
    let method = match name {
        "baseline" => Method::Baseline,
        "parallel" => Method::Parallel { n, spm: false },
        "parallel-spm" => Method::Parallel { n, spm: true },
        "spec-reason" => Method::SpecReason { tau },
        "ssr" => Method::Ssr { n, tau, stop: StopRule::Full },
        "ssr-fast1" => Method::Ssr { n, tau, stop: StopRule::Fast1 },
        "ssr-fast2" => Method::Ssr { n, tau, stop: StopRule::Fast2 },
        other => bail!("unknown method `{other}`"),
    };
    match method {
        Method::Parallel { n, .. } | Method::Ssr { n, .. } if n == 0 || n > 16 => {
            bail!("paths must be in 1..=16, got {n}")
        }
        _ => {}
    }
    Ok(method)
}

pub struct Server {
    pub addr: String,
    sched: PoolHandle,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    cfg: SsrConfig,
    /// intake gates (token buckets / class queues / lane quotas / SLO
    /// shed, DESIGN.md §14) — consulted before any job touches the pool
    admission: Arc<AdmissionController>,
    /// the policy loop when `--autoscale on`; stopped (and its pool
    /// handle released) when the server shuts down
    autoscaler: Option<Autoscaler>,
}

impl Server {
    /// Spawn the backend pool (`cfg.shards` scheduler threads) and bind
    /// the listener. `backend_factory(shard)` runs ON that shard's
    /// thread (PJRT types are not Send) — once per shard.
    pub fn start<F>(
        host: &str,
        port: u16,
        cfg: SsrConfig,
        vocab: crate::runtime::Vocab,
        backend_factory: F,
    ) -> Result<(Server, TcpListener)>
    where
        F: Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (sched, _joins) =
            BackendPool::spawn(cfg.clone(), vocab, Arc::clone(&metrics), backend_factory)?;
        let autoscaler = cfg
            .autoscale
            .enabled
            .then(|| Autoscaler::spawn(sched.clone(), Arc::clone(&metrics), &cfg));
        // fair-share lane quotas are sized against the pool's nominal
        // lane capacity at start (autoscale growth only adds headroom)
        let lane_capacity = cfg.shards.max(1) * cfg.max_lanes.max(1);
        let admission = Arc::new(AdmissionController::new(cfg.qos.clone(), lane_capacity));

        let listener =
            TcpListener::bind((host, port)).with_context(|| format!("binding {host}:{port}"))?;
        let addr = listener.local_addr()?.to_string();
        log::info!(
            "ssr server listening on {addr} ({} shard(s), autoscale={})",
            sched.shards(),
            cfg.autoscale.enabled
        );
        Ok((
            Server {
                addr,
                sched,
                metrics,
                started: Instant::now(),
                shutdown: Arc::new(AtomicBool::new(false)),
                cfg,
                admission,
                autoscaler,
            },
            listener,
        ))
    }

    /// Accept-loop; blocks until a shutdown request arrives.
    pub fn serve(&self, listener: TcpListener, pool: &ThreadPool) -> Result<()> {
        listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::debug!("connection from {peer}");
                    let sched = self.sched.clone();
                    let metrics = Arc::clone(&self.metrics);
                    let started = self.started;
                    let shutdown = Arc::clone(&self.shutdown);
                    let cfg = self.cfg.clone();
                    let admission = Arc::clone(&self.admission);
                    pool.execute(move || {
                        if let Err(e) = handle_conn(
                            stream, sched, metrics, started, shutdown, cfg, admission,
                        ) {
                            log::warn!("connection error: {e:#}");
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        pool.join();
        Ok(())
    }

    /// Stop the autoscaler loop (releases its pool handle). Called on
    /// shutdown; also runs on drop.
    pub fn stop_autoscaler(&mut self) {
        if let Some(mut a) = self.autoscaler.take() {
            a.stop();
        }
    }

    pub fn metrics(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }
}

fn handle_conn(
    stream: TcpStream,
    sched: PoolHandle,
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    cfg: SsrConfig,
    admission: Arc<AdmissionController>,
) -> Result<()> {
    // slow-loris guard: a peer that stalls mid-line for the idle
    // timeout gets a structured reply and the socket is closed, so a
    // handful of dribbling connections cannot pin every handler thread
    if cfg.conn_idle_timeout_ms > 0 {
        stream.set_read_timeout(Some(Duration::from_millis(cfg.conn_idle_timeout_ms)))?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        // bounded read: a line that never ends cannot grow the buffer
        // past MAX_LINE_BYTES (the remainder is discarded below)
        let n = match reader.by_ref().take(MAX_LINE_BYTES).read_line(&mut line) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // non-UTF-8 bytes: the offending line was consumed, so
                // answer and keep serving
                write_reply(&mut out, &error_reply("request line is not valid UTF-8"))?;
                continue;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle timeout expired (mid-line or between requests):
                // best-effort structured goodbye, then close
                let _ = write_reply(
                    &mut out,
                    &error_reply(format!(
                        "idle timeout after {}ms",
                        cfg.conn_idle_timeout_ms
                    )),
                );
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            return Ok(()); // client closed
        }
        if n as u64 == MAX_LINE_BYTES && !line.ends_with('\n') {
            let eof = !drain_line(&mut reader)?;
            write_reply(
                &mut out,
                &error_reply(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
            )?;
            if eof {
                return Ok(());
            }
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        // a panic while serving one request must not kill the handler
        // thread (and with it every queued line on this connection)
        let reply = match catch_unwind(AssertUnwindSafe(|| {
            process_line(&line, &sched, &metrics, started, &shutdown, &cfg, &admission)
        })) {
            Ok(Ok(v)) => v,
            Ok(Err(e)) => error_reply(format!("{e:#}")),
            Err(_) => error_reply("internal error serving request"),
        };
        write_reply(&mut out, &reply)?;
        if shutdown.load(Ordering::Acquire) {
            return Ok(());
        }
    }
}

fn error_reply(msg: impl std::fmt::Display) -> Value {
    json::obj(vec![("ok", Value::Bool(false)), ("error", json::s(msg.to_string()))])
}

/// The structured intake-shed reply (DESIGN.md §14): `err` (not
/// `error`) distinguishes "back off and retry" from a malformed
/// request, and `retry_after_ms` tells the client when.
fn overloaded_reply(rej: &Reject) -> Value {
    json::obj(vec![
        ("ok", Value::Bool(false)),
        ("err", json::s("overloaded")),
        ("reason", json::s(rej.reason.name())),
        ("retry_after_ms", json::i(rej.retry_after_ms as i64)),
    ])
}

fn write_reply(out: &mut TcpStream, reply: &Value) -> Result<()> {
    out.write_all(reply.print().as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()?;
    Ok(())
}

/// Discard bytes up to and including the next newline; `false` on EOF.
fn drain_line(reader: &mut impl BufRead) -> std::io::Result<bool> {
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(false);
        }
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            reader.consume(pos + 1);
            return Ok(true);
        }
        let n = buf.len();
        reader.consume(n);
    }
}

fn process_line(
    line: &str,
    sched: &PoolHandle,
    metrics: &Arc<Mutex<Metrics>>,
    started: Instant,
    shutdown: &Arc<AtomicBool>,
    cfg: &SsrConfig,
    admission: &AdmissionController,
) -> Result<Value> {
    let req = Value::parse(line).context("parsing request")?;
    match req.get_str("op")? {
        "solve" => {
            let expr = req.get_str("expr")?.to_string();
            let method = parse_method(&req, cfg.n_paths, cfg.tau)?;
            let seed = req.opt("seed").map(|s| s.i64()).transpose()?.unwrap_or(0) as u64;
            let deadline_ms =
                req.opt("deadline_ms").map(|x| x.i64()).transpose()?.unwrap_or(0).max(0) as u64;
            // type errors here (numeric tenant, object class, ...) are
            // plain `error` replies, NOT `overloaded` — the client sent
            // a malformed request, not excess load
            let tenant =
                req.opt("tenant").map(|v| v.str()).transpose().context("`tenant` field")?;
            let class = req
                .opt("class")
                .map(|v| v.str())
                .transpose()
                .context("`class` field")?
                .map(QosClass::parse)
                .transpose()?
                .unwrap_or_default();
            // intake gates (DESIGN.md §14) — consulted BEFORE the job
            // touches the pool, so a shed request costs no shard work
            let p99 = lock_ok(metrics).class_p99(QosClass::Interactive);
            let lanes = lane_estimate(method, cfg.pool_size);
            let permit = match admission.admit(tenant, class, lanes, p99) {
                Ok(p) => p,
                Err(rej) => {
                    lock_ok(metrics).record_reject(
                        tenant,
                        rej.reason == RejectReason::Shed,
                        rej.retry_after_ms,
                    );
                    return Ok(overloaded_reply(&rej));
                }
            };
            lock_ok(metrics).record_tenant_admit(tenant);
            let (rtx, rrx) = mpsc::channel();
            sched.submit(SolveRequest { expr, method, seed, deadline_ms, class, reply: rtx })?;
            let reply = rrx.recv().context("scheduler reply")?;
            // the permit spans submit -> terminal reply: its Drop frees
            // the class slot + tenant lanes and feeds the per-class
            // drain-rate EWMA that prices queue-full retry hints
            drop(permit);
            reply
        }
        "stats" => {
            let mut v = {
                let mut m = lock_ok(metrics);
                // the pool owns the live lock-free shape-hit counter
                // (the submit hot path never takes this mutex); sync it
                // into the snapshot the summary renders
                m.set_placement_shape_hits(sched.placement_shape_hits());
                m.summary_json(started.elapsed().as_secs_f64())
            };
            if let Value::Obj(ref mut map) = v {
                map.insert("ok".into(), Value::Bool(true));
                map.insert("shards_live".into(), json::i(sched.shards() as i64));
            }
            Ok(v)
        }
        "add_shard" => {
            let id = sched.add_shard()?;
            log::info!("hot-added shard {id} ({} live)", sched.shards());
            Ok(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("shard", json::i(id as i64)),
                ("shards_live", json::i(sched.shards() as i64)),
            ]))
        }
        "remove_shard" => {
            let id = req.get("shard").context("remove_shard needs a `shard` id")?.usize()?;
            // blocks this connection handler until the shard has
            // finished its in-flight runs; other connections keep
            // solving on the surviving shards meanwhile
            let drain_s = sched.remove_shard(id)?;
            log::info!("drained shard {id} in {drain_s:.3}s ({} live)", sched.shards());
            Ok(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("drained", json::i(id as i64)),
                ("drain_s", json::n(drain_s)),
                ("shards_live", json::i(sched.shards() as i64)),
            ]))
        }
        "shutdown" => {
            shutdown.store(true, Ordering::Release);
            Ok(json::obj(vec![("ok", Value::Bool(true)), ("bye", Value::Bool(true))]))
        }
        other => bail!("unknown op `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_method_variants() {
        let v = Value::parse(r#"{"op":"solve","method":"parallel-spm","paths":3}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::Parallel { n: 3, spm: true });
        let v = Value::parse(r#"{"op":"solve"}"#).unwrap();
        assert_eq!(
            parse_method(&v, 5, 7).unwrap(),
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full }
        );
        let v = Value::parse(r#"{"op":"solve","method":"nope"}"#).unwrap();
        assert!(parse_method(&v, 5, 7).is_err());
    }

    #[test]
    fn parse_method_tau_override() {
        let v = Value::parse(r#"{"method":"spec-reason","tau":9}"#).unwrap();
        assert_eq!(parse_method(&v, 5, 7).unwrap(), Method::SpecReason { tau: 9 });
    }

    #[test]
    fn parse_method_bounds_wire_paths() {
        for bad in [r#"{"method":"parallel","paths":100000000}"#, r#"{"method":"ssr","paths":0}"#]
        {
            let v = Value::parse(bad).unwrap();
            assert!(parse_method(&v, 5, 7).is_err(), "accepted {bad}");
        }
        let v = Value::parse(r#"{"method":"parallel","paths":16}"#).unwrap();
        assert!(parse_method(&v, 5, 7).is_ok());
    }
}
