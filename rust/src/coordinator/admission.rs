//! Admission control and QoS at the serving boundary (DESIGN.md §14).
//!
//! PR 6 made the pool survive internal faults; this layer protects it
//! from the *outside*: a flash crowd or one hot tenant must not degrade
//! everyone. Requests carry optional `tenant` and `class` wire fields,
//! and before a job touches the pool the [`AdmissionController`] runs
//! four gates, in order:
//!
//! 1. **SLO shedding** — when the interactive p99 exceeds
//!    `qos.slo_ms`, `best_effort` intake is shed first; `batch` joins
//!    once the breach passes 2x the SLO. `interactive` is never shed by
//!    SLO (it is bounded by its own queue cap instead).
//! 2. **Per-tenant token bucket** — each tenant refills at
//!    `qos.tenant_rate` admits/second up to `qos.tenant_burst`
//!    (overridable per tenant); a dry bucket rejects with a
//!    `retry_after_ms` computed from the refill time of one token.
//! 3. **Per-class bounded queue** — at most `qos.queue_cap` requests
//!    of a class may be in the system (queued + in flight); a full
//!    class rejects with a `retry_after_ms` derived from the observed
//!    per-class drain rate.
//! 4. **Fair-share lane quota** — one tenant may hold at most
//!    `qos.lane_share` of total lane capacity (shards x max_lanes) in
//!    flight, so a single tenant cannot monopolize the batch even when
//!    under its rate limit.
//!
//! Every reject is *intake-only*: admitted work is never dropped. An
//! admitted request returns a [`Permit`] whose `Drop` releases the
//! class slot and tenant lanes — RAII makes the accounting exact on
//! every reply path, including errors and panics caught upstream.
//!
//! All decision logic takes an explicit `now_s` clock so unit tests
//! drive time deterministically; the wall-clock entry points
//! ([`AdmissionController::admit`]) are thin wrappers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::QosCfg;

/// Priority class of a request, carried on the `class` wire field.
/// Absent field = `Interactive` (pre-QoS clients are latency-sensitive
/// humans by assumption; batch pipelines opt in explicitly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosClass {
    #[default]
    Interactive,
    Batch,
    BestEffort,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Batch, QosClass::BestEffort];

    pub fn parse(s: &str) -> Result<QosClass> {
        Ok(match s {
            "interactive" => QosClass::Interactive,
            "batch" => QosClass::Batch,
            "best_effort" | "best-effort" => QosClass::BestEffort,
            _ => bail!("unknown class `{s}` (interactive|batch|best_effort)"),
        })
    }

    /// Stable index into per-class arrays (metrics, weights, queues).
    pub fn idx(self) -> usize {
        match self {
            QosClass::Interactive => 0,
            QosClass::Batch => 1,
            QosClass::BestEffort => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
            QosClass::BestEffort => "best_effort",
        }
    }
}

/// Why intake was refused — named in the `reason` field of the
/// structured `overloaded` reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// the tenant's token bucket is dry
    RateLimited,
    /// the class's bounded queue is full
    QueueFull,
    /// the tenant holds its full fair share of lanes
    LaneQuota,
    /// low-priority intake shed while the interactive SLO is breached
    Shed,
}

impl RejectReason {
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::RateLimited => "rate_limited",
            RejectReason::QueueFull => "queue_full",
            RejectReason::LaneQuota => "lane_quota",
            RejectReason::Shed => "shed",
        }
    }
}

/// A structured intake rejection: the wire reply is
/// `{"ok":false,"err":"overloaded","reason":...,"retry_after_ms":...}`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reject {
    pub reason: RejectReason,
    pub retry_after_ms: u64,
}

/// Classic token bucket with lazy refill. Time is an explicit seconds
/// counter so the math is unit-testable without sleeping.
#[derive(Debug, Clone)]
struct Bucket {
    tokens: f64,
    rate: f64,
    burst: f64,
    last_refill_s: f64,
    /// admission sequence of last use — LRU victim ordering when the
    /// tenant table hits `max_tenants`
    last_used: u64,
}

impl Bucket {
    fn new(rate: f64, burst: f64, now_s: f64) -> Bucket {
        Bucket { tokens: burst, rate, burst, last_refill_s: now_s, last_used: 0 }
    }

    fn refill(&mut self, now_s: f64) {
        let dt = (now_s - self.last_refill_s).max(0.0);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last_refill_s = now_s;
    }

    /// Seconds until one whole token is available (0 if already).
    fn time_to_token_s(&self) -> f64 {
        if self.tokens >= 1.0 || self.rate <= 0.0 {
            0.0
        } else {
            (1.0 - self.tokens) / self.rate
        }
    }
}

const DRAIN_EWMA_ALPHA: f64 = 0.3;
/// Retry hints are clamped into a sane band: long enough not to invite
/// an instant retry storm, short enough that clients actually wait.
const MIN_RETRY_MS: u64 = 10;
const MAX_RETRY_MS: u64 = 30_000;
/// Fallback hint before any completion has been observed for a class.
const DEFAULT_RETRY_MS: u64 = 100;

/// Shared mutable accounting behind one mutex — admit/release are a
/// few map ops, far cheaper than the solve they gate.
struct State {
    seq: u64,
    buckets: HashMap<String, Bucket>,
    /// requests in the system (queued + in flight) per class index
    in_system: [usize; 3],
    /// outstanding lane estimate per tenant (fair-share quota)
    tenant_lanes: HashMap<String, usize>,
    /// EWMA of inter-completion gaps per class — the observed drain
    /// rate that prices a queue-full retry hint
    drain_gap_s: [f64; 3],
    last_finish_s: [Option<f64>; 3],
}

/// The intake gate. One per server, shared across connection handlers.
pub struct AdmissionController {
    cfg: QosCfg,
    /// total lane capacity (spawn-time shards x max_lanes) — the base
    /// of the fair-share quota
    lane_capacity: usize,
    started: Instant,
    state: Arc<Mutex<State>>,
    /// permits whose requester vanished before the terminal reply
    /// (closed connection or slow-consumer disconnect, DESIGN.md §16).
    /// The permit is still held until the run reaches its terminal
    /// reply — the lanes stay occupied either way — so this counts
    /// capacity spent on answers nobody read, not an accounting leak.
    disconnects: AtomicU64,
}

/// RAII admission slot: dropping it releases the class slot and the
/// tenant's lanes, and feeds the drain-rate estimator. Hold it for the
/// life of the request (submit through reply).
pub struct Permit {
    state: Arc<Mutex<State>>,
    class: usize,
    tenant: String,
    lanes: usize,
    started: Instant,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let now_s = self.started.elapsed().as_secs_f64();
        if let Ok(mut st) = self.state.lock() {
            st.release(self.class, &self.tenant, self.lanes, now_s);
        }
    }
}

impl State {
    fn release(&mut self, class: usize, tenant: &str, lanes: usize, now_s: f64) {
        self.in_system[class] = self.in_system[class].saturating_sub(1);
        if let Some(l) = self.tenant_lanes.get_mut(tenant) {
            *l = l.saturating_sub(lanes);
            if *l == 0 {
                self.tenant_lanes.remove(tenant);
            }
        }
        if let Some(prev) = self.last_finish_s[class] {
            let gap = (now_s - prev).max(0.0);
            self.drain_gap_s[class] = if self.drain_gap_s[class] > 0.0 {
                DRAIN_EWMA_ALPHA * gap + (1.0 - DRAIN_EWMA_ALPHA) * self.drain_gap_s[class]
            } else {
                gap
            };
        }
        self.last_finish_s[class] = Some(now_s);
    }

    /// Retry hint for a full class queue: the time one slot takes to
    /// drain at the observed completion rate.
    fn drain_hint_ms(&self, class: usize) -> u64 {
        let gap = self.drain_gap_s[class];
        if gap <= 0.0 {
            return DEFAULT_RETRY_MS;
        }
        ((gap * 1000.0).ceil() as u64).clamp(MIN_RETRY_MS, MAX_RETRY_MS)
    }
}

impl AdmissionController {
    pub fn new(cfg: QosCfg, lane_capacity: usize) -> AdmissionController {
        AdmissionController {
            cfg,
            lane_capacity: lane_capacity.max(1),
            started: Instant::now(),
            state: Arc::new(Mutex::new(State {
                seq: 0,
                buckets: HashMap::new(),
                in_system: [0; 3],
                tenant_lanes: HashMap::new(),
                drain_gap_s: [0.0; 3],
                last_finish_s: [None; 3],
            })),
            disconnects: AtomicU64::new(0),
        }
    }

    /// A request's connection died before its terminal reply (the
    /// server releases the permit only once the run retires — see the
    /// struct field doc). Feeds the `stream_disconnects` stat.
    pub fn note_disconnect(&self) {
        self.disconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Permits released after their requester disconnected.
    pub fn disconnects(&self) -> u64 {
        self.disconnects.load(Ordering::Relaxed)
    }

    /// Max lanes one tenant may hold in flight.
    fn lane_quota(&self) -> usize {
        // never below one max-width request, or nothing could admit
        ((self.lane_capacity as f64 * self.cfg.lane_share).ceil() as usize).max(16)
    }

    /// Wall-clock entry point used by the server.
    pub fn admit(
        &self,
        tenant: Option<&str>,
        class: QosClass,
        lanes: usize,
        interactive_p99_s: f64,
    ) -> Result<Permit, Reject> {
        self.admit_at(tenant, class, lanes, interactive_p99_s, self.started.elapsed().as_secs_f64())
    }

    /// Deterministic core: all gates evaluated at an explicit time.
    pub fn admit_at(
        &self,
        tenant: Option<&str>,
        class: QosClass,
        lanes: usize,
        interactive_p99_s: f64,
        now_s: f64,
    ) -> Result<Permit, Reject> {
        let tenant = tenant.unwrap_or("");
        let mut st = self.state.lock().expect("admission state poisoned");
        st.seq += 1;
        let seq = st.seq;

        if self.cfg.enabled {
            // gate 1: SLO shed — low-priority intake first, never
            // interactive, never anything already admitted
            if self.cfg.slo_ms > 0 {
                let slo_s = self.cfg.slo_ms as f64 / 1000.0;
                let shed = match class {
                    QosClass::BestEffort => interactive_p99_s > slo_s,
                    QosClass::Batch => interactive_p99_s > 2.0 * slo_s,
                    QosClass::Interactive => false,
                };
                if shed {
                    return Err(Reject {
                        reason: RejectReason::Shed,
                        retry_after_ms: self.cfg.slo_ms.clamp(MIN_RETRY_MS, MAX_RETRY_MS),
                    });
                }
            }

            // gate 2: per-tenant token bucket (peek; consume only after
            // every other gate passes so a queue-full reject does not
            // burn the tenant's tokens)
            let (rate, burst) = self.cfg.bucket_for(tenant);
            if rate > 0.0 {
                if !st.buckets.contains_key(tenant) {
                    if st.buckets.len() >= self.cfg.max_tenants {
                        // recycle the least-recently-used bucket; a new
                        // tenant starting full is the safe direction
                        if let Some(victim) = st
                            .buckets
                            .iter()
                            .min_by_key(|(_, b)| b.last_used)
                            .map(|(k, _)| k.clone())
                        {
                            st.buckets.remove(&victim);
                        }
                    }
                    st.buckets.insert(tenant.to_string(), Bucket::new(rate, burst, now_s));
                }
                let b = st.buckets.get_mut(tenant).expect("bucket just ensured");
                b.last_used = seq;
                b.refill(now_s);
                if b.tokens < 1.0 {
                    let wait_ms = (b.time_to_token_s() * 1000.0).ceil() as u64;
                    return Err(Reject {
                        reason: RejectReason::RateLimited,
                        retry_after_ms: wait_ms.clamp(MIN_RETRY_MS, MAX_RETRY_MS),
                    });
                }
            }

            // gate 3: per-class bounded queue
            let ci = class.idx();
            if self.cfg.queue_cap > 0 && st.in_system[ci] >= self.cfg.queue_cap {
                let hint = st.drain_hint_ms(ci);
                return Err(Reject { reason: RejectReason::QueueFull, retry_after_ms: hint });
            }

            // gate 4: fair-share lane quota
            let held = st.tenant_lanes.get(tenant).copied().unwrap_or(0);
            if held + lanes > self.lane_quota() {
                let hint = st.drain_hint_ms(ci);
                return Err(Reject { reason: RejectReason::LaneQuota, retry_after_ms: hint });
            }

            // all gates passed — consume the token
            if rate > 0.0 {
                if let Some(b) = st.buckets.get_mut(tenant) {
                    b.tokens -= 1.0;
                }
            }
        }

        let ci = class.idx();
        st.in_system[ci] += 1;
        *st.tenant_lanes.entry(tenant.to_string()).or_insert(0) += lanes;
        Ok(Permit {
            state: Arc::clone(&self.state),
            class: ci,
            tenant: tenant.to_string(),
            lanes,
            started: self.started,
        })
    }

    /// Requests currently in the system per class (tests, stats).
    pub fn in_system(&self) -> [usize; 3] {
        self.state.lock().expect("admission state poisoned").in_system
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QosCfg {
        QosCfg { tenant_rate: 2.0, tenant_burst: 4.0, queue_cap: 8, ..QosCfg::default() }
    }

    #[test]
    fn class_parse_and_default() {
        assert_eq!(QosClass::parse("interactive").unwrap(), QosClass::Interactive);
        assert_eq!(QosClass::parse("batch").unwrap(), QosClass::Batch);
        assert_eq!(QosClass::parse("best-effort").unwrap(), QosClass::BestEffort);
        assert!(QosClass::parse("urgent").is_err());
        assert_eq!(QosClass::default(), QosClass::Interactive);
        for (i, c) in QosClass::ALL.iter().enumerate() {
            assert_eq!(c.idx(), i);
        }
    }

    #[test]
    fn token_bucket_limits_sustained_rate_and_allows_burst() {
        let ac = AdmissionController::new(cfg(), 64);
        let mut permits = Vec::new();
        // burst of 4 admits instantly...
        for _ in 0..4 {
            permits.push(
                ac.admit_at(Some("t"), QosClass::Interactive, 5, 0.0, 0.0)
                    .expect("burst should admit"),
            );
        }
        // ...the 5th is dry, with a refill-priced retry hint
        let rej = ac.admit_at(Some("t"), QosClass::Interactive, 5, 0.0, 0.0).unwrap_err();
        assert_eq!(rej.reason, RejectReason::RateLimited);
        // one token refills in 1/rate = 0.5s
        assert!(rej.retry_after_ms >= 400 && rej.retry_after_ms <= 600, "{rej:?}");
        // after 0.6s one token is back
        assert!(ac.admit_at(Some("t"), QosClass::Interactive, 5, 0.0, 0.6).is_ok());
        drop(permits);
    }

    #[test]
    fn queue_cap_bounds_in_system_and_released_permits_free_slots() {
        let mut c = cfg();
        c.tenant_rate = 0.0; // isolate the queue gate
        c.queue_cap = 2;
        let ac = AdmissionController::new(c, 1024);
        let p1 = ac.admit_at(None, QosClass::Batch, 1, 0.0, 0.0).unwrap();
        let _p2 = ac.admit_at(None, QosClass::Batch, 1, 0.0, 0.0).unwrap();
        let rej = ac.admit_at(None, QosClass::Batch, 1, 0.0, 0.0).unwrap_err();
        assert_eq!(rej.reason, RejectReason::QueueFull);
        assert!(rej.retry_after_ms >= MIN_RETRY_MS);
        // other classes are unaffected by batch being full
        assert!(ac.admit_at(None, QosClass::Interactive, 1, 0.0, 0.0).is_ok());
        drop(p1);
        assert_eq!(ac.in_system()[QosClass::Batch.idx()], 1);
        assert!(ac.admit_at(None, QosClass::Batch, 1, 0.0, 0.1).is_ok());
    }

    #[test]
    fn lane_quota_caps_one_tenant_but_not_others() {
        let mut c = cfg();
        c.tenant_rate = 0.0;
        c.queue_cap = 0;
        c.lane_share = 0.5;
        // capacity 64 -> quota 32 lanes per tenant
        let ac = AdmissionController::new(c, 64);
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(ac.admit_at(Some("pig"), QosClass::Interactive, 8, 0.0, 0.0).unwrap());
        }
        let rej = ac.admit_at(Some("pig"), QosClass::Interactive, 8, 0.0, 0.0).unwrap_err();
        assert_eq!(rej.reason, RejectReason::LaneQuota);
        // a different tenant still has room
        assert!(ac.admit_at(Some("other"), QosClass::Interactive, 8, 0.0, 0.0).is_ok());
        // releasing lanes reopens the quota
        held.pop();
        assert!(ac.admit_at(Some("pig"), QosClass::Interactive, 8, 0.0, 0.1).is_ok());
    }

    #[test]
    fn slo_breach_sheds_best_effort_then_batch_never_interactive() {
        let mut c = cfg();
        c.tenant_rate = 0.0;
        c.slo_ms = 500;
        let ac = AdmissionController::new(c, 64);
        // p99 under SLO: everything admits
        assert!(ac.admit_at(None, QosClass::BestEffort, 1, 0.4, 0.0).is_ok());
        // p99 past SLO: best_effort shed, batch + interactive still in
        let rej = ac.admit_at(None, QosClass::BestEffort, 1, 0.6, 0.0).unwrap_err();
        assert_eq!(rej.reason, RejectReason::Shed);
        assert_eq!(rej.retry_after_ms, 500);
        assert!(ac.admit_at(None, QosClass::Batch, 1, 0.6, 0.0).is_ok());
        assert!(ac.admit_at(None, QosClass::Interactive, 1, 0.6, 0.0).is_ok());
        // p99 past 2x SLO: batch joins the shed; interactive never does
        assert!(ac.admit_at(None, QosClass::Batch, 1, 1.1, 0.0).is_err());
        assert!(ac.admit_at(None, QosClass::Interactive, 1, 1.1, 0.0).is_ok());
    }

    #[test]
    fn queue_full_reject_does_not_burn_tokens() {
        let mut c = cfg();
        c.tenant_rate = 1.0;
        c.tenant_burst = 2.0;
        c.queue_cap = 1;
        let ac = AdmissionController::new(c, 64);
        let _held = ac.admit_at(Some("t"), QosClass::Interactive, 1, 0.0, 0.0).unwrap();
        // queue full -> reject, but the bucket still holds 1 token...
        let rej = ac.admit_at(Some("t"), QosClass::Interactive, 1, 0.0, 0.0).unwrap_err();
        assert_eq!(rej.reason, RejectReason::QueueFull);
        drop(_held);
        // ...which admits as soon as the slot frees, without refill time
        assert!(ac.admit_at(Some("t"), QosClass::Interactive, 1, 0.0, 0.0).is_ok());
    }

    #[test]
    fn disabled_qos_admits_everything_but_still_accounts() {
        let mut c = cfg();
        c.enabled = false;
        c.queue_cap = 1;
        c.tenant_rate = 0.001;
        let ac = AdmissionController::new(c, 4);
        let permits: Vec<_> = (0..16)
            .map(|_| ac.admit_at(Some("t"), QosClass::BestEffort, 8, 99.0, 0.0).unwrap())
            .collect();
        assert_eq!(ac.in_system()[QosClass::BestEffort.idx()], 16);
        drop(permits);
        assert_eq!(ac.in_system()[QosClass::BestEffort.idx()], 0);
    }

    #[test]
    fn tenant_table_is_cardinality_bounded() {
        let mut c = cfg();
        c.max_tenants = 4;
        let ac = AdmissionController::new(c, 1 << 16);
        let mut permits = Vec::new();
        for k in 0..64 {
            permits.push(
                ac.admit_at(Some(&format!("t{k}")), QosClass::Interactive, 1, 0.0, k as f64)
                    .unwrap(),
            );
        }
        let st = ac.state.lock().unwrap();
        assert!(st.buckets.len() <= 4, "bucket table must stay bounded");
        drop(st);
        drop(permits);
    }

    #[test]
    fn disconnect_accounting_is_independent_of_release() {
        let ac = AdmissionController::new(cfg(), 64);
        assert_eq!(ac.disconnects(), 0);
        let p = ac.admit_at(Some("t"), QosClass::Interactive, 1, 0.0, 0.0).unwrap();
        // requester vanished mid-solve: counted, but the permit (and
        // its class slot) is still held until the run retires
        ac.note_disconnect();
        assert_eq!(ac.disconnects(), 1);
        assert_eq!(ac.in_system()[QosClass::Interactive.idx()], 1);
        drop(p);
        assert_eq!(ac.in_system()[QosClass::Interactive.idx()], 0);
        assert_eq!(ac.disconnects(), 1, "release does not touch the counter");
    }

    #[test]
    fn drain_rate_prices_retry_hints() {
        let mut st = State {
            seq: 0,
            buckets: HashMap::new(),
            in_system: [0; 3],
            tenant_lanes: HashMap::new(),
            drain_gap_s: [0.0; 3],
            last_finish_s: [None; 3],
        };
        assert_eq!(st.drain_hint_ms(0), DEFAULT_RETRY_MS, "no data -> default hint");
        // completions 200ms apart -> hint converges near 200ms
        for k in 1..=20 {
            st.release(0, "", 1, 0.2 * k as f64);
        }
        let hint = st.drain_hint_ms(0);
        assert!((150..=260).contains(&hint), "hint {hint} should track the 200ms gap");
    }
}
