//! Serving metrics: latency recorder + counters surfaced by the server
//! (`ssr serve` replies to a `{"op":"stats"}` request) and the bench
//! harness.

use std::time::Instant;

use crate::util::stats::{mean, percentile, Histogram};

#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// per-request end-to-end latency, seconds
    pub latencies: Vec<f64>,
    pub requests: u64,
    pub answered: u64,
    pub errors: u64,
    pub draft_tokens: u64,
    pub target_tokens: u64,
    pub steps: u64,
    pub rewrites: u64,
    /// 0..=9 step-score histogram (fig5 input)
    pub scores: Option<Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { scores: Some(Histogram::new(10)), ..Default::default() }
    }

    pub fn record_request(&mut self, latency_s: f64, answered: bool) {
        self.latencies.push(latency_s);
        self.requests += 1;
        if answered {
            self.answered += 1;
        }
    }

    pub fn record_tokens(&mut self, draft: u64, target: u64, steps: u64, rewrites: u64) {
        self.draft_tokens += draft;
        self.target_tokens += target;
        self.steps += steps;
        self.rewrites += rewrites;
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.latencies, 99.0)
    }

    pub fn mean_latency(&self) -> f64 {
        mean(&self.latencies)
    }

    /// requests/second over the observed span (0 when < 2 requests).
    pub fn throughput(&self, elapsed_s: f64) -> f64 {
        if elapsed_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed_s
        }
    }

    pub fn rewrite_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rewrites as f64 / self.steps as f64
        }
    }

    pub fn summary_json(&self, elapsed_s: f64) -> crate::util::json::Value {
        use crate::util::json::{i, n, obj};
        obj(vec![
            ("requests", i(self.requests as i64)),
            ("answered", i(self.answered as i64)),
            ("errors", i(self.errors as i64)),
            ("mean_latency_s", n(self.mean_latency())),
            ("p50_s", n(self.p50())),
            ("p99_s", n(self.p99())),
            ("throughput_rps", n(self.throughput(elapsed_s))),
            ("draft_tokens", i(self.draft_tokens as i64)),
            ("target_tokens", i(self.target_tokens as i64)),
            ("rewrite_rate", n(self.rewrite_rate())),
        ])
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 / 100.0, true);
        }
        assert!((m.p50() - 0.505).abs() < 0.01);
        assert!(m.p99() > 0.98);
        assert_eq!(m.answered, 100);
    }

    #[test]
    fn rates() {
        let mut m = Metrics::new();
        m.record_tokens(100, 50, 10, 3);
        assert!((m.rewrite_rate() - 0.3).abs() < 1e-12);
        m.record_request(0.1, true);
        assert_eq!(m.throughput(2.0), 0.5);
        assert_eq!(m.throughput(0.0), 0.0);
    }

    #[test]
    fn summary_json_parses() {
        let mut m = Metrics::new();
        m.record_request(0.2, true);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("requests").unwrap(), 1);
        assert!(v.get_f64("mean_latency_s").unwrap() > 0.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }
}
