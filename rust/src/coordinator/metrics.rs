//! Serving metrics: latency recorder + counters surfaced by the server
//! (`ssr serve` replies to a `{"op":"stats"}` request) and the bench
//! harness.
//!
//! Latency and admission-wait recorders are bounded reservoirs
//! ([`Reservoir`]): sustained traffic no longer grows an unbounded
//! `Vec<f64>`, while p50/p99 stay exact below capacity and unbiased
//! above it. The scheduler additionally feeds batch-occupancy (lanes
//! per backend step call), queue-depth and admission-wait gauges — the
//! observables that make cross-request batching wins measurable.

use std::collections::BTreeMap;
use std::time::Instant;

use super::admission::QosClass;
use crate::config::ShardClass;
use crate::util::stats::{Histogram, Reservoir};

/// Occupancy histogram buckets (lane counts; last bucket = overflow).
const OCCUPANCY_BUCKETS: usize = 65;

/// Cardinality bound on the per-tenant gauge maps: an adversarial
/// client inventing tenant names must not grow stats memory without
/// bound. Past the cap, new names fold into the `_other` row.
const TENANT_GAUGE_CAP: usize = 64;
/// Fold-in row for tenants beyond `TENANT_GAUGE_CAP`.
const TENANT_OTHER: &str = "_other";
/// Gauge row for requests with no `tenant` wire field.
const TENANT_ANON: &str = "_anon";

#[derive(Debug, Clone)]
pub struct Metrics {
    /// per-request end-to-end latency, seconds (bounded reservoir)
    latencies: Reservoir,
    /// seconds requests spent queued before the scheduler admitted them
    admission_waits: Reservoir,
    pub requests: u64,
    pub answered: u64,
    pub errors: u64,
    pub draft_tokens: u64,
    pub target_tokens: u64,
    pub steps: u64,
    pub rewrites: u64,
    /// 0..=9 step-score histogram (fig5 input)
    pub scores: Option<Histogram>,
    /// model-executing backend step calls (draft/score/rewrite/target)
    pub backend_calls: u64,
    /// total lanes those calls carried (mean occupancy numerator)
    pub backend_lanes: u64,
    /// per-call lane-count histogram
    pub occupancy: Histogram,
    pub queue_samples: u64,
    pub queue_depth_sum: u64,
    pub queue_depth_max: u64,
    /// prefix-reuse gauges (scheduler `PrefixCache` / shared-tier
    /// totals): tier-LOGICAL hits — the prompt was already known. On a
    /// single shard this equals "prompt prefill skipped entirely"; in a
    /// sharded pool it includes first-touch shard fills (which do
    /// prefill once): `prefix_hits - prefix_shard_fills` is the
    /// prefill-skipped count
    pub prefix_hits: u64,
    /// solves that prefilled a fresh shared prefix
    pub prefix_misses: u64,
    /// cached prefixes evicted by the capacity/byte bounds
    pub prefix_evictions: u64,
    /// tier hits that still prefilled because the serving shard had no
    /// handle yet (sharded serving only; 0 on a single shard)
    pub prefix_shard_fills: u64,
    /// spill-tier counters (DESIGN.md §17): hot-tier evictions/drains
    /// demoted into the persistent store
    pub prefix_spills: u64,
    /// logical misses served by promoting a spill record instead of
    /// prefilling (counted under `prefix_misses` too, so the hot-tier
    /// hit rate stays honest)
    pub prefix_promotes: u64,
    /// promotes of records that predate this process — the warm-restart
    /// wins `--prefix-spill-dir` exists for
    pub prefix_warm_hits: u64,
    /// two-tier occupancy gauges: hot-tier live entries/bytes and
    /// persistent spill-store records/payload bytes
    pub prefix_hot_entries: u64,
    pub prefix_hot_bytes: u64,
    pub prefix_spill_entries: u64,
    pub prefix_spill_bytes: u64,
    /// per-LIVE-shard cumulative prompt-prefill tokens (target + draft
    /// prompt passes only — the ingest warm restarts avoid); dead ids
    /// fold into `retired_prefill_tokens` on removal
    pub shard_prefill_tokens: BTreeMap<usize, u64>,
    retired_prefill_tokens: u64,
    /// sum of the per-shard backend model-clocks (real PJRT seconds,
    /// virtual seconds on the calibrated substrate) — total model COST
    pub model_secs: f64,
    /// per-LIVE-shard model-clocks keyed by shard id (ids are monotonic
    /// and never reused, so dead ids are folded into
    /// `retired_model_secs` on removal instead of growing a column
    /// forever under autoscale churn); `model_secs_makespan()` is the
    /// virtual wall-clock of the pool, the number shard scaling improves
    pub shard_clocks: BTreeMap<usize, f64>,
    /// requests admitted per live shard (placement telemetry); dead
    /// ids fold into `retired_requests`
    pub shard_requests: BTreeMap<usize, u64>,
    /// model-seconds of shards since removed (still part of the COST)
    pub retired_model_secs: f64,
    /// makespan floor contributed by removed shards (their final clock
    /// still bounds the pool's virtual wall-clock from below)
    pub retired_makespan: f64,
    /// requests served by shards since removed
    pub retired_requests: u64,
    /// queued jobs moved by cross-shard work stealing
    pub steals: u64,
    /// in-flight runs migrated between shards (drain or steal), and the
    /// approximate bytes their snapshots carried
    pub migrations: u64,
    pub migration_bytes: u64,
    /// shard lifecycle events (`PoolHandle::add_shard` / `remove_shard`)
    pub shards_added: u64,
    pub shards_removed: u64,
    /// autoscaler policy decisions (subset of the lifecycle events)
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// completed shard drains and their durations (remove_shard's
    /// mark-draining -> joined span)
    pub drains: u64,
    pub drain_secs_sum: f64,
    pub drain_secs_max: f64,
    /// fault-tolerance counters (DESIGN.md §13)
    /// shard threads that panicked and were caught by the supervisor
    pub shard_crashes: u64,
    /// admitted runs re-homed after a crash (checkpoint or replay)
    pub runs_recovered: u64,
    /// subset of `runs_recovered` replayed from scratch via the
    /// placement-invariant run seed (no checkpoint was available)
    pub runs_replayed: u64,
    /// transient backend errors absorbed by in-place step retries
    pub retries: u64,
    /// poison runs refused after exhausting their crash-retry budget
    pub quarantined: u64,
    /// per-request deadlines that expired at a step boundary
    pub deadline_expirations: u64,
    /// replies finalized early from partial votes (`degraded:true`)
    pub degraded_replies: u64,
    /// overload-protection counters (DESIGN.md §14)
    /// intake refused by admission control (dry token bucket, full
    /// class queue, or lane quota) — never an admitted run
    pub rejected: u64,
    /// intake shed because the interactive latency SLO was breached
    /// (best_effort first, batch past 2x)
    pub shed: u64,
    /// structured `overloaded` replies that carried a `retry_after_ms`
    /// backoff hint (= rejected + shed), plus the hinted total so the
    /// mean hint is reportable
    pub retry_after_hints: u64,
    retry_after_ms_sum: u64,
    /// poison-run entries evicted by the quarantine LRU bound
    pub quarantine_evictions: u64,
    /// speculation accounting (DESIGN.md §15)
    /// per-shard-class acceptance ledger `(accepted, proposed)`: the
    /// retiring shard's class accrues each speculative run's lifetime
    /// counts, so `gamma_of_class` reports measured per-class gamma
    pub class_gamma: BTreeMap<ShardClass, (u64, u64)>,
    /// final controller window depth per retired speculative run
    /// (bucket = depth; last bucket = overflow)
    pub spec_depth_hist: Histogram,
    spec_depth_sum: u64,
    spec_runs: u64,
    /// speculative runs retired with the controller in target-only mode
    pub target_only_runs: u64,
    /// gamma-driven class migrations (a subset of `migrations`)
    pub gamma_migrations: u64,
    /// per-LIVE-shard `(draft, target)` model-clock split: where each
    /// shard's `model_secs` went by side; dead ids fold into the
    /// retired split on removal
    pub shard_clock_splits: BTreeMap<usize, (f64, f64)>,
    retired_draft_secs: f64,
    retired_target_secs: f64,
    /// least-loaded placements whose batch-shape hint matched (the
    /// pool owns the live atomic; the server/bench pushes it here)
    pub placement_shape_hits: u64,
    /// per-class end-to-end latency reservoirs, indexed by
    /// `QosClass::idx` ([interactive, batch, best_effort])
    class_latencies: [Reservoir; 3],
    /// completed requests per class (same indexing)
    pub class_requests: [u64; 3],
    /// admitted requests per tenant (cardinality-bounded)
    pub tenant_requests: BTreeMap<String, u64>,
    /// refused intake per tenant (cardinality-bounded)
    pub tenant_rejected: BTreeMap<String, u64>,
    /// streaming front-end gauges (DESIGN.md §16)
    /// streamed solves currently between admission and terminal frame
    pub streams_active: u64,
    /// step-boundary events queued to stream taps (progress + first_vote)
    pub stream_events: u64,
    /// events evicted by drop-oldest backpressure (slow readers)
    pub stream_drops: u64,
    /// requesters that vanished before their terminal frame (closed or
    /// slow-consumer-disconnected connections; permits release late)
    pub stream_disconnects: u64,
    /// seconds from enqueue to the first lane finishing with a parsed
    /// answer, per streamed run — time-to-first-useful-answer
    time_to_first_vote: Reservoir,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            latencies: Reservoir::default(),
            admission_waits: Reservoir::default(),
            requests: 0,
            answered: 0,
            errors: 0,
            draft_tokens: 0,
            target_tokens: 0,
            steps: 0,
            rewrites: 0,
            scores: Some(Histogram::new(10)),
            backend_calls: 0,
            backend_lanes: 0,
            occupancy: Histogram::new(OCCUPANCY_BUCKETS),
            queue_samples: 0,
            queue_depth_sum: 0,
            queue_depth_max: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_evictions: 0,
            prefix_shard_fills: 0,
            prefix_spills: 0,
            prefix_promotes: 0,
            prefix_warm_hits: 0,
            prefix_hot_entries: 0,
            prefix_hot_bytes: 0,
            prefix_spill_entries: 0,
            prefix_spill_bytes: 0,
            shard_prefill_tokens: BTreeMap::new(),
            retired_prefill_tokens: 0,
            model_secs: 0.0,
            shard_clocks: BTreeMap::new(),
            shard_requests: BTreeMap::new(),
            retired_model_secs: 0.0,
            retired_makespan: 0.0,
            retired_requests: 0,
            steals: 0,
            migrations: 0,
            migration_bytes: 0,
            shards_added: 0,
            shards_removed: 0,
            scale_ups: 0,
            scale_downs: 0,
            drains: 0,
            drain_secs_sum: 0.0,
            drain_secs_max: 0.0,
            shard_crashes: 0,
            runs_recovered: 0,
            runs_replayed: 0,
            retries: 0,
            quarantined: 0,
            deadline_expirations: 0,
            degraded_replies: 0,
            rejected: 0,
            shed: 0,
            retry_after_hints: 0,
            retry_after_ms_sum: 0,
            quarantine_evictions: 0,
            class_gamma: BTreeMap::new(),
            // depth buckets 0..=16 plus overflow (max configurable
            // depth is 16; 0 is unused — target-only runs report their
            // forced depth of 1)
            spec_depth_hist: Histogram::new(18),
            spec_depth_sum: 0,
            spec_runs: 0,
            target_only_runs: 0,
            gamma_migrations: 0,
            shard_clock_splits: BTreeMap::new(),
            retired_draft_secs: 0.0,
            retired_target_secs: 0.0,
            placement_shape_hits: 0,
            class_latencies: [Reservoir::default(), Reservoir::default(), Reservoir::default()],
            class_requests: [0; 3],
            tenant_requests: BTreeMap::new(),
            tenant_rejected: BTreeMap::new(),
            streams_active: 0,
            stream_events: 0,
            stream_drops: 0,
            stream_disconnects: 0,
            time_to_first_vote: Reservoir::default(),
        }
    }

    /// One streamed run produced its first finished-lane vote,
    /// `elapsed_s` after enqueue (the `first_vote` stream event).
    pub fn record_first_vote(&mut self, elapsed_s: f64) {
        self.time_to_first_vote.push(elapsed_s);
    }

    pub fn ttfv_mean(&self) -> f64 {
        self.time_to_first_vote.mean()
    }

    pub fn ttfv_p99(&self) -> f64 {
        self.time_to_first_vote.percentile(99.0)
    }

    /// First-vote observations recorded (reservoir `seen`, not capped).
    pub fn first_votes(&self) -> u64 {
        self.time_to_first_vote.seen()
    }

    /// Seed the per-shard gauges for the spawn-time shard set (hot-added
    /// shards insert their own entries on first use).
    pub fn init_shards(&mut self, shards: usize) {
        for s in 0..shards.max(1) {
            self.shard_clocks.entry(s).or_insert(0.0);
            self.shard_requests.entry(s).or_insert(0);
        }
    }

    /// One shard's cumulative backend clock; `model_secs` becomes the
    /// retired total plus the sum across live shards (total cost).
    pub fn set_shard_clock(&mut self, shard: usize, secs: f64) {
        self.shard_clocks.insert(shard, secs);
        self.model_secs = self.retired_model_secs + self.shard_clocks.values().sum::<f64>();
    }

    /// One shard's cumulative `(draft, target)` model-clock split —
    /// how its `model_secs` divide between draft-side and target-side
    /// work (DESIGN.md §15); the two sum to the shard's clock.
    pub fn set_shard_clock_split(&mut self, shard: usize, draft_s: f64, target_s: f64) {
        self.shard_clock_splits.insert(shard, (draft_s, target_s));
    }

    /// Pool-wide `(draft, target)` model-seconds split across live and
    /// retired shards.
    pub fn model_secs_split(&self) -> (f64, f64) {
        let (mut d, mut t) = (self.retired_draft_secs, self.retired_target_secs);
        for &(ds, ts) in self.shard_clock_splits.values() {
            d += ds;
            t += ts;
        }
        (d, t)
    }

    /// Fold a removed shard's per-id gauges into the retired
    /// accumulators and drop its columns, so week-long autoscale churn
    /// (monotonic ids, never reused) cannot grow memory without bound.
    pub fn retire_shard(&mut self, shard: usize) {
        if let Some(clock) = self.shard_clocks.remove(&shard) {
            self.retired_model_secs += clock;
            self.retired_makespan = self.retired_makespan.max(clock);
        }
        if let Some((d, t)) = self.shard_clock_splits.remove(&shard) {
            self.retired_draft_secs += d;
            self.retired_target_secs += t;
        }
        if let Some(reqs) = self.shard_requests.remove(&shard) {
            self.retired_requests += reqs;
        }
        if let Some(toks) = self.shard_prefill_tokens.remove(&shard) {
            self.retired_prefill_tokens += toks;
        }
        self.model_secs = self.retired_model_secs + self.shard_clocks.values().sum::<f64>();
    }

    /// Virtual wall-clock of the pool: the slowest shard's model time
    /// (shards run concurrently, so throughput divides by this, not by
    /// the summed cost). Removed shards keep contributing their final
    /// clock as a floor.
    pub fn model_secs_makespan(&self) -> f64 {
        if self.shard_clocks.is_empty() && self.retired_makespan == 0.0 {
            self.model_secs
        } else {
            self.shard_clocks
                .values()
                .cloned()
                .fold(self.retired_makespan, f64::max)
        }
    }

    /// `n` queued jobs stolen by an under-occupied shard.
    pub fn record_steals(&mut self, n: u64) {
        self.steals += n;
    }

    /// One in-flight run migrated between shards (drain or steal);
    /// `bytes` is its snapshot's approximate size.
    pub fn record_migration(&mut self, bytes: u64) {
        self.migrations += 1;
        self.migration_bytes += bytes;
    }

    /// One shard hot-added at runtime.
    pub fn record_shard_added(&mut self) {
        self.shards_added += 1;
    }

    /// One autoscaler decision applied (up = add_shard succeeded).
    pub fn record_scale_event(&mut self, up: bool) {
        if up {
            self.scale_ups += 1;
        } else {
            self.scale_downs += 1;
        }
    }

    /// One shard drained and removed; `drain_secs` is the mark-draining
    /// -> joined span.
    pub fn record_shard_removed(&mut self, drain_secs: f64) {
        self.shards_removed += 1;
        self.drains += 1;
        self.drain_secs_sum += drain_secs;
        self.drain_secs_max = self.drain_secs_max.max(drain_secs);
    }

    /// Mean shard-drain duration (0 before any drain).
    pub fn mean_drain_secs(&self) -> f64 {
        if self.drains == 0 {
            0.0
        } else {
            self.drain_secs_sum / self.drains as f64
        }
    }

    /// One request admitted on `shard`.
    pub fn record_shard_request(&mut self, shard: usize) {
        *self.shard_requests.entry(shard).or_insert(0) += 1;
    }

    /// Requests admitted across live and retired shards.
    pub fn total_shard_requests(&self) -> u64 {
        self.retired_requests + self.shard_requests.values().sum::<u64>()
    }

    pub fn record_request(&mut self, latency_s: f64, answered: bool) {
        self.latencies.push(latency_s);
        self.requests += 1;
        if answered {
            self.answered += 1;
        }
    }

    /// Like [`record_request`], additionally feeding the per-class
    /// latency reservoir (the SLO/shedding signal).
    ///
    /// [`record_request`]: Metrics::record_request
    pub fn record_request_class(&mut self, latency_s: f64, answered: bool, class: QosClass) {
        self.record_request(latency_s, answered);
        self.class_latencies[class.idx()].push(latency_s);
        self.class_requests[class.idx()] += 1;
    }

    pub fn class_p50(&self, class: QosClass) -> f64 {
        self.class_latencies[class.idx()].percentile(50.0)
    }

    pub fn class_p99(&self, class: QosClass) -> f64 {
        self.class_latencies[class.idx()].percentile(99.0)
    }

    fn bump_tenant(map: &mut BTreeMap<String, u64>, tenant: Option<&str>) {
        let name = match tenant {
            None | Some("") => TENANT_ANON,
            Some(t) => t,
        };
        let key = if map.contains_key(name) || map.len() < TENANT_GAUGE_CAP {
            name
        } else {
            TENANT_OTHER
        };
        *map.entry(key.to_string()).or_insert(0) += 1;
    }

    /// One request admitted past the intake gates for `tenant`.
    pub fn record_tenant_admit(&mut self, tenant: Option<&str>) {
        Self::bump_tenant(&mut self.tenant_requests, tenant);
    }

    /// One intake refusal with its backoff hint. `shed` separates
    /// SLO sheds from capacity rejects (buckets/queues/quotas).
    pub fn record_reject(&mut self, tenant: Option<&str>, shed: bool, retry_after_ms: u64) {
        if shed {
            self.shed += 1;
        } else {
            self.rejected += 1;
        }
        self.retry_after_hints += 1;
        self.retry_after_ms_sum += retry_after_ms;
        Self::bump_tenant(&mut self.tenant_rejected, tenant);
    }

    /// Mean `retry_after_ms` hinted to refused clients (0 before any).
    pub fn retry_after_hint_mean_ms(&self) -> f64 {
        if self.retry_after_hints == 0 {
            0.0
        } else {
            self.retry_after_ms_sum as f64 / self.retry_after_hints as f64
        }
    }

    /// One retired run's speculation ledger, attributed to the class of
    /// the shard that retired it (DESIGN.md §15). Non-speculative runs
    /// (`proposed == 0`, never target-only) are not counted.
    pub fn record_speculation(
        &mut self,
        class: ShardClass,
        proposed: u64,
        accepted: u64,
        depth: usize,
        target_only: bool,
    ) {
        if proposed == 0 && !target_only {
            return;
        }
        let e = self.class_gamma.entry(class).or_insert((0, 0));
        e.0 += accepted;
        e.1 += proposed;
        self.spec_depth_hist.add(depth);
        self.spec_depth_sum += depth as u64;
        self.spec_runs += 1;
        if target_only {
            self.target_only_runs += 1;
        }
    }

    /// Measured acceptance rate on shards of `class` (0 before any
    /// speculative run retired there).
    pub fn gamma_of_class(&self, class: ShardClass) -> f64 {
        match self.class_gamma.get(&class) {
            Some(&(acc, prop)) if prop > 0 => acc as f64 / prop as f64,
            _ => 0.0,
        }
    }

    /// Pool-wide measured acceptance rate across every class.
    pub fn gamma_overall(&self) -> f64 {
        let (acc, prop) = self
            .class_gamma
            .values()
            .fold((0u64, 0u64), |(a, p), &(acc, prop)| (a + acc, p + prop));
        if prop == 0 {
            0.0
        } else {
            acc as f64 / prop as f64
        }
    }

    /// Mean final controller depth across retired speculative runs.
    pub fn spec_depth_mean(&self) -> f64 {
        if self.spec_runs == 0 {
            0.0
        } else {
            self.spec_depth_sum as f64 / self.spec_runs as f64
        }
    }

    /// Sync the pool's batch-shape placement-hit counter (the pool owns
    /// the live lock-free counter; see `PoolHandle::placement_shape_hits`).
    pub fn set_placement_shape_hits(&mut self, hits: u64) {
        self.placement_shape_hits = hits;
    }

    pub fn record_tokens(&mut self, draft: u64, target: u64, steps: u64, rewrites: u64) {
        self.draft_tokens += draft;
        self.target_tokens += target;
        self.steps += steps;
        self.rewrites += rewrites;
    }

    /// One batched backend step call carrying `lanes` lanes.
    pub fn record_batch(&mut self, lanes: usize) {
        self.backend_calls += 1;
        self.backend_lanes += lanes as u64;
        self.occupancy.add(lanes);
    }

    /// Scheduler queue depth after an admission pass.
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.queue_samples += 1;
        self.queue_depth_sum += depth as u64;
        self.queue_depth_max = self.queue_depth_max.max(depth as u64);
    }

    /// Seconds one request waited from enqueue to lane admission.
    pub fn record_admission_wait(&mut self, wait_s: f64) {
        self.admission_waits.push(wait_s);
    }

    /// Sync the prefix-cache totals (the scheduler owns the live cache
    /// and pushes its counters here after each admission pass).
    pub fn set_prefix_cache(&mut self, hits: u64, misses: u64, evictions: u64) {
        self.prefix_hits = hits;
        self.prefix_misses = misses;
        self.prefix_evictions = evictions;
    }

    /// Shared-tier shard-fill total (re-prefills on a second shard).
    pub fn set_prefix_shard_fills(&mut self, fills: u64) {
        self.prefix_shard_fills = fills;
    }

    /// Sync the spill-tier counters (demotions, promotes, warm-restart
    /// promotes) from the shared tier's stats.
    pub fn set_prefix_spill(&mut self, spills: u64, promotes: u64, warm_hits: u64) {
        self.prefix_spills = spills;
        self.prefix_promotes = promotes;
        self.prefix_warm_hits = warm_hits;
    }

    /// Sync the two-tier occupancy gauges.
    pub fn set_prefix_tier_gauges(
        &mut self,
        hot_entries: usize,
        hot_bytes: u64,
        spill_entries: usize,
        spill_bytes: u64,
    ) {
        self.prefix_hot_entries = hot_entries as u64;
        self.prefix_hot_bytes = hot_bytes;
        self.prefix_spill_entries = spill_entries as u64;
        self.prefix_spill_bytes = spill_bytes;
    }

    /// One shard's cumulative prompt-prefill token count (target +
    /// draft prompt passes); the pool total is the retired fold plus
    /// the live columns.
    pub fn set_shard_prefill_tokens(&mut self, shard: usize, tokens: u64) {
        self.shard_prefill_tokens.insert(shard, tokens);
    }

    /// Prompt tokens prefilled across live and retired shards — the
    /// scalar the warm-restart bench compares cold vs warm.
    pub fn prefill_prompt_tokens(&self) -> u64 {
        self.retired_prefill_tokens + self.shard_prefill_tokens.values().sum::<u64>()
    }

    /// Fraction of solves whose prompt prefill was served from cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// Fraction of hot-tier misses rescued by the spill store (promotes
    /// are counted under `prefix_misses`, so this reads promotes over
    /// misses; 0 before any miss).
    pub fn prefix_spill_hit_rate(&self) -> f64 {
        if self.prefix_misses == 0 {
            0.0
        } else {
            self.prefix_promotes as f64 / self.prefix_misses as f64
        }
    }

    pub fn p50(&self) -> f64 {
        self.latencies.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.latencies.percentile(99.0)
    }

    pub fn mean_latency(&self) -> f64 {
        self.latencies.mean()
    }

    /// Retained latency sample (exact below the reservoir capacity).
    pub fn latency_samples(&self) -> &[f64] {
        self.latencies.samples()
    }

    /// Mean lanes per model-executing backend call.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.backend_calls == 0 {
            0.0
        } else {
            self.backend_lanes as f64 / self.backend_calls as f64
        }
    }

    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_samples == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.queue_samples as f64
        }
    }

    pub fn mean_admission_wait(&self) -> f64 {
        self.admission_waits.mean()
    }

    pub fn p99_admission_wait(&self) -> f64 {
        self.admission_waits.percentile(99.0)
    }

    /// requests/second over the observed span (0 when < 2 requests).
    pub fn throughput(&self, elapsed_s: f64) -> f64 {
        if elapsed_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed_s
        }
    }

    pub fn rewrite_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rewrites as f64 / self.steps as f64
        }
    }

    pub fn summary_json(&self, elapsed_s: f64) -> crate::util::json::Value {
        use crate::util::json::{arr, i, n, obj, Value};
        let shard_requests: Vec<Value> =
            self.shard_requests.values().map(|&r| i(r as i64)).collect();
        let spec_depth_hist: Vec<Value> =
            self.spec_depth_hist.counts.iter().map(|&c| i(c as i64)).collect();
        let class_requests: Vec<Value> =
            self.class_requests.iter().map(|&r| i(r as i64)).collect();
        let tenant_obj = |m: &BTreeMap<String, u64>| {
            Value::Obj(m.iter().map(|(k, &v)| (k.clone(), i(v as i64))).collect())
        };
        obj(vec![
            ("requests", i(self.requests as i64)),
            ("answered", i(self.answered as i64)),
            ("errors", i(self.errors as i64)),
            ("mean_latency_s", n(self.mean_latency())),
            ("p50_s", n(self.p50())),
            ("p99_s", n(self.p99())),
            ("throughput_rps", n(self.throughput(elapsed_s))),
            ("draft_tokens", i(self.draft_tokens as i64)),
            ("target_tokens", i(self.target_tokens as i64)),
            ("rewrite_rate", n(self.rewrite_rate())),
            ("backend_calls", i(self.backend_calls as i64)),
            ("mean_batch_occupancy", n(self.mean_batch_occupancy())),
            ("queue_depth_mean", n(self.mean_queue_depth())),
            ("queue_depth_max", i(self.queue_depth_max as i64)),
            ("admission_wait_mean_s", n(self.mean_admission_wait())),
            ("admission_wait_p99_s", n(self.p99_admission_wait())),
            ("prefix_hits", i(self.prefix_hits as i64)),
            ("prefix_misses", i(self.prefix_misses as i64)),
            ("prefix_evictions", i(self.prefix_evictions as i64)),
            ("prefix_shard_fills", i(self.prefix_shard_fills as i64)),
            ("prefix_hit_rate", n(self.prefix_hit_rate())),
            ("prefix_spills", i(self.prefix_spills as i64)),
            ("prefix_promotes", i(self.prefix_promotes as i64)),
            ("prefix_warm_hits", i(self.prefix_warm_hits as i64)),
            ("prefix_spill_hit_rate", n(self.prefix_spill_hit_rate())),
            ("prefix_hot_entries", i(self.prefix_hot_entries as i64)),
            ("prefix_hot_bytes", i(self.prefix_hot_bytes as i64)),
            ("prefix_spill_entries", i(self.prefix_spill_entries as i64)),
            ("prefix_spill_bytes", i(self.prefix_spill_bytes as i64)),
            ("prefill_prompt_tokens", i(self.prefill_prompt_tokens() as i64)),
            ("model_secs", n(self.model_secs)),
            ("model_secs_makespan", n(self.model_secs_makespan())),
            ("model_secs_draft", n(self.model_secs_split().0)),
            ("model_secs_target", n(self.model_secs_split().1)),
            ("gamma_overall", n(self.gamma_overall())),
            ("gamma_draft_heavy", n(self.gamma_of_class(ShardClass::DraftHeavy))),
            ("gamma_balanced", n(self.gamma_of_class(ShardClass::Balanced))),
            ("gamma_target_heavy", n(self.gamma_of_class(ShardClass::TargetHeavy))),
            ("spec_depth_mean", n(self.spec_depth_mean())),
            ("spec_depth_hist", arr(spec_depth_hist)),
            ("target_only_runs", i(self.target_only_runs as i64)),
            ("gamma_migrations", i(self.gamma_migrations as i64)),
            ("placement_shape_hits", i(self.placement_shape_hits as i64)),
            ("shards", i(self.shard_clocks.len().max(1) as i64)),
            ("shard_requests", arr(shard_requests)),
            ("steals", i(self.steals as i64)),
            ("migrations", i(self.migrations as i64)),
            ("migration_bytes", i(self.migration_bytes as i64)),
            ("shards_added", i(self.shards_added as i64)),
            ("shards_removed", i(self.shards_removed as i64)),
            ("scale_ups", i(self.scale_ups as i64)),
            ("scale_downs", i(self.scale_downs as i64)),
            ("drain_mean_s", n(self.mean_drain_secs())),
            ("drain_max_s", n(self.drain_secs_max)),
            ("shard_crashes", i(self.shard_crashes as i64)),
            ("runs_recovered", i(self.runs_recovered as i64)),
            ("runs_replayed", i(self.runs_replayed as i64)),
            ("retries", i(self.retries as i64)),
            ("quarantined", i(self.quarantined as i64)),
            ("deadline_expirations", i(self.deadline_expirations as i64)),
            ("degraded_replies", i(self.degraded_replies as i64)),
            ("rejected", i(self.rejected as i64)),
            ("shed", i(self.shed as i64)),
            ("retry_after_hints", i(self.retry_after_hints as i64)),
            ("retry_after_hint_mean_ms", n(self.retry_after_hint_mean_ms())),
            ("quarantine_evictions", i(self.quarantine_evictions as i64)),
            ("class_requests", arr(class_requests)),
            ("interactive_p50_s", n(self.class_p50(QosClass::Interactive))),
            ("interactive_p99_s", n(self.class_p99(QosClass::Interactive))),
            ("batch_p50_s", n(self.class_p50(QosClass::Batch))),
            ("batch_p99_s", n(self.class_p99(QosClass::Batch))),
            ("best_effort_p50_s", n(self.class_p50(QosClass::BestEffort))),
            ("best_effort_p99_s", n(self.class_p99(QosClass::BestEffort))),
            ("tenant_requests", tenant_obj(&self.tenant_requests)),
            ("tenant_rejected", tenant_obj(&self.tenant_rejected)),
            ("streams_active", i(self.streams_active as i64)),
            ("stream_events", i(self.stream_events as i64)),
            ("stream_drops", i(self.stream_drops as i64)),
            ("stream_disconnects", i(self.stream_disconnects as i64)),
            ("first_votes", i(self.first_votes() as i64)),
            ("time_to_first_vote_mean_s", n(self.ttfv_mean())),
            ("time_to_first_vote_p99_s", n(self.ttfv_p99())),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 / 100.0, true);
        }
        assert!((m.p50() - 0.505).abs() < 0.01);
        assert!(m.p99() > 0.98);
        assert_eq!(m.answered, 100);
    }

    #[test]
    fn latencies_stay_bounded_under_sustained_traffic() {
        let mut m = Metrics::new();
        for i in 0..100_000u64 {
            m.record_request(i as f64 / 100_000.0, true);
        }
        assert_eq!(m.requests, 100_000);
        assert!(m.latency_samples().len() <= 4096, "recorder grew unbounded");
        assert!((m.p50() - 0.5).abs() < 0.05, "p50 {}", m.p50());
        assert!(m.p99() > 0.95, "p99 {}", m.p99());
    }

    #[test]
    fn rates() {
        let mut m = Metrics::new();
        m.record_tokens(100, 50, 10, 3);
        assert!((m.rewrite_rate() - 0.3).abs() < 1e-12);
        m.record_request(0.1, true);
        assert_eq!(m.throughput(2.0), 0.5);
        assert_eq!(m.throughput(0.0), 0.0);
    }

    #[test]
    fn occupancy_and_queue_gauges() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_batch_occupancy(), 0.0);
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.backend_calls, 2);
        assert!((m.mean_batch_occupancy() - 6.0).abs() < 1e-12);
        assert_eq!(m.occupancy.counts[4], 1);
        assert_eq!(m.occupancy.counts[8], 1);

        m.record_queue_depth(0);
        m.record_queue_depth(6);
        assert_eq!(m.queue_depth_max, 6);
        assert!((m.mean_queue_depth() - 3.0).abs() < 1e-12);

        m.record_admission_wait(0.2);
        assert!((m.mean_admission_wait() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn summary_json_parses() {
        let mut m = Metrics::new();
        m.record_request(0.2, true);
        m.record_batch(5);
        m.record_queue_depth(2);
        m.set_prefix_cache(3, 1, 0);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("requests").unwrap(), 1);
        assert!(v.get_f64("mean_latency_s").unwrap() > 0.0);
        assert_eq!(v.get_i64("backend_calls").unwrap(), 1);
        assert!((v.get_f64("mean_batch_occupancy").unwrap() - 5.0).abs() < 1e-12);
        assert_eq!(v.get_i64("queue_depth_max").unwrap(), 2);
        assert_eq!(v.get_i64("prefix_hits").unwrap(), 3);
        assert_eq!(v.get_i64("prefix_misses").unwrap(), 1);
        assert!((v.get_f64("prefix_hit_rate").unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stream_gauges_and_first_vote_reservoir() {
        let mut m = Metrics::new();
        assert_eq!(m.ttfv_mean(), 0.0);
        m.streams_active = 2;
        m.stream_events += 7;
        m.stream_drops += 3;
        m.stream_disconnects += 1;
        m.record_first_vote(0.2);
        m.record_first_vote(0.4);
        assert_eq!(m.first_votes(), 2);
        assert!((m.ttfv_mean() - 0.3).abs() < 1e-12);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("streams_active").unwrap(), 2);
        assert_eq!(v.get_i64("stream_events").unwrap(), 7);
        assert_eq!(v.get_i64("stream_drops").unwrap(), 3);
        assert_eq!(v.get_i64("stream_disconnects").unwrap(), 1);
        assert_eq!(v.get_i64("first_votes").unwrap(), 2);
        assert!((v.get_f64("time_to_first_vote_mean_s").unwrap() - 0.3).abs() < 1e-12);
        // p99 interpolates between the two samples: 0.2 + 0.99 * 0.2
        assert!((v.get_f64("time_to_first_vote_p99_s").unwrap() - 0.398).abs() < 1e-12);
    }

    #[test]
    fn shard_gauges_sum_and_makespan() {
        let mut m = Metrics::new();
        // no shards configured: model_secs is whatever was set directly
        m.model_secs = 3.0;
        assert_eq!(m.model_secs_makespan(), 3.0);
        m.init_shards(2);
        m.set_shard_clock(0, 4.0);
        m.set_shard_clock(1, 6.0);
        assert!((m.model_secs - 10.0).abs() < 1e-12, "sum is the cost");
        assert!((m.model_secs_makespan() - 6.0).abs() < 1e-12, "max is the makespan");
        m.record_shard_request(0);
        m.record_shard_request(1);
        m.record_shard_request(1);
        assert_eq!(m.shard_requests, BTreeMap::from([(0, 1), (1, 2)]));
        assert_eq!(m.total_shard_requests(), 3);
        m.set_prefix_shard_fills(3);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("shards").unwrap(), 2);
        assert!((v.get_f64("model_secs_makespan").unwrap() - 6.0).abs() < 1e-12);
        assert_eq!(v.get_i64("prefix_shard_fills").unwrap(), 3);
        assert_eq!(v.get("shard_requests").unwrap().arr().unwrap().len(), 2);
    }

    #[test]
    fn lifecycle_gauges() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_drain_secs(), 0.0);
        m.record_steals(3);
        m.record_steals(2);
        m.record_shard_added();
        m.record_shard_removed(0.2);
        m.record_shard_removed(0.4);
        m.record_migration(1024);
        m.record_migration(512);
        m.record_scale_event(true);
        m.record_scale_event(false);
        assert_eq!(m.steals, 5);
        assert_eq!((m.shards_added, m.shards_removed, m.drains), (1, 2, 2));
        assert_eq!((m.migrations, m.migration_bytes), (2, 1536));
        assert_eq!((m.scale_ups, m.scale_downs), (1, 1));
        assert!((m.mean_drain_secs() - 0.3).abs() < 1e-12);
        assert!((m.drain_secs_max - 0.4).abs() < 1e-12);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("steals").unwrap(), 5);
        assert_eq!(v.get_i64("shards_added").unwrap(), 1);
        assert_eq!(v.get_i64("shards_removed").unwrap(), 2);
        assert_eq!(v.get_i64("migrations").unwrap(), 2);
        assert_eq!(v.get_i64("migration_bytes").unwrap(), 1536);
        assert_eq!(v.get_i64("scale_ups").unwrap(), 1);
        assert_eq!(v.get_i64("scale_downs").unwrap(), 1);
        assert!((v.get_f64("drain_mean_s").unwrap() - 0.3).abs() < 1e-12);
        assert!((v.get_f64("drain_max_s").unwrap() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn retired_shards_fold_into_accumulators_and_free_their_columns() {
        // week-long autoscale churn: per-id state must stay bounded by
        // the LIVE shard count while the cost/makespan gauges keep
        // counting the retired shards' work
        let mut m = Metrics::new();
        m.init_shards(1);
        m.set_shard_clock(0, 2.0);
        m.record_shard_request(0);
        for id in 1..=100usize {
            m.set_shard_clock(id, id as f64 * 0.01);
            m.record_shard_request(id);
            m.retire_shard(id);
        }
        assert_eq!(m.shard_clocks.len(), 1, "dead-id columns were retained");
        assert_eq!(m.shard_requests.len(), 1);
        assert_eq!(m.total_shard_requests(), 101);
        // cost = live 2.0 + sum of retired clocks
        let retired: f64 = (1..=100).map(|i| i as f64 * 0.01).sum();
        assert!((m.model_secs - (2.0 + retired)).abs() < 1e-9);
        // makespan = max(live, retired floor) = 2.0 here
        assert!((m.model_secs_makespan() - 2.0).abs() < 1e-12);
        // a slow retired shard keeps flooring the makespan
        m.set_shard_clock(7, 9.0);
        m.retire_shard(7);
        assert!((m.model_secs_makespan() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn fault_tolerance_counters_surface_in_summary() {
        let mut m = Metrics::new();
        m.shard_crashes += 1;
        m.runs_recovered += 2;
        m.runs_replayed += 1;
        m.retries += 3;
        m.quarantined += 1;
        m.deadline_expirations += 2;
        m.degraded_replies += 2;
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("shard_crashes").unwrap(), 1);
        assert_eq!(v.get_i64("runs_recovered").unwrap(), 2);
        assert_eq!(v.get_i64("runs_replayed").unwrap(), 1);
        assert_eq!(v.get_i64("retries").unwrap(), 3);
        assert_eq!(v.get_i64("quarantined").unwrap(), 1);
        assert_eq!(v.get_i64("deadline_expirations").unwrap(), 2);
        assert_eq!(v.get_i64("degraded_replies").unwrap(), 2);
    }

    #[test]
    fn prefix_gauges() {
        let mut m = Metrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.set_prefix_cache(2, 2, 1);
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(m.prefix_evictions, 1);
        assert!((m.prefix_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spill_tier_gauges_and_prefill_fold() {
        let mut m = Metrics::new();
        assert_eq!(m.prefix_spill_hit_rate(), 0.0, "no misses reads 0");
        m.set_prefix_cache(6, 4, 3);
        m.set_prefix_spill(3, 2, 1);
        m.set_prefix_tier_gauges(5, 1200, 7, 900);
        assert!((m.prefix_spill_hit_rate() - 0.5).abs() < 1e-12, "2 of 4 misses promoted");
        m.set_shard_prefill_tokens(0, 100);
        m.set_shard_prefill_tokens(1, 40);
        assert_eq!(m.prefill_prompt_tokens(), 140);
        // a retired shard's ingest keeps counting, its column is freed
        m.retire_shard(1);
        m.set_shard_prefill_tokens(0, 110);
        assert_eq!(m.prefill_prompt_tokens(), 150);
        assert!(!m.shard_prefill_tokens.contains_key(&1));
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("prefix_spills").unwrap(), 3);
        assert_eq!(v.get_i64("prefix_promotes").unwrap(), 2);
        assert_eq!(v.get_i64("prefix_warm_hits").unwrap(), 1);
        assert!((v.get_f64("prefix_spill_hit_rate").unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(v.get_i64("prefix_hot_entries").unwrap(), 5);
        assert_eq!(v.get_i64("prefix_hot_bytes").unwrap(), 1200);
        assert_eq!(v.get_i64("prefix_spill_entries").unwrap(), 7);
        assert_eq!(v.get_i64("prefix_spill_bytes").unwrap(), 900);
        assert_eq!(v.get_i64("prefill_prompt_tokens").unwrap(), 150);
    }

    #[test]
    fn per_class_latency_reservoirs() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_request_class(i as f64 / 100.0, true, QosClass::Interactive);
            m.record_request_class(2.0 + i as f64 / 100.0, true, QosClass::Batch);
        }
        assert_eq!(m.requests, 200, "class recording feeds the global gauges too");
        assert!((m.class_p50(QosClass::Interactive) - 0.5).abs() < 0.05);
        assert!(m.class_p99(QosClass::Batch) > 2.9);
        assert_eq!(m.class_p50(QosClass::BestEffort), 0.0, "empty class reads 0");
        assert_eq!(m.class_requests, [100, 100, 0]);
        let v = m.summary_json(1.0);
        assert!(v.get_f64("interactive_p99_s").unwrap() > 0.9);
        assert!(v.get_f64("batch_p50_s").unwrap() > 2.0);
        assert_eq!(v.get("class_requests").unwrap().arr().unwrap().len(), 3);
    }

    #[test]
    fn reject_and_shed_counters_with_hints() {
        let mut m = Metrics::new();
        m.record_reject(Some("hot"), false, 200);
        m.record_reject(Some("hot"), false, 400);
        m.record_reject(None, true, 600);
        assert_eq!((m.rejected, m.shed, m.retry_after_hints), (2, 1, 3));
        assert!((m.retry_after_hint_mean_ms() - 400.0).abs() < 1e-12);
        m.record_tenant_admit(Some("hot"));
        m.record_tenant_admit(None);
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("rejected").unwrap(), 2);
        assert_eq!(v.get_i64("shed").unwrap(), 1);
        assert_eq!(v.get_i64("retry_after_hints").unwrap(), 3);
        let tr = v.get("tenant_rejected").unwrap();
        assert_eq!(tr.get_i64("hot").unwrap(), 2);
        assert_eq!(tr.get_i64("_anon").unwrap(), 1);
        let ta = v.get("tenant_requests").unwrap();
        assert_eq!(ta.get_i64("hot").unwrap(), 1);
    }

    #[test]
    fn tenant_gauges_are_cardinality_bounded() {
        let mut m = Metrics::new();
        for k in 0..1000 {
            m.record_tenant_admit(Some(&format!("tenant-{k}")));
        }
        assert!(
            m.tenant_requests.len() <= TENANT_GAUGE_CAP + 1,
            "gauge map grew unbounded: {}",
            m.tenant_requests.len()
        );
        let folded = m.tenant_requests.get(TENANT_OTHER).copied().unwrap_or(0);
        assert_eq!(folded, 1000 - TENANT_GAUGE_CAP as u64, "overflow folds into _other");
    }

    #[test]
    fn speculation_accounting_by_class() {
        let mut m = Metrics::new();
        // non-speculative runs are invisible
        m.record_speculation(ShardClass::Balanced, 0, 0, 1, false);
        assert_eq!(m.spec_depth_mean(), 0.0);
        assert_eq!(m.gamma_overall(), 0.0);
        // two runs on balanced, one on target_heavy (collapsed)
        m.record_speculation(ShardClass::Balanced, 10, 8, 4, false);
        m.record_speculation(ShardClass::Balanced, 10, 9, 6, false);
        m.record_speculation(ShardClass::TargetHeavy, 20, 4, 1, true);
        assert!((m.gamma_of_class(ShardClass::Balanced) - 0.85).abs() < 1e-12);
        assert!((m.gamma_of_class(ShardClass::TargetHeavy) - 0.2).abs() < 1e-12);
        assert_eq!(m.gamma_of_class(ShardClass::DraftHeavy), 0.0);
        assert!((m.gamma_overall() - 21.0 / 40.0).abs() < 1e-12);
        assert!((m.spec_depth_mean() - 11.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.target_only_runs, 1);
        assert_eq!(m.spec_depth_hist.counts[4], 1);
        assert_eq!(m.spec_depth_hist.counts[6], 1);
        assert_eq!(m.spec_depth_hist.counts[1], 1);
        m.gamma_migrations += 2;
        m.set_placement_shape_hits(7);
        let v = m.summary_json(1.0);
        assert!((v.get_f64("gamma_balanced").unwrap() - 0.85).abs() < 1e-12);
        assert!((v.get_f64("gamma_target_heavy").unwrap() - 0.2).abs() < 1e-12);
        assert!(v.get_f64("spec_depth_mean").unwrap() > 3.0);
        assert_eq!(v.get_i64("target_only_runs").unwrap(), 1);
        assert_eq!(v.get_i64("gamma_migrations").unwrap(), 2);
        assert_eq!(v.get_i64("placement_shape_hits").unwrap(), 7);
    }

    #[test]
    fn clock_split_folds_through_retirement() {
        let mut m = Metrics::new();
        assert_eq!(m.model_secs_split(), (0.0, 0.0));
        m.set_shard_clock_split(0, 1.0, 3.0);
        m.set_shard_clock_split(1, 0.5, 2.0);
        let (d, t) = m.model_secs_split();
        assert!((d - 1.5).abs() < 1e-12 && (t - 5.0).abs() < 1e-12);
        // retiring a shard folds its split into the accumulators
        m.retire_shard(1);
        let (d, t) = m.model_secs_split();
        assert!((d - 1.5).abs() < 1e-12 && (t - 5.0).abs() < 1e-12);
        assert!(!m.shard_clock_splits.contains_key(&1), "dead-id split retained");
        let v = m.summary_json(1.0);
        assert!((v.get_f64("model_secs_draft").unwrap() - 1.5).abs() < 1e-12);
        assert!((v.get_f64("model_secs_target").unwrap() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn quarantine_eviction_counter_surfaces() {
        let mut m = Metrics::new();
        m.quarantine_evictions += 5;
        let v = m.summary_json(1.0);
        assert_eq!(v.get_i64("quarantine_evictions").unwrap(), 5);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }
}
