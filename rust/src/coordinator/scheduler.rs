//! Cross-request continuous batching: the per-shard step-level
//! scheduling loop that multiplexes concurrent solves into shared
//! backend batches, plus the single-shard [`Scheduler::spawn`]
//! convenience wrapper over [`BackendPool`].
//!
//! # Serving & scheduling design notes
//!
//! The pre-scheduler serving path drained requests strictly FIFO: one
//! `Engine::run` held the backend until its slowest path finished, so
//! under concurrent load the batched `draft_step`/`score_step`/
//! `rewrite_step` entry points ran at batch size <= n_paths of a single
//! request. The scheduler closes that gap the way production test-time
//! -scaling stacks do — by making the *step*, not the request, the unit
//! of backend scheduling:
//!
//! * **Work items.** A [`SolveRequest`] (expression, method, seed,
//!   reply channel) enters over an mpsc channel from any number of
//!   connection handlers or bench clients — routed to one shard's
//!   channel by the pool's placement policy (`coordinator::pool`).
//!   Intake parses the problem (parse failures reply immediately) and
//!   places it in the shard's admission queue. The channel also
//!   carries already-queued or mid-solve work re-homed by drains and
//!   steals ([`ShardMsg::Job`]).
//! * **Admission / lane pool.** Each method occupies `Method::lanes()`
//!   lanes (its parallel paths; SPM methods clamped to the strategy
//!   pool, and the wire `paths` field is bounded to 1..=16 at parse
//!   time). The scheduler admits queued jobs —
//!   FIFO by default, smallest-lane-need-first under
//!   `AdmitPolicy::SmallestFirst` — while the lane pool
//!   (`SsrConfig::max_lanes`, PER SHARD) has room, and admits at least
//!   one job whenever the pool is idle so an oversized request can
//!   never wedge the queue. Admission runs again every tick, so queued
//!   problems join mid-flight the moment lanes free up. A
//!   [`Work::Resume`] job re-attaches a [`DetachedRun`] instead of
//!   starting fresh — bit-identical decisions, no re-counted request.
//! * **Tick loop.** Every tick gathers the union of active lanes across
//!   ALL in-flight [`ProblemRun`]s of this shard and issues ONE batched
//!   draft -> score -> accept|rewrite cycle (speculative lanes, each
//!   scored against its own run's tau) plus one `target_step` batch
//!   (non-speculative lanes) via `engine::step_tick`. Backends that pin
//!   lanes to their prefill cache group (PJRT) fall back to per-problem
//!   calls; the calibrated substrate batches lanes from any mix of
//!   requests up to `BackendMeta::max_batch_lanes`.
//! * **Fast-mode retirement.** A run whose stop rule fires (Fast1 /
//!   Fast2 agreement) or whose lanes all terminate retires *at the end
//!   of that tick*: it closes its paths, votes, replies, and releases
//!   its lanes — which the same tick's admission pass hands to the next
//!   queued problem. Slow requests never convoy fast ones.
//! * **Prefix reuse.** Admission opens lane groups through the shared
//!   prefix tier ([`SharedPrefixTier`], DESIGN.md §10): the problem
//!   prompt is prefilled once per shard that serves it and lanes are
//!   forked from it; a repeated problem (pass@k, re-run suites,
//!   benchmark sweeps) skips prompt prefill entirely. Hit / miss /
//!   shard-fill / eviction gauges surface through `{"op":"stats"}`.
//! * **Observability.** Every batched step call records its lane count
//!   (`Metrics::record_batch` -> mean/histogram batch occupancy), every
//!   admission pass samples queue depth, and every admitted job records
//!   its admission wait and shard. `{"op":"stats"}` surfaces all of it.
//! * **Gamma-driven class rebalancing.** With a heterogeneous fleet
//!   (`--shard-classes`, DESIGN.md §15) every shard watches its runs'
//!   per-run acceptance EWMA after each retire pass: a run whose gamma
//!   collapsed below the break-even band (or that went target-only)
//!   migrates to a `target_heavy` shard, and a high-gamma run stuck on
//!   one migrates to `draft_heavy`/`balanced` capacity — through the
//!   same detach/attach machinery as stealing, so decisions never
//!   change. Ping-pong is bounded three ways: a run must breach the
//!   threshold for `GAMMA_BREACH_TICKS` consecutive ticks
//!   (hysteresis), each run has a lifetime budget of
//!   `MAX_CLASS_MOVES` class migrations, and a shard moves at most one
//!   run per tick.
//! * **Work stealing & live migration.** With `steal_threshold > 0`, a
//!   shard whose occupancy sat below the threshold (for a full tick,
//!   or instantly when fully idle) and whose own queue is empty pulls
//!   queued-but-unstarted jobs from the most-loaded shard's admission
//!   queue. When nothing is queued anywhere but a peer's lanes are
//!   saturated, the thief posts a *shed request* and the victim
//!   detaches whole in-flight runs ([`ProblemRun::detach`]) at its
//!   next step boundary and hands them over — run migration, not just
//!   queue rebalancing (DESIGN.md §12). Idle steal-mode shards park on
//!   the pool's [`WorkSignal`] condvar (woken by every enqueue)
//!   instead of polling, so an idle pool burns no CPU.
//! * **Shutdown / drain.** A shard's loop exits once every submitter
//!   handle is dropped AND its queue and lane pool are empty — in-
//!   flight work always drains, and the drain releases the shard's
//!   handles in the shared tier. `PoolHandle::remove_shard` drains one
//!   shard this same way (its channel closes) while the rest of the
//!   pool keeps serving; with `migration` enabled the draining shard
//!   re-homes its in-flight runs on the survivors at the next step
//!   boundary, so the drain completes in O(one step) instead of O(one
//!   solve).
//!
//! Determinism: the run seed is a pure function of (request seed,
//! prompt) — NOT of admission order, shard placement, work stealing, or
//! migration — and the calibrated substrate's per-problem draws are
//! derived streams (`backend::calibrated`) while migrated lanes carry
//! their sampling-stream positions with them (`LaneSnapshot`), so
//! identical requests reproduce identical answers on any shard of any
//! pool size, even mid-solve re-homed (the equivalence tests pin this).
//!
//! [`WorkSignal`]: super::pool::WorkSignal

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::QosClass;
use super::engine::{step_tick, DetachedRun, Method, ProblemRun};
use super::events::ReplySink;
use super::metrics::Metrics;
use super::pool::{BackendPool, ShardRegistry, ShedRequest, WorkSignal};
use super::prefix::{PrefixProvider, ShardPrefix, SharedPrefixTier};
use crate::backend::{severity_of, Backend, FaultSeverity};
use crate::config::{AdmitPolicy, ShardClass, SsrConfig};
use crate::runtime::Vocab;
use crate::util::hash;
use crate::util::json::{self, Value};
use crate::util::sync::lock_ok;
use crate::workload::problems::problem_from_text;
use crate::workload::Problem;

/// Safety timeout for an idle steal-mode shard parked on the pool's
/// enqueue signal: normally it wakes on the condvar the moment anything
/// is enqueued anywhere; the timeout only bounds shutdown latency (and
/// pathological lost-wakeup bugs). ~20 wakeups/s when truly idle.
const IDLE_PARK: Duration = Duration::from_millis(50);

/// The submitter side of the pool — kept under its historical name;
/// see [`coordinator::pool::PoolHandle`](super::pool::PoolHandle).
pub use super::pool::PoolHandle as SchedulerHandle;

/// One queued unit of work: a solve request and its reply slot.
pub struct SolveRequest {
    pub expr: String,
    pub method: Method,
    pub seed: u64,
    /// per-request deadline in milliseconds, enforced at step
    /// boundaries; 0 = use the config default (`SsrConfig::deadline_ms`,
    /// itself 0 = none). On expiry the run finalizes from the votes
    /// collected so far and the reply carries `degraded:true`
    pub deadline_ms: u64,
    /// priority class from the `class` wire field (DESIGN.md §14):
    /// weighted dequeue order and per-class latency gauges only — run
    /// decisions never depend on it (determinism contract)
    pub class: QosClass,
    /// terminal reply sender plus the optional stream tap
    /// ([`ReplySink`]); a plain `mpsc::Sender` converts with `.into()`
    pub reply: ReplySink,
}

/// What travels over a shard's channel: a wire request to parse, or an
/// already-parsed (possibly mid-solve) job re-homed by a drain or a
/// shed handoff.
pub(crate) enum ShardMsg {
    Solve(SolveRequest),
    Job(QueuedJob),
}

/// Lanes a method will occupy once admitted — the admission and
/// placement currency. SPM methods clamp their path count to the
/// strategy pool, so an unclamped estimate could overstate the need and
/// head-of-line block the queue on capacity the job would never use.
pub(crate) fn lane_estimate(method: Method, pool_size: usize) -> usize {
    match method {
        Method::Parallel { n, spm: true } | Method::Ssr { n, .. } => n.min(pool_size),
        m => m.lanes(),
    }
}

/// Everything one shard's loop needs besides its backend: its identity,
/// the shared prefix tier, its own load gauge / admission queue /
/// draining flag / shed inbox (shared with the pool registry so submit,
/// steal, shed and drain can see them), the pool-wide enqueue signal,
/// and a weak registry reference for picking steal victims and
/// migration targets. Weak, because a strong reference from the shard
/// thread would keep every shard's channel sender alive and the pool
/// could never drain by dropping its handles.
pub(crate) struct ShardCtx {
    pub shard: usize,
    /// the shard's hardware class (DESIGN.md §15): scales the lane
    /// pool (`lane_factor`) and anchors gamma-driven rebalancing;
    /// `Balanced` for uniform pools
    pub class: ShardClass,
    pub tier: Arc<SharedPrefixTier>,
    pub load: Arc<AtomicU64>,
    pub queue: Arc<Mutex<VecDeque<QueuedJob>>>,
    pub draining: Arc<AtomicBool>,
    pub shed: Arc<Mutex<Vec<ShedRequest>>>,
    /// admitted-run re-admission records, shared with the pool
    /// supervisor for crash recovery (see [`RunTicket`])
    pub tickets: TicketMap,
    pub signal: Arc<WorkSignal>,
    pub registry: Weak<ShardRegistry>,
}

impl ShardCtx {
    /// One request reached a terminal reply: return its lane estimate
    /// to the load gauge (advisory placement signal — Relaxed is fine).
    fn done(&self, est: usize) {
        self.load.fetch_sub(est as u64, Ordering::Relaxed);
    }

    /// Stage a re-admission ticket for a newly admitted run; returns
    /// its pool-unique id.
    fn stage_ticket(&self, ticket: RunTicket) -> u64 {
        let id = NEXT_TICKET.fetch_add(1, Ordering::Relaxed);
        lock_ok(&self.tickets).insert(id, ticket);
        id
    }

    /// A run reached a terminal reply or left this shard (detach):
    /// drop its re-admission ticket.
    fn clear_ticket(&self, id: u64) {
        lock_ok(&self.tickets).remove(&id);
    }
}

/// One parsed, admitted-but-unstarted unit of work. Lives in a shard's
/// *shared* admission queue so an idle shard can steal it; a stolen
/// fresh job re-derives its run state from the placement-invariant run
/// seed at admission, and a migrated job carries its mid-solve state
/// with it — decisions are identical wherever either lands.
pub(crate) struct QueuedJob {
    /// submit-side lane estimate (admission weight AND the exact amount
    /// to return to the owning shard's load gauge on the terminal
    /// reply; stealing and migration move it between gauges)
    pub(crate) lanes: usize,
    /// original submission time — the reply's `latency_s`/`queue_wait_s`
    /// baseline; survives steals and migrations unchanged
    pub(crate) enqueued: Instant,
    /// when this job (re-)entered a queue — the head-of-line wait the
    /// autoscaler samples. Re-stamped when a detached run is re-queued,
    /// so a migrated long-running solve doesn't masquerade as a
    /// 30-second admission backlog and flap the policy
    pub(crate) queued_at: Instant,
    /// absolute deadline (derived once at intake from the wire field /
    /// config default); survives steals, migrations and crash recovery
    pub(crate) deadline: Option<Instant>,
    /// shard crashes this work has already survived (crash-recovery
    /// retry budget, DESIGN.md §13); 0 for never-crashed work
    pub(crate) retries: u32,
    /// priority class: weighted dequeue + class-aware steal/shed order;
    /// survives steals, migrations and crash recovery
    pub(crate) class: QosClass,
    pub(crate) work: Work,
}

/// The two kinds of queued work: a not-yet-started solve, and a
/// mid-solve run detached from another shard (live migration).
pub(crate) enum Work {
    Fresh {
        problem: Problem,
        method: Method,
        seed: u64,
        reply: ReplySink,
    },
    Resume {
        run: DetachedRun,
        method: Method,
        gold: i64,
        reply: ReplySink,
    },
}

struct InFlight {
    run: ProblemRun,
    method: Method,
    gold: i64,
    est: usize,
    enqueued: Instant,
    admitted: Instant,
    /// key of this run's [`RunTicket`] in the shard's ticket map;
    /// removed on every terminal reply and on detach
    ticket: u64,
    deadline: Option<Instant>,
    retries: u32,
    class: QosClass,
    /// the deadline expired and the run was force-stopped: the reply
    /// carries `degraded:true`
    degraded: bool,
    /// consecutive ticks this run's gamma EWMA has sat on the wrong
    /// side of the class-rebalancing thresholds (hysteresis: a single
    /// noisy window must not trigger a migration)
    gamma_breach: u32,
    reply: ReplySink,
}

/// Re-admission record for one *admitted* run — the state the pool
/// supervisor needs to rebuild the request if this shard's thread dies
/// (DESIGN.md §13). Queued-but-unstarted jobs survive a crash in the
/// slot's shared queue; admitted runs live on the panicking stack, so
/// everything needed to re-admit them is staged here, in an `Arc` map
/// shared with the pool, *before* the run takes its first step:
///
/// * `checkpoint` — a step-boundary [`DetachedRun`] when one is
///   available (a migrated-in run re-admits bit-identically from it);
/// * otherwise `problem` + `wire_seed` — the placement-invariant run
///   seed replays the whole run from scratch with identical decisions
///   (the same determinism contract work stealing relies on).
///
/// The reply sender is a clone, so the supervisor can still answer the
/// client after the original sender died with the shard thread.
pub(crate) struct RunTicket {
    pub(crate) problem: Option<Problem>,
    pub(crate) method: Method,
    pub(crate) wire_seed: u64,
    pub(crate) gold: i64,
    pub(crate) est: usize,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
    pub(crate) retries: u32,
    pub(crate) class: QosClass,
    pub(crate) checkpoint: Option<DetachedRun>,
    pub(crate) reply: ReplySink,
}

/// Per-shard map of admitted-run tickets, shared between the shard's
/// loop (insert/remove) and the pool supervisor (drain on crash).
pub(crate) type TicketMap = Arc<Mutex<HashMap<u64, RunTicket>>>;

/// Pool-wide unique ticket ids (uniqueness must survive re-admission
/// onto other shards).
static NEXT_TICKET: AtomicU64 = AtomicU64::new(1);

pub struct Scheduler;

impl Scheduler {
    /// Spawn a single-shard scheduler (the historical entry point;
    /// multi-shard serving goes through [`BackendPool::spawn`]).
    /// `backend_factory` runs on the shard thread (PJRT wrapper types
    /// are not Send). Returns the submitter handle plus the join handle
    /// (the server ignores the latter; benches join it to flush final
    /// clock metrics).
    pub fn spawn<F>(
        cfg: SsrConfig,
        vocab: Vocab,
        metrics: Arc<Mutex<Metrics>>,
        backend_factory: F,
    ) -> Result<(SchedulerHandle, std::thread::JoinHandle<()>)>
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let mut cfg = cfg;
        cfg.shards = 1;
        let cell = Mutex::new(Some(backend_factory));
        let (handle, mut joins) = BackendPool::spawn(cfg, vocab, metrics, move |_shard| {
            let f = cell
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("single-shard factory already consumed"))?;
            f()
        })?;
        let join = joins.pop().expect("one shard spawns one thread");
        Ok((handle, join))
    }
}

/// Index of the next queue entry the admission policy would admit.
///
/// Class-weighted dequeue (DESIGN.md §14): `tick` walks a
/// weighted-round-robin cycle over `weights` =
/// `[interactive, batch, best_effort]`, so while both queues are
/// non-empty each class is guaranteed its weight's share of admissions
/// — `batch` cannot starve `interactive` and vice versa. Within the
/// preferred class the configured `AdmitPolicy` applies (FIFO /
/// smallest-first); when the preferred class has nothing queued, the
/// slot falls through in priority order. Dequeue order affects latency
/// only, never run decisions (the determinism contract).
fn pick_next(
    queue: &VecDeque<QueuedJob>,
    policy: AdmitPolicy,
    weights: [u64; 3],
    tick: u64,
) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    let pick_in_class = |class: Option<usize>| -> Option<usize> {
        let eligible =
            |j: &QueuedJob| class.map(|c| j.class.idx() == c).unwrap_or(true);
        match policy {
            AdmitPolicy::Fifo => queue.iter().position(eligible),
            AdmitPolicy::SmallestFirst => queue
                .iter()
                .enumerate()
                .filter(|(_, j)| eligible(j))
                .min_by_key(|(i, j)| (j.lanes, *i))
                .map(|(i, _)| i),
        }
    };
    let total: u64 = weights.iter().sum();
    if total == 0 {
        return pick_in_class(None);
    }
    let slot = tick % total;
    let preferred = if slot < weights[0] {
        0
    } else if slot < weights[0] + weights[1] {
        1
    } else {
        2
    };
    // preferred class first, then fall through in priority order
    for class in [preferred, 0, 1, 2] {
        if let Some(i) = pick_in_class(Some(class)) {
            return Some(i);
        }
    }
    None
}

fn intake(
    msg: ShardMsg,
    cfg: &SsrConfig,
    vocab: &Vocab,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    match msg {
        ShardMsg::Solve(req) => {
            let lanes = lane_estimate(req.method, cfg.pool_size);
            match problem_from_text(vocab, &req.expr) {
                Ok(problem) => {
                    let now = Instant::now();
                    // wire deadline wins over the config default; both
                    // 0 = no deadline. Resolved to an absolute instant
                    // once, so steals / migrations / crash recovery
                    // can't extend it
                    let dl_ms =
                        if req.deadline_ms > 0 { req.deadline_ms } else { cfg.deadline_ms };
                    let deadline = (dl_ms > 0).then(|| now + Duration::from_millis(dl_ms));
                    lock_ok(&ctx.queue).push_back(QueuedJob {
                        lanes,
                        enqueued: now,
                        queued_at: now,
                        deadline,
                        retries: 0,
                        class: req.class,
                        work: Work::Fresh {
                            problem,
                            method: req.method,
                            seed: req.seed,
                            reply: req.reply,
                        },
                    });
                }
                Err(e) => {
                    lock_ok(metrics).errors += 1;
                    ctx.done(lanes);
                    let _ = req.reply.send(Err(e));
                }
            }
        }
        // already parsed (drain re-placement) or mid-solve (migration):
        // straight into the admission queue
        ShardMsg::Job(job) => lock_ok(&ctx.queue).push_back(job),
    }
}

/// Close a retired run and render the reply object (the wire shape the
/// server forwards verbatim; see the protocol doc in `server.rs`).
fn finish_job(
    backend: &mut dyn Backend,
    f: &mut InFlight,
    metrics: &Arc<Mutex<Metrics>>,
    shard_class: ShardClass,
) -> Result<Value> {
    let r = f.run.finish(backend)?;
    let latency = f.enqueued.elapsed().as_secs_f64();
    let queue_wait = f.admitted.duration_since(f.enqueued).as_secs_f64();
    {
        let mut m = lock_ok(metrics);
        m.record_request_class(latency, r.answer().is_some(), f.class);
        m.record_tokens(r.draft_tokens, r.target_tokens, r.steps, r.rewrites);
        // speculation accounting (DESIGN.md §15): the run's acceptance
        // ledger lands on the class of the shard that RETIRED it — a
        // migrated run is attributed where it finished
        m.record_speculation(shard_class, r.proposed, r.accepted, r.spec_depth, r.target_only);
        if f.degraded {
            m.degraded_replies += 1;
        }
    }
    Ok(json::obj(vec![
        ("ok", Value::Bool(true)),
        // deadline expired mid-solve: the answer is the vote over
        // whatever paths had finished (possibly null) — degraded, not
        // an error (DESIGN.md §13)
        ("degraded", Value::Bool(f.degraded)),
        ("answer", r.answer().map(json::i).unwrap_or(Value::Null)),
        ("gold", json::i(f.gold)),
        ("correct", Value::Bool(r.answer() == Some(f.gold))),
        ("method", json::s(f.method.name())),
        ("steps", json::i(r.steps as i64)),
        ("rewrites", json::i(r.rewrites as i64)),
        ("draft_tokens", json::i(r.draft_tokens as i64)),
        ("target_tokens", json::i(r.target_tokens as i64)),
        ("latency_s", json::n(latency)),
        ("queue_wait_s", json::n(queue_wait)),
        // speculation telemetry (DESIGN.md §15): lifetime acceptance
        // rate (null when the run never speculated) and the window
        // depth the controller had settled on at retirement
        ("gamma", r.gamma.map(json::n).unwrap_or(Value::Null)),
        ("spec_depth", json::i(r.spec_depth as i64)),
        ("target_only", Value::Bool(r.target_only)),
    ]))
}

/// Detach one in-flight run into a migratable Resume job. On export
/// failure the request is failed (its lanes were closed by the failed
/// detach) — never silently dropped.
fn detach_job(
    backend: &mut dyn Backend,
    f: InFlight,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) -> Option<(QueuedJob, u64)> {
    let InFlight {
        run, method, gold, est, enqueued, ticket, deadline, retries, class, reply, ..
    } = f;
    ctx.clear_ticket(ticket);
    match run.detach(backend) {
        Ok(d) => {
            let bytes = d.approx_bytes();
            let job = QueuedJob {
                lanes: est,
                enqueued,
                queued_at: Instant::now(),
                deadline,
                retries,
                class,
                work: Work::Resume { run: d, method, gold, reply },
            };
            Some((job, bytes))
        }
        Err(e) => {
            lock_ok(metrics).errors += 1;
            ctx.done(est);
            let _ = reply.send(Err(e));
            None
        }
    }
}

/// Re-admit a job this shard failed to hand off (no survivor / thief
/// gone): Resume jobs re-attach immediately, Fresh jobs re-queue.
fn take_back(
    backend: &mut dyn Backend,
    job: QueuedJob,
    inflight: &mut Vec<InFlight>,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    let QueuedJob { lanes, enqueued, deadline, retries, class, work, .. } = job;
    match work {
        Work::Resume { run, method, gold, reply } => {
            let checkpoint = run.clone();
            match ProblemRun::attach(run, backend) {
                Ok(run) => {
                    let ticket = ctx.stage_ticket(RunTicket {
                        problem: None,
                        method,
                        wire_seed: 0,
                        gold,
                        est: lanes,
                        enqueued,
                        deadline,
                        retries,
                        class,
                        checkpoint: Some(checkpoint),
                        reply: reply.clone(),
                    });
                    inflight.push(InFlight {
                        run,
                        method,
                        gold,
                        est: lanes,
                        enqueued,
                        admitted: Instant::now(),
                        ticket,
                        deadline,
                        retries,
                        class,
                        degraded: false,
                        gamma_breach: 0,
                        reply,
                    });
                }
                Err(e) => {
                    lock_ok(metrics).errors += 1;
                    ctx.done(lanes);
                    let _ = reply.send(Err(e));
                }
            }
        }
        work @ Work::Fresh { .. } => {
            lock_ok(&ctx.queue).push_back(QueuedJob {
                lanes,
                enqueued,
                queued_at: Instant::now(),
                deadline,
                retries,
                class,
                work,
            });
        }
    }
}

/// Drain-via-migration: detach every in-flight run at this step
/// boundary and re-home it on the survivors. Queued stragglers that
/// raced into the closing channel are re-placed too. Falls back to
/// local completion when no survivor accepts (full pool shutdown).
fn migrate_out(
    backend: &mut dyn Backend,
    inflight: &mut Vec<InFlight>,
    reg: &Arc<ShardRegistry>,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    let runs: Vec<InFlight> = inflight.drain(..).collect();
    for f in runs {
        let est = f.est;
        let Some((job, bytes)) = detach_job(backend, f, metrics, ctx) else { continue };
        ctx.load.fetch_sub(est as u64, Ordering::Relaxed);
        match reg.resubmit(job) {
            Ok(()) => {
                lock_ok(metrics).record_migration(bytes);
            }
            Err(job) => {
                ctx.load.fetch_add(est as u64, Ordering::Relaxed);
                take_back(backend, job, inflight, metrics, ctx);
            }
        }
    }
    let mut queued: VecDeque<QueuedJob> = {
        let mut q = lock_ok(&ctx.queue);
        std::mem::take(&mut *q)
    };
    while let Some(job) = queued.pop_front() {
        let est = job.lanes as u64;
        ctx.load.fetch_sub(est, Ordering::Relaxed);
        if let Err(job) = reg.resubmit(job) {
            // no survivors: serve this and the rest ourselves after all
            ctx.load.fetch_add(est, Ordering::Relaxed);
            let mut q = lock_ok(&ctx.queue);
            q.push_back(job);
            q.append(&mut queued);
            break;
        }
    }
}

/// Serve thieves' shed requests: detach the most recently admitted
/// unfinished runs (least sunk context on this shard) and hand them
/// directly to the requesting shard. Two convergence guards: the
/// victim always keeps at least one run (the pool cannot ping-pong its
/// last job around), and it grants at most HALF its current lanes per
/// request, so one handoff moves toward balance instead of inverting
/// the imbalance and bouncing back.
fn shed_to_thieves(
    backend: &mut dyn Backend,
    inflight: &mut Vec<InFlight>,
    reg: &Arc<ShardRegistry>,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    let reqs: Vec<ShedRequest> = {
        let mut s = lock_ok(&ctx.shed);
        if s.is_empty() {
            return;
        }
        s.drain(..).collect()
    };
    for r in reqs {
        let total_lanes: usize = inflight.iter().map(|f| f.run.lanes()).sum();
        let budget = r.lanes.min(total_lanes / 2);
        let mut granted = 0usize;
        while inflight.len() > 1 {
            // prefer shedding the lowest QoS class first (best_effort,
            // then batch, then interactive): moving a run costs it one
            // detach/attach round-trip of latency, so the disruption
            // lands on the class with the loosest latency contract.
            // Within a class, still the most recently admitted run
            // (least sunk context on this shard).
            let Some(pos) = [QosClass::BestEffort, QosClass::Batch, QosClass::Interactive]
                .iter()
                .find_map(|c| {
                    inflight.iter().rposition(|f| f.class == *c && !f.run.is_done())
                })
            else {
                break;
            };
            // the cap is checked BEFORE detaching: a whole-run grant
            // that would overshoot the half-lanes budget is refused,
            // never rounded up (overshooting would invert the
            // imbalance and bounce the run back)
            let lanes = inflight[pos].run.lanes();
            if granted + lanes.max(1) > budget {
                break;
            }
            let f = inflight.remove(pos);
            let est = f.est;
            let Some((job, bytes)) = detach_job(backend, f, metrics, ctx) else { continue };
            ctx.load.fetch_sub(est as u64, Ordering::Relaxed);
            match reg.send_to(r.thief, job) {
                Ok(()) => {
                    granted += lanes.max(1);
                    lock_ok(metrics).record_migration(bytes);
                }
                Err(job) => {
                    // thief is gone or draining: take the run back
                    ctx.load.fetch_add(est as u64, Ordering::Relaxed);
                    take_back(backend, job, inflight, metrics, ctx);
                    break;
                }
            }
        }
    }
}

/// A run's gamma EWMA below this on a non-target-heavy shard marks it
/// collapsed: its windows are mostly rewrites, so it wants target-cheap
/// capacity (DESIGN.md §15).
const GAMMA_COLLAPSE: f64 = 0.3;
/// A run's gamma EWMA above this on a target-heavy shard marks it
/// draft-friendly: it is paying the target-heavy draft surcharge for
/// verification passes it almost never needs.
const GAMMA_RICH: f64 = 0.85;
/// Windows observed before the EWMA is trusted for placement at all.
const GAMMA_MIN_SAMPLES: u64 = 3;
/// Consecutive ticks a run must breach a threshold before it migrates
/// (hysteresis against single noisy windows).
const GAMMA_BREACH_TICKS: u32 = 3;
/// Lifetime cap on gamma-driven class migrations per run: with the
/// hysteresis this bounds ping-pong even when a run's gamma straddles a
/// threshold for its whole life.
const MAX_CLASS_MOVES: u32 = 2;

/// Gamma-driven class rebalancing (DESIGN.md §15): move at most ONE
/// misplaced run per tick to a shard class that matches its observed
/// acceptance rate, through the same detach/attach machinery as work
/// stealing — so the migrated run's decisions are bit-identical, only
/// its clock placement changes. Breach counters for every other
/// misplaced run keep accumulating, so a backlog drains over successive
/// ticks without ever bursting the migration channel.
fn rebalance_by_gamma(
    backend: &mut dyn Backend,
    inflight: &mut Vec<InFlight>,
    reg: &Arc<ShardRegistry>,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    let here = ctx.class;
    let mut pick: Option<(usize, &'static [ShardClass])> = None;
    for (i, f) in inflight.iter_mut().enumerate() {
        if f.run.is_done() {
            continue;
        }
        // non-speculative runs have no gamma; immature EWMAs and runs
        // out of migration budget stay where they are
        let Some(g) = f.run.gamma_ewma() else { continue };
        if f.run.gamma_samples() < GAMMA_MIN_SAMPLES
            || f.run.class_moves() >= MAX_CLASS_MOVES
        {
            continue;
        }
        let collapsed = (g < GAMMA_COLLAPSE || f.run.target_only())
            && here != ShardClass::TargetHeavy;
        let rich = g > GAMMA_RICH && here == ShardClass::TargetHeavy;
        if collapsed || rich {
            f.gamma_breach += 1;
            if pick.is_none() && f.gamma_breach >= GAMMA_BREACH_TICKS {
                let pref: &'static [ShardClass] = if collapsed {
                    &[ShardClass::TargetHeavy]
                } else {
                    &[ShardClass::DraftHeavy, ShardClass::Balanced]
                };
                pick = Some((i, pref));
            }
        } else {
            f.gamma_breach = 0;
        }
    }
    let Some((i, pref)) = pick else { return };
    // no destination of the wanted class -> stay put (the breach
    // counter saturates and retries next tick; capacity may appear)
    let Some(dest) = reg.pick_shard_of_class(ctx.shard, pref) else { return };
    let mut f = inflight.remove(i);
    // spend the budget BEFORE detaching — the counter travels inside
    // the run's controller state, so the destination sees it
    f.run.note_class_move();
    let est = f.est;
    let Some((job, bytes)) = detach_job(backend, f, metrics, ctx) else { return };
    ctx.load.fetch_sub(est as u64, Ordering::Relaxed);
    match reg.send_to(dest, job) {
        Ok(()) => {
            let mut m = lock_ok(metrics);
            m.record_migration(bytes);
            m.gamma_migrations += 1;
        }
        Err(job) => {
            // destination vanished between pick and send: take it back
            ctx.load.fetch_add(est as u64, Ordering::Relaxed);
            take_back(backend, job, inflight, metrics, ctx);
        }
    }
}

/// Publish one step boundary's telemetry to every tapped (streamed)
/// run: a `progress` event per tick, a `token_delta` whenever the
/// run's committed-token total moved since the last announcement (the
/// tap tracks the announced total, so deltas sum to the final total
/// even across migration), plus a once-latched `first_vote` on the
/// first tick where any lane holds a parsed answer (the metric SSR's
/// early-stopping methods exist to move — time-to-first-useful-answer,
/// recorded into the `time_to_first_vote` reservoir). Each run's
/// events go down in ONE `push_batch` call, so a consumer never
/// observes half a boundary, and the tap's drop-oldest ring means a
/// slow reader costs dropped telemetry — never shard time (the
/// terminal reply rides the reply channel, not the tap).
fn emit_stream_events(inflight: &[InFlight], metrics: &Arc<Mutex<Metrics>>) {
    let mut pushed = 0u64;
    let mut dropped = 0u64;
    let mut first_votes: Vec<f64> = Vec::new();
    for f in inflight {
        let Some(tap) = f.reply.events.as_ref() else { continue };
        let p = f.run.progress();
        let mut evs = vec![json::obj(vec![
            ("event", json::s("progress")),
            ("steps", json::i(p.steps as i64)),
            ("lanes", json::i(p.lanes as i64)),
            ("finished", json::i(p.finished as i64)),
            ("gamma", p.gamma.map(json::n).unwrap_or(Value::Null)),
            ("spec_depth", json::i(p.spec_depth as i64)),
        ])];
        let delta = tap.token_delta(p.tokens);
        if delta > 0 {
            evs.push(json::obj(vec![
                ("event", json::s("token_delta")),
                ("tokens", json::i(delta as i64)),
                ("total_tokens", json::i(p.tokens as i64)),
            ]));
        }
        if p.finished > 0 && tap.mark_first_vote() {
            let elapsed = f.enqueued.elapsed().as_secs_f64();
            first_votes.push(elapsed);
            evs.push(json::obj(vec![
                ("event", json::s("first_vote")),
                ("answer", p.vote.map(json::i).unwrap_or(Value::Null)),
                ("votes", json::i(p.finished as i64)),
                ("elapsed_s", json::n(elapsed)),
            ]));
        }
        pushed += evs.len() as u64;
        dropped += tap.push_batch(evs);
    }
    if pushed > 0 {
        let mut m = lock_ok(metrics);
        m.stream_events += pushed;
        m.stream_drops += dropped;
        for t in first_votes {
            m.record_first_vote(t);
        }
    }
}

/// One shard's thread body: intake -> migrate/steal -> admit -> tick ->
/// retire -> rebalance -> shed, until every submitter is gone (channel
/// disconnected — pool shutdown or `remove_shard` drain) and all of
/// this shard's work has finished or been re-homed.
pub(crate) fn run_loop(
    backend: &mut dyn Backend,
    cfg: &SsrConfig,
    vocab: &Vocab,
    rx: &mpsc::Receiver<ShardMsg>,
    metrics: &Arc<Mutex<Metrics>>,
    ctx: &ShardCtx,
) {
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut disconnected = false;
    // the class's lane factor scales the pool: draft-heavy shards run
    // wider (cheap drafts buy lane width), so admission, stealing and
    // the autoscaler's occupancy all see the effective capacity
    let max_lanes = cfg.max_lanes.max(1).saturating_mul(ctx.class.lane_factor().max(1));
    let steal_at = cfg.steal_threshold;
    let migration = cfg.migration;
    // consecutive passes this shard sat under the steal threshold with
    // an empty queue: a partially-occupied shard must be hungry for a
    // full tick before raiding its peers (a fully idle one may steal
    // immediately — there is nothing it could be between)
    let mut hungry_ticks = 0usize;
    // monotone admit counter driving the weighted-round-robin class
    // schedule in `pick_next`: per-shard, deterministic, and only
    // affects dequeue ORDER (latency), never run outcomes
    let mut admit_tick: u64 = 0;
    // park epoch: read before each pass scans its wake sources, so an
    // enqueue signaled during/after the scan wakes the next park
    let mut seen = ctx.signal.epoch();

    loop {
        // --- intake ---------------------------------------------------
        if inflight.is_empty() && lock_ok(&ctx.queue).is_empty() {
            if disconnected {
                break;
            }
            if steal_at == 0 {
                match rx.recv() {
                    Ok(msg) => intake(msg, cfg, vocab, metrics, ctx),
                    Err(_) => disconnected = true,
                }
            } else {
                // stealing enabled: park on the pool-wide enqueue
                // signal (no CPU burned while idle; ROADMAP item —
                // this replaced a 500 µs poll loop)
                match rx.try_recv() {
                    Ok(msg) => intake(msg, cfg, vocab, metrics, ctx),
                    Err(mpsc::TryRecvError::Empty) => ctx.signal.wait_past(seen, IDLE_PARK),
                    Err(mpsc::TryRecvError::Disconnected) => disconnected = true,
                }
            }
        }
        seen = ctx.signal.epoch();
        loop {
            match rx.try_recv() {
                Ok(msg) => intake(msg, cfg, vocab, metrics, ctx),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }

        // --- drain via migration --------------------------------------
        if migration && ctx.draining.load(Ordering::Relaxed) {
            if let Some(reg) = ctx.registry.upgrade() {
                migrate_out(backend, &mut inflight, &reg, metrics, ctx);
            }
        }

        // --- work stealing --------------------------------------------
        let mut lanes_used: usize = inflight.iter().map(|f| f.run.lanes()).sum();
        if steal_at > 0 && !ctx.draining.load(Ordering::Relaxed) {
            let hungry = lanes_used < steal_at && lock_ok(&ctx.queue).is_empty();
            hungry_ticks = if hungry { hungry_ticks + 1 } else { 0 };
            if hungry && (hungry_ticks > 1 || lanes_used == 0) {
                if let Some(reg) = ctx.registry.upgrade() {
                    let stolen = reg.steal_into(ctx, max_lanes.saturating_sub(lanes_used));
                    if stolen > 0 {
                        hungry_ticks = 0;
                        lock_ok(metrics).record_steals(stolen as u64);
                    }
                }
            }
        }

        // --- admission ------------------------------------------------
        let mut admitted = 0usize;
        loop {
            let job = {
                let mut q = lock_ok(&ctx.queue);
                let Some(pos) = pick_next(&q, cfg.admission, cfg.qos.weights, admit_tick)
                else {
                    break;
                };
                let need = q[pos].lanes;
                // always admit into an idle pool so one oversized
                // request cannot wedge the queue
                if !inflight.is_empty() && lanes_used + need > max_lanes {
                    break;
                }
                q.remove(pos).expect("picked index in range")
            };
            let QueuedJob { lanes: est, enqueued, deadline, retries, class, work, .. } = job;
            admit_tick += 1;
            match work {
                Work::Fresh { problem, method, seed: wire_seed, reply } => {
                    // run seed = f(request seed, prompt): decorrelates
                    // distinct problems sharing a wire seed while
                    // staying independent of admission order, shard
                    // placement AND work stealing (equivalence tests)
                    let seed = wire_seed ^ hash::fnv1a_i32(&problem.tokens);
                    // poison runs (crashed shards past their recovery
                    // budget) are refused before touching the backend
                    if ctx
                        .registry
                        .upgrade()
                        .is_some_and(|reg| reg.is_quarantined(seed))
                    {
                        lock_ok(metrics).errors += 1;
                        ctx.done(est);
                        let _ = reply
                            .send(Err(anyhow!("run is quarantined (crashed too many shards)")));
                        continue;
                    }
                    let mut provider =
                        ShardPrefix { tier: ctx.tier.as_ref(), shard: ctx.shard };
                    match ProblemRun::start_with_cache(
                        backend,
                        cfg,
                        &problem,
                        method,
                        seed,
                        Some(&mut provider as &mut dyn PrefixProvider),
                    ) {
                        Ok(run) => {
                            lanes_used += run.lanes();
                            admitted += 1;
                            {
                                let mut m = lock_ok(metrics);
                                m.record_admission_wait(enqueued.elapsed().as_secs_f64());
                                m.record_shard_request(ctx.shard);
                            }
                            let gold = problem.answer;
                            let ticket = ctx.stage_ticket(RunTicket {
                                problem: Some(problem),
                                method,
                                wire_seed,
                                gold,
                                est,
                                enqueued,
                                deadline,
                                retries,
                                class,
                                checkpoint: None,
                                reply: reply.clone(),
                            });
                            inflight.push(InFlight {
                                run,
                                method,
                                gold,
                                est,
                                enqueued,
                                admitted: Instant::now(),
                                ticket,
                                deadline,
                                retries,
                                class,
                                degraded: false,
                                gamma_breach: 0,
                                reply,
                            });
                        }
                        Err(e) => {
                            lock_ok(metrics).errors += 1;
                            ctx.done(est);
                            let _ = reply.send(Err(e));
                        }
                    }
                }
                Work::Resume { run, method, gold, reply } => {
                    // a migrated run: re-attach its lanes and continue
                    // mid-solve. Its request was admitted (and counted)
                    // on the original shard — no re-recorded admission
                    // wait or shard-request here. The pre-attach clone
                    // is the crash-recovery checkpoint: re-admission
                    // from it is bit-identical to continuing here.
                    let checkpoint = run.clone();
                    match ProblemRun::attach(run, backend) {
                        Ok(run) => {
                            lanes_used += run.lanes();
                            admitted += 1;
                            let ticket = ctx.stage_ticket(RunTicket {
                                problem: None,
                                method,
                                wire_seed: 0,
                                gold,
                                est,
                                enqueued,
                                deadline,
                                retries,
                                class,
                                checkpoint: Some(checkpoint),
                                reply: reply.clone(),
                            });
                            inflight.push(InFlight {
                                run,
                                method,
                                gold,
                                est,
                                enqueued,
                                admitted: Instant::now(),
                                ticket,
                                deadline,
                                retries,
                                class,
                                degraded: false,
                                gamma_breach: 0,
                                reply,
                            });
                        }
                        Err(e) => {
                            lock_ok(metrics).errors += 1;
                            ctx.done(est);
                            let _ = reply.send(Err(e));
                        }
                    }
                }
            }
        }
        // record observability gauges only on passes that carry work, so
        // an idle loop doesn't flood the queue-depth samples
        if admitted > 0 || !inflight.is_empty() {
            let ts = ctx.tier.stats();
            let depth = lock_ok(&ctx.queue).len();
            let mut m = lock_ok(metrics);
            m.record_queue_depth(depth);
            m.set_prefix_cache(ts.hits, ts.misses, ts.evictions);
            m.set_prefix_shard_fills(ts.shard_fills);
            m.set_prefix_spill(ts.spills, ts.promotes, ts.warm_hits);
            m.set_prefix_tier_gauges(
                ctx.tier.len(),
                ctx.tier.bytes(),
                ctx.tier.spill_entries(),
                ctx.tier.spill_bytes(),
            );
        }

        if inflight.is_empty() {
            continue; // queue is empty too -> back to blocking intake
        }

        // --- deadline enforcement (step-boundary granularity) ---------
        let now = Instant::now();
        for f in inflight.iter_mut() {
            if !f.degraded && f.deadline.is_some_and(|d| now >= d) {
                // graceful degradation: stop drafting; the retire pass
                // below finalizes from the votes collected so far and
                // the reply carries degraded:true (DESIGN.md §13)
                f.run.force_stop();
                f.degraded = true;
                lock_ok(metrics).deadline_expirations += 1;
            }
        }

        // --- one shared step tick -------------------------------------
        let tick = {
            let mut runs: Vec<&mut ProblemRun> =
                inflight.iter_mut().map(|f| &mut f.run).collect();
            step_tick(backend, &mut runs)
        };
        match tick {
            Ok(tick) => {
                let mut m = lock_ok(metrics);
                for lanes in tick.lanes_per_call {
                    m.record_batch(lanes);
                }
                m.retries += tick.retries;
                m.set_shard_clock(ctx.shard, backend.clock_secs());
                let (draft_s, target_s) = backend.clock_split_secs();
                m.set_shard_clock_split(ctx.shard, draft_s, target_s);
                // prompt ingest only (target + draft prompt passes):
                // suffix/spm prefills scale with lane count identically
                // cold vs warm, so this is the scalar warm restarts move
                let ps = backend.prefill_stats();
                m.set_shard_prefill_tokens(
                    ctx.shard,
                    ps.target_prompt_tokens + ps.draft_prompt_tokens,
                );
            }
            Err(e) => {
                // shard-fatal faults (substrate gone, device wedged)
                // can't be handled by failing requests: escalate to the
                // pool supervisor (catch_unwind in spawn_shard), which
                // respawns this shard and re-admits its runs from their
                // tickets on the survivors
                if severity_of(&e) == FaultSeverity::ShardFatal {
                    log::error!(
                        "shard {}: shard-fatal backend error: {e:#}",
                        ctx.shard
                    );
                    panic!("shard-fatal backend error: {e:#}");
                }
                // a lane-fatal fault mid-batch poisons every in-flight
                // problem of this shard (batched calls lose per-run
                // attribution): fail them all rather than serve wrong
                // lanes, and close their lanes so backend state doesn't
                // leak. Transient faults never reach here — step_tick
                // retries them in place.
                let msg = format!("scheduler tick failed: {e:#}");
                log::error!("shard {}: {msg}", ctx.shard);
                let mut m = lock_ok(metrics);
                for mut f in inflight.drain(..) {
                    ctx.clear_ticket(f.ticket);
                    f.run.abort(backend);
                    m.errors += 1;
                    ctx.done(f.est);
                    let _ = f.reply.send(Err(anyhow!("{msg}")));
                }
                continue;
            }
        }

        // --- stream events (step boundary) ----------------------------
        emit_stream_events(&inflight, metrics);

        // --- retire finished problems ---------------------------------
        let mut i = 0;
        while i < inflight.len() {
            if inflight[i].run.is_done() {
                let mut f = inflight.swap_remove(i);
                ctx.clear_ticket(f.ticket);
                let result = finish_job(backend, &mut f, metrics, ctx.class);
                if result.is_err() {
                    // finish bailed mid-close: close whatever it left
                    // open (abort swallows double-close errors)
                    f.run.abort(backend);
                    lock_ok(metrics).errors += 1;
                }
                ctx.done(f.est);
                let _ = f.reply.send(result);
            } else {
                i += 1;
            }
        }

        // --- gamma-driven class rebalancing ---------------------------
        if migration
            && !cfg.shard_classes.is_empty()
            && !ctx.draining.load(Ordering::Relaxed)
        {
            if let Some(reg) = ctx.registry.upgrade() {
                rebalance_by_gamma(backend, &mut inflight, &reg, metrics, ctx);
            }
        }

        // --- shed in-flight runs to requesting thieves ----------------
        if migration && !ctx.draining.load(Ordering::Relaxed) {
            if let Some(reg) = ctx.registry.upgrade() {
                shed_to_thieves(backend, &mut inflight, &reg, metrics, ctx);
            }
        }
    }
    // drain: release this shard's tier handles (clear_shard runs first
    // so drain-time demotions land in the spill counters) and flush
    // final gauges
    ctx.tier.clear_shard(ctx.shard, backend);
    let ts = ctx.tier.stats();
    let mut m = lock_ok(metrics);
    m.set_prefix_cache(ts.hits, ts.misses, ts.evictions);
    m.set_prefix_shard_fills(ts.shard_fills);
    m.set_prefix_spill(ts.spills, ts.promotes, ts.warm_hits);
    m.set_prefix_tier_gauges(
        ctx.tier.len(),
        ctx.tier.bytes(),
        ctx.tier.spill_entries(),
        ctx.tier.spill_bytes(),
    );
    m.set_shard_clock(ctx.shard, backend.clock_secs());
    let (draft_s, target_s) = backend.clock_split_secs();
    m.set_shard_clock_split(ctx.shard, draft_s, target_s);
    let ps = backend.prefill_stats();
    m.set_shard_prefill_tokens(
        ctx.shard,
        ps.target_prompt_tokens + ps.draft_prompt_tokens,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::calibrated::CalibratedBackend;
    use crate::config::StopRule;
    use crate::model::tokenizer;

    /// Spawn a calibrated-backend scheduler. When `gate` is given, the
    /// scheduler thread blocks inside the backend factory until the
    /// test releases it — so a batch of submissions is guaranteed to be
    /// in the intake channel together before the first tick (the
    /// concurrency the assertions rely on, without sleeps).
    fn spawn_test_scheduler(
        cfg: SsrConfig,
        gate: Option<mpsc::Receiver<()>>,
    ) -> (SchedulerHandle, std::thread::JoinHandle<()>, Arc<Mutex<Metrics>>) {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (handle, join) = Scheduler::spawn(
            cfg,
            tokenizer::builtin_vocab(),
            Arc::clone(&metrics),
            move || {
                if let Some(g) = gate {
                    let _ = g.recv();
                }
                Ok(Box::new(CalibratedBackend::for_suite("synth-math500", 7)?)
                    as Box<dyn Backend>)
            },
        )
        .unwrap();
        (handle, join, metrics)
    }

    fn submit(
        handle: &SchedulerHandle,
        expr: &str,
        method: Method,
        seed: u64,
    ) -> mpsc::Receiver<Result<Value>> {
        let (rtx, rrx) = mpsc::channel();
        handle
            .submit(SolveRequest {
                expr: expr.to_string(),
                method,
                seed,
                deadline_ms: 0,
                class: QosClass::default(),
                reply: rtx.into(),
            })
            .unwrap();
        rrx
    }

    #[test]
    fn concurrent_mixed_methods_all_complete_and_share_batches() {
        use crate::config::StopRule;
        let (gate_tx, gate_rx) = mpsc::channel();
        let (handle, join, metrics) =
            spawn_test_scheduler(SsrConfig::default(), Some(gate_rx));
        let methods = [
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
            Method::Baseline,
            Method::Ssr { n: 3, tau: 7, stop: StopRule::Fast2 },
            Method::SpecReason { tau: 7 },
            Method::Parallel { n: 4, spm: true },
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
        ];
        let replies: Vec<_> = methods
            .iter()
            .enumerate()
            .map(|(i, &m)| submit(&handle, &format!("{}+{}*3", i + 1, i + 2), m, i as u64))
            .collect();
        gate_tx.send(()).unwrap(); // every request is queued: open the gate
        for (i, rrx) in replies.iter().enumerate() {
            let v = rrx.recv().unwrap().unwrap();
            assert!(v.get("ok").unwrap().bool().unwrap());
            assert_eq!(v.get_i64("gold").unwrap(), (i as i64 + 1) + (i as i64 + 2) * 3);
            assert!(v.get_i64("steps").unwrap() > 0);
            assert!(v.get_f64("latency_s").unwrap() >= 0.0);
            assert!(v.get_f64("queue_wait_s").unwrap() >= 0.0);
        }
        drop(handle);
        join.join().unwrap();

        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 6);
        assert_eq!(m.errors, 0);
        assert!(m.backend_calls > 0);
        // submitted together -> in flight together -> shared batches
        // wider than any single request's lane group (max n = 5)
        assert!(
            m.occupancy.counts[6..].iter().sum::<u64>() > 0,
            "no cross-request batch observed: {:?}",
            m.occupancy.counts
        );
        assert!(m.model_secs > 0.0);
    }

    #[test]
    fn lane_pool_limits_concurrency_and_queues_waiters() {
        use crate::config::StopRule;
        let mut cfg = SsrConfig::default();
        cfg.max_lanes = 5; // one ssr-m5 at a time
        let (gate_tx, gate_rx) = mpsc::channel();
        let (handle, join, metrics) = spawn_test_scheduler(cfg, Some(gate_rx));
        let replies: Vec<_> = (0..4)
            .map(|i| {
                submit(
                    &handle,
                    "17+25*3",
                    Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
                    i,
                )
            })
            .collect();
        gate_tx.send(()).unwrap();
        for rrx in &replies {
            let v = rrx.recv().unwrap().unwrap();
            assert!(v.get("ok").unwrap().bool().unwrap());
        }
        drop(handle);
        join.join().unwrap();

        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 4);
        // serialized: no step call ever exceeded one request's 5 lanes
        assert!(
            m.occupancy.counts[6..].iter().sum::<u64>() == 0,
            "lane pool exceeded: {:?}",
            m.occupancy.counts
        );
        // and the later arrivals really queued
        assert!(m.queue_depth_max >= 1, "queue never formed");
    }

    #[test]
    fn oversized_request_still_admitted_into_idle_pool() {
        let mut cfg = SsrConfig::default();
        cfg.max_lanes = 2;
        let (handle, join, _metrics) = spawn_test_scheduler(cfg, None);
        let rrx = submit(
            &handle,
            "5+6",
            Method::Parallel { n: 4, spm: false }, // 4 lanes > pool of 2
            1,
        );
        let v = rrx.recv().unwrap().unwrap();
        assert!(v.get("ok").unwrap().bool().unwrap());
        assert_eq!(v.get_i64("gold").unwrap(), 11);
        drop(handle);
        join.join().unwrap();
    }

    #[test]
    fn smallest_first_admission_completes_mixed_load() {
        use crate::config::StopRule;
        let mut cfg = SsrConfig::default();
        cfg.max_lanes = 6;
        cfg.admission = AdmitPolicy::SmallestFirst;
        let (handle, join, metrics) = spawn_test_scheduler(cfg, None);
        let replies: Vec<_> = [
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
            Method::Baseline,
            Method::Ssr { n: 5, tau: 7, stop: StopRule::Full },
            Method::Baseline,
        ]
        .iter()
        .enumerate()
        .map(|(i, &m)| submit(&handle, "2+3", m, i as u64))
        .collect();
        for rrx in &replies {
            assert!(rrx.recv().unwrap().is_ok());
        }
        drop(handle);
        join.join().unwrap();
        assert_eq!(metrics.lock().unwrap().requests, 4);
    }

    #[test]
    fn reply_carries_speculation_telemetry() {
        use crate::config::{ShardClass, StopRule};
        let (handle, join, metrics) = spawn_test_scheduler(SsrConfig::default(), None);
        let ssr = submit(
            &handle,
            "17+25*3",
            Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
            0,
        );
        let v = ssr.recv().unwrap().unwrap();
        // speculative run: the reply surfaces its controller state
        let g = v.get_f64("gamma").unwrap();
        assert!(g > 0.0 && g <= 1.0, "gamma {g}");
        assert_eq!(v.get_i64("spec_depth").unwrap(), 1, "fixed:1 default");
        assert_eq!(v.get("target_only").unwrap(), &Value::Bool(false));
        // non-speculative run: gamma is null, not 0 (no proposals made)
        let base = submit(&handle, "2+3", Method::Baseline, 0);
        let v = base.recv().unwrap().unwrap();
        assert_eq!(v.get("gamma").unwrap(), &Value::Null);
        drop(handle);
        join.join().unwrap();
        let m = metrics.lock().unwrap();
        // the SSR run's ledger landed under the retiring shard's class
        // (classless pools default to balanced)
        assert!(m.gamma_of_class(ShardClass::Balanced) > 0.0);
        assert_eq!(m.gamma_of_class(ShardClass::TargetHeavy), 0.0);
        assert!((m.gamma_overall() - m.gamma_of_class(ShardClass::Balanced)).abs() < 1e-12);
        assert_eq!(m.target_only_runs, 0);
        assert!(m.spec_depth_mean() >= 1.0);
    }

    #[test]
    fn repeated_problems_hit_the_prefix_cache() {
        use crate::config::StopRule;
        // ISSUE acceptance: prefix-cache hit rate > 0 on a repeated
        // suite, visible in the serving stats.
        let (handle, join, metrics) = spawn_test_scheduler(SsrConfig::default(), None);
        let m = Method::Ssr { n: 3, tau: 7, stop: StopRule::Full };
        for round in 0..3u64 {
            for expr in ["17+25*3", "4+5*6"] {
                let rrx = submit(&handle, expr, m, round);
                assert!(rrx.recv().unwrap().is_ok());
            }
        }
        drop(handle);
        join.join().unwrap();
        let m = metrics.lock().unwrap();
        assert_eq!(m.requests, 6);
        // 2 distinct prompts, 6 solves: 2 misses, 4 hits
        assert_eq!(m.prefix_misses, 2, "misses {}", m.prefix_misses);
        assert_eq!(m.prefix_hits, 4, "hits {}", m.prefix_hits);
        assert!(m.prefix_hit_rate() > 0.5);
        // single shard: the tier never re-prefills anywhere else
        assert_eq!(m.prefix_shard_fills, 0);
    }

    #[test]
    fn prefix_reuse_off_never_caches() {
        let mut cfg = SsrConfig::default();
        cfg.prefix.enabled = false;
        let (handle, join, metrics) = spawn_test_scheduler(cfg, None);
        for _ in 0..3 {
            let rrx = submit(&handle, "2+3", Method::Baseline, 0);
            assert!(rrx.recv().unwrap().is_ok());
        }
        drop(handle);
        join.join().unwrap();
        let m = metrics.lock().unwrap();
        assert_eq!(m.prefix_hits, 0);
    }

    #[test]
    fn malformed_expression_replies_error_and_counts() {
        let (handle, join, metrics) = spawn_test_scheduler(SsrConfig::default(), None);
        let rrx = submit(&handle, "1+", Method::Baseline, 0);
        assert!(rrx.recv().unwrap().is_err());
        let ok = submit(&handle, "1+1", Method::Baseline, 0);
        assert!(ok.recv().unwrap().is_ok());
        drop(handle);
        join.join().unwrap();
        let m = metrics.lock().unwrap();
        assert_eq!(m.errors, 1);
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn identical_submission_sequences_are_deterministic() {
        use crate::config::StopRule;
        let answers: Vec<Vec<Option<i64>>> = (0..2)
            .map(|_| {
                let (handle, join, _m) = spawn_test_scheduler(SsrConfig::default(), None);
                let replies: Vec<_> = (0..5)
                    .map(|i| {
                        submit(
                            &handle,
                            &format!("{}+{}", 10 + i, 20 + i),
                            Method::Ssr { n: 3, tau: 7, stop: StopRule::Full },
                            i as u64,
                        )
                    })
                    .collect();
                let out = replies
                    .iter()
                    .map(|r| {
                        let v = r.recv().unwrap().unwrap();
                        match v.get("answer").unwrap() {
                            Value::Null => None,
                            x => Some(x.i64().unwrap()),
                        }
                    })
                    .collect();
                drop(handle);
                join.join().unwrap();
                out
            })
            .collect();
        assert_eq!(answers[0], answers[1], "scheduler is not deterministic");
    }

    #[test]
    fn lane_estimates_match_admission_currency() {
        use crate::config::StopRule;
        assert_eq!(lane_estimate(Method::Baseline, 12), 1);
        assert_eq!(lane_estimate(Method::SpecReason { tau: 7 }, 12), 1);
        assert_eq!(lane_estimate(Method::Parallel { n: 4, spm: false }, 12), 4);
        // SPM methods clamp to the strategy pool
        assert_eq!(lane_estimate(Method::Parallel { n: 9, spm: true }, 5), 5);
        assert_eq!(lane_estimate(Method::Ssr { n: 9, tau: 7, stop: StopRule::Full }, 5), 5);
    }

    #[test]
    fn pick_next_empty_queue() {
        let q: VecDeque<QueuedJob> = VecDeque::new();
        assert_eq!(pick_next(&q, AdmitPolicy::Fifo, [4, 2, 1], 0), None);
        assert_eq!(pick_next(&q, AdmitPolicy::SmallestFirst, [4, 2, 1], 0), None);
    }

    fn queued(class: QosClass, lanes: usize) -> QueuedJob {
        // the receiver is dropped; pick_next never sends, so a dangling
        // reply sender is fine for these tests
        let (rtx, _rrx) = mpsc::channel();
        let problem =
            problem_from_text(&tokenizer::builtin_vocab(), "1+1").unwrap();
        QueuedJob {
            lanes,
            enqueued: Instant::now(),
            queued_at: Instant::now(),
            deadline: None,
            retries: 0,
            class,
            work: Work::Fresh { problem, method: Method::Baseline, seed: 0, reply: rtx.into() },
        }
    }

    #[test]
    fn weighted_dequeue_interleaves_classes_without_starvation() {
        // queue: 1 interactive buried behind best_effort, plus batch —
        // replay the WRR schedule over weights [4,2,1] and count how
        // often each class is picked across one full period per job
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        for _ in 0..7 {
            q.push_back(queued(QosClass::BestEffort, 1));
        }
        for _ in 0..7 {
            q.push_back(queued(QosClass::Batch, 1));
        }
        for _ in 0..7 {
            q.push_back(queued(QosClass::Interactive, 1));
        }
        let mut picks = [0usize; 3];
        for tick in 0..21u64 {
            let pos = pick_next(&q, AdmitPolicy::Fifo, [4, 2, 1], tick).unwrap();
            let job = q.remove(pos).unwrap();
            picks[job.class.idx()] += 1;
        }
        assert!(q.is_empty());
        // every class drained; the weighted schedule gives interactive
        // the most early slots but nobody is starved
        assert_eq!(picks, [7, 7, 7]);
        // and over the FIRST period (7 ticks), the 4/2/1 split holds
        let mut q2: VecDeque<QueuedJob> = VecDeque::new();
        for c in [QosClass::Interactive, QosClass::Batch, QosClass::BestEffort] {
            for _ in 0..7 {
                q2.push_back(queued(c, 1));
            }
        }
        let mut first = [0usize; 3];
        for tick in 0..7u64 {
            let pos = pick_next(&q2, AdmitPolicy::Fifo, [4, 2, 1], tick).unwrap();
            let job = q2.remove(pos).unwrap();
            first[job.class.idx()] += 1;
        }
        assert_eq!(first, [4, 2, 1]);
    }

    #[test]
    fn weighted_dequeue_falls_through_when_preferred_class_empty() {
        // only best_effort is queued: every tick must still pick it,
        // whatever class the WRR slot prefers
        let mut q: VecDeque<QueuedJob> = VecDeque::new();
        q.push_back(queued(QosClass::BestEffort, 2));
        q.push_back(queued(QosClass::BestEffort, 1));
        for tick in 0..4u64 {
            assert!(pick_next(&q, AdmitPolicy::Fifo, [4, 2, 1], tick).is_some());
        }
        // SmallestFirst still orders by lanes within the class
        let pos = pick_next(&q, AdmitPolicy::SmallestFirst, [4, 2, 1], 0).unwrap();
        assert_eq!(q[pos].lanes, 1);
        // zero weights (all slots weightless) degrade to class-blind
        let pos = pick_next(&q, AdmitPolicy::Fifo, [0, 0, 0], 5).unwrap();
        assert_eq!(pos, 0);
    }
}
