//! Queue-driven autoscaler: a policy loop over the elastic pool
//! (DESIGN.md §12).
//!
//! PR 4 made the shard set elastic but manual (`{"op":"add_shard"}` /
//! `{"op":"remove_shard"}`); this module closes the loop. A small
//! thread samples the pool's live signals every `interval_ms`:
//!
//! * **queue depth** — queued-but-unstarted jobs across all shards,
//! * **admission wait** — how long the oldest queued job has been
//!   waiting (the head-of-line wait a new arrival is about to inherit),
//! * **occupancy** — outstanding lane estimates / (shards x max_lanes),
//! * **interactive p99** — the per-class latency reservoir (DESIGN.md
//!   §14): with `--slo-ms` set, a sustained p99 breach is scale-up
//!   pressure even when queues look shallow (latency is the contract,
//!   depth is only a proxy),
//!
//! smooths them into EWMAs, and applies a [`Policy`]: scale UP when the
//! wait / per-shard queue / SLO-breach EWMAs breach their thresholds,
//! scale DOWN when occupancy stays low with empty queues and the SLO
//! intact. With `--cost-ceiling` set, scale-ups are vetoed once the
//! cumulative backend model-clock (`model_secs`, the shard-seconds
//! bill) reaches the ceiling — overload is then handled by admission
//! control alone rather than by unbounded capacity. Two guards keep it
//! from thrashing the lifecycle primitives:
//!
//! * **hysteresis** — a threshold must be breached on `hysteresis`
//!   *consecutive* evaluations before the policy acts, so one bursty
//!   sample can't flap the pool;
//! * **cooldown** — at least `cooldown_ms` between applied events, so
//!   the pool observes the effect of one decision before the next.
//!
//! Scale-down picks the least-loaded shard (newest on ties) and drains
//! it through `PoolHandle::remove_shard` — with live run migration
//! enabled (`migration`, the default) that drain re-homes in-flight
//! runs at the next step boundary and completes in O(one step), which
//! is what makes an autoscaler on these primitives viable at all
//! (ROADMAP item: "design migration before autoscaling policies land").
//!
//! The policy core ([`Policy::observe`]) is a pure function of the
//! sampled signals so the hysteresis/cooldown behavior is unit-testable
//! without threads; the [`Autoscaler`] wrapper owns the sampling thread
//! and stops promptly on drop (condvar, not sleep).
//!
//! Heterogeneous fleets (`--shard-classes`, DESIGN.md §15) get one
//! policy instance per configured class, each fed that class's slice of
//! `PoolHandle::sample_class_signals` and scaling it independently via
//! `add_shard_of` / class-scoped victims — a draft-heavy backlog grows
//! draft capacity without buying target-heavy iron and vice versa. Each
//! class drains no lower than one shard (`remove_shard` additionally
//! refuses to retire the last target-capable shard), and the fleet-wide
//! `max_shards` ceiling binds across classes.
//!
//! Fault interaction (DESIGN.md §13): every signal the policy consumes
//! comes from `PoolHandle::sample_signals` / `shard_loads`, which count
//! only *healthy* shards — a crashed shard mid-respawn is invisible to
//! the policy (it can neither inflate capacity nor be picked as a
//! scale-down victim), and `remove_shard`'s `min_shards` floor is
//! likewise clamped against healthy shards, so supervision and
//! autoscaling never fight over the same slot.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::admission::QosClass;
use super::metrics::Metrics;
use super::pool::PoolHandle;
use crate::config::{AutoscaleCfg, ShardClass, SsrConfig};
use crate::util::sync::lock_ok;

/// One evaluation's worth of pool signals.
#[derive(Debug, Clone, Copy)]
pub struct Signals {
    /// live shards
    pub shards: usize,
    /// queued-but-unstarted jobs across all shards
    pub queued_jobs: usize,
    /// seconds the oldest queued job has waited (0.0 if none)
    pub oldest_wait_s: f64,
    /// outstanding lane estimates across all shards
    pub outstanding_lanes: u64,
    /// interactive-class p99 latency (seconds; 0.0 before any data) —
    /// the SLO signal (DESIGN.md §14)
    pub interactive_p99_s: f64,
    /// cumulative backend model-clock across all shards (the
    /// shard-seconds bill the cost ceiling is charged against)
    pub model_secs: f64,
}

/// A policy decision the loop should apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Up,
    Down,
}

/// EWMA smoothing factor per evaluation (fixed; the operator tunes the
/// evaluation interval instead).
const EWMA_ALPHA: f64 = 0.3;

/// The pure policy core: EWMAs + hysteresis counters + cooldown clock,
/// advanced one `interval_ms` per [`Policy::observe`] call.
pub struct Policy {
    cfg: AutoscaleCfg,
    min_shards: usize,
    max_lanes: usize,
    /// interactive SLO in seconds (0 = no SLO signal; `--slo-ms`)
    slo_s: f64,
    /// max shard-seconds budget (0 = unlimited; `--cost-ceiling`)
    cost_ceiling_s: f64,
    wait_ewma: f64,
    queue_ewma: f64,
    occ_ewma: f64,
    p99_ewma: f64,
    up_breaches: u32,
    down_breaches: u32,
    /// virtual milliseconds since the last applied event (starts at
    /// cooldown so the first decision only waits out the hysteresis)
    since_event_ms: u64,
}

impl Policy {
    /// Class-scoped policy for heterogeneous fleets (DESIGN.md §15):
    /// one instance per configured [`ShardClass`], fed that class's
    /// slice of `PoolHandle::sample_class_signals`. The class floor is
    /// one — `remove_shard`'s per-class and target-capability guards
    /// are the backstop, the pool-level `min_shards` stays a fleet
    /// total — and capacity is scaled by the class's lane multiplier
    /// (a draft-heavy shard runs twice the lanes, so the same
    /// outstanding work reads as half the occupancy).
    pub fn for_class(cfg: &SsrConfig, class: ShardClass) -> Policy {
        let mut p = Policy::new(cfg);
        p.min_shards = 1;
        p.max_lanes = cfg.max_lanes.max(1).saturating_mul(class.lane_factor().max(1));
        p
    }

    pub fn new(cfg: &SsrConfig) -> Policy {
        Policy {
            cfg: cfg.autoscale,
            min_shards: cfg.min_shards.max(1),
            max_lanes: cfg.max_lanes.max(1),
            slo_s: cfg.qos.slo_ms as f64 / 1000.0,
            cost_ceiling_s: cfg.qos.cost_ceiling_s,
            wait_ewma: 0.0,
            queue_ewma: 0.0,
            occ_ewma: 0.0,
            p99_ewma: 0.0,
            up_breaches: 0,
            down_breaches: 0,
            since_event_ms: cfg.autoscale.cooldown_ms,
        }
    }

    /// Feed one interval's signals; returns the action to apply (the
    /// caller is expected to apply it — the cooldown clock resets).
    pub fn observe(&mut self, s: &Signals) -> Option<Action> {
        self.since_event_ms = self.since_event_ms.saturating_add(self.cfg.interval_ms);
        let a = EWMA_ALPHA;
        self.wait_ewma = a * s.oldest_wait_s + (1.0 - a) * self.wait_ewma;
        self.queue_ewma = a * s.queued_jobs as f64 + (1.0 - a) * self.queue_ewma;
        let capacity = (s.shards.max(1) * self.max_lanes) as f64;
        let occ = s.outstanding_lanes as f64 / capacity;
        self.occ_ewma = a * occ + (1.0 - a) * self.occ_ewma;
        self.p99_ewma = a * s.interactive_p99_s + (1.0 - a) * self.p99_ewma;

        let per_shard_queue = self.queue_ewma / s.shards.max(1) as f64;
        // a sustained interactive-SLO breach is scale-up pressure on
        // its own: depth/wait are throughput proxies, the p99 IS the
        // contract (DESIGN.md §14)
        let slo_breach = self.slo_s > 0.0 && self.p99_ewma > self.slo_s;
        let pressured = self.wait_ewma > self.cfg.scale_up_wait_s
            || per_shard_queue > self.cfg.scale_up_queue
            || slo_breach;
        // scale-down wants sustained slack: low occupancy AND nothing
        // queued right now AND no meaningful head-of-line wait building
        // AND the interactive SLO intact
        let slack = self.occ_ewma < self.cfg.scale_down_occupancy
            && s.queued_jobs == 0
            && self.wait_ewma < self.cfg.scale_up_wait_s * 0.5
            && !slo_breach;
        if pressured {
            self.up_breaches += 1;
            self.down_breaches = 0;
        } else if slack {
            self.down_breaches += 1;
            self.up_breaches = 0;
        } else {
            self.up_breaches = 0;
            self.down_breaches = 0;
        }

        if self.since_event_ms < self.cfg.cooldown_ms {
            return None;
        }
        // cost ceiling: once the cumulative shard-seconds bill reaches
        // the budget, capacity stops growing — overload is handled by
        // admission control (shed/reject) instead of unbounded spend.
        // Scale-DOWN stays allowed: the bill only stops growing faster.
        let cost_capped = self.cost_ceiling_s > 0.0 && s.model_secs >= self.cost_ceiling_s;
        if self.up_breaches >= self.cfg.hysteresis
            && s.shards < self.cfg.max_shards
            && !cost_capped
        {
            self.up_breaches = 0;
            self.down_breaches = 0;
            self.since_event_ms = 0;
            return Some(Action::Up);
        }
        if self.down_breaches >= self.cfg.hysteresis && s.shards > self.min_shards {
            self.up_breaches = 0;
            self.down_breaches = 0;
            self.since_event_ms = 0;
            return Some(Action::Down);
        }
        None
    }
}

/// Apply one scale-up: class-pinned on heterogeneous fleets (the
/// id-indexed class pattern drifts under churn, so the class must be
/// requested explicitly).
fn apply_up(handle: &PoolHandle, metrics: &Arc<Mutex<Metrics>>, class: Option<ShardClass>) {
    let res = match class {
        Some(c) => handle.add_shard_of(c),
        None => handle.add_shard(),
    };
    match res {
        Ok(id) => {
            lock_ok(metrics).record_scale_event(true);
            let tag = class.map(|c| format!(" [{}]", c.name())).unwrap_or_default();
            log::info!("autoscaler: +shard {id}{tag} ({} live)", handle.shards());
        }
        Err(e) => log::debug!("autoscaler: add_shard refused: {e:#}"),
    }
}

/// Apply one scale-down: least-loaded victim (newest shard on ties),
/// scoped to `class` on heterogeneous fleets. `remove_shard`'s
/// min-shards / per-class / target-capability floors may still refuse
/// the pick — refusal is a no-op, not an error.
fn apply_down(handle: &PoolHandle, metrics: &Arc<Mutex<Metrics>>, class: Option<ShardClass>) {
    let loads = match class {
        Some(c) => handle.shard_loads_of(c),
        None => handle.shard_loads(),
    };
    let victim = loads
        .into_iter()
        .min_by_key(|&(id, load)| (load, std::cmp::Reverse(id)))
        .map(|(id, _)| id);
    if let Some(id) = victim {
        match handle.remove_shard(id) {
            Ok(drain_s) => {
                lock_ok(metrics).record_scale_event(false);
                log::info!(
                    "autoscaler: -shard {id} (drained {drain_s:.3}s, {} live)",
                    handle.shards()
                );
            }
            Err(e) => log::debug!("autoscaler: remove_shard refused: {e:#}"),
        }
    }
}

/// The sampling thread wrapper: owns a [`PoolHandle`] clone and applies
/// [`Policy`] decisions via `add_shard` / `remove_shard`. Stop it (or
/// drop it) before expecting the pool to drain — its handle keeps the
/// pool alive.
pub struct Autoscaler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Autoscaler {
    /// Start the policy loop. No-op loop body until signals warrant a
    /// scale event; the thread wakes every `autoscale.interval_ms`.
    pub fn spawn(
        handle: PoolHandle,
        metrics: Arc<Mutex<Metrics>>,
        cfg: &SsrConfig,
    ) -> Autoscaler {
        // heterogeneous fleet: one policy per configured class, each
        // scaling its own slice of the pool independently (DESIGN.md
        // §15); uniform pools keep the single pool-wide policy
        let mut class_policies: Vec<(ShardClass, Policy)> = {
            let mut classes = cfg.shard_classes.clone();
            classes.sort();
            classes.dedup();
            classes.into_iter().map(|c| (c, Policy::for_class(cfg, c))).collect()
        };
        let mut pool_policy =
            if class_policies.is_empty() { Some(Policy::new(cfg)) } else { None };
        let max_total = cfg.autoscale.max_shards;
        let interval = Duration::from_millis(cfg.autoscale.interval_ms.max(1));
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("ssr-autoscaler".into())
            .spawn(move || {
                loop {
                    {
                        let (lock, cv) = &*stop2;
                        let guard = lock_ok(lock);
                        let (guard, _) = cv
                            .wait_timeout_while(guard, interval, |s| !*s)
                            .unwrap_or_else(|e| e.into_inner());
                        if *guard {
                            break;
                        }
                    }
                    // the SLO and the cost bill are fleet-wide signals:
                    // a p99 breach pressures every class up, the cost
                    // ceiling vetoes every class's growth
                    let (interactive_p99_s, model_secs) = {
                        let m = lock_ok(&metrics);
                        (m.class_p99(QosClass::Interactive), m.model_secs)
                    };
                    if let Some(policy) = pool_policy.as_mut() {
                        // one consistent sample, one lock pass per shard
                        let (shards, queued_jobs, oldest_wait_s, outstanding_lanes) =
                            handle.sample_signals();
                        if shards == 0 {
                            continue;
                        }
                        let s = Signals {
                            shards,
                            queued_jobs,
                            oldest_wait_s,
                            outstanding_lanes,
                            interactive_p99_s,
                            model_secs,
                        };
                        match policy.observe(&s) {
                            Some(Action::Up) => apply_up(&handle, &metrics, None),
                            Some(Action::Down) => apply_down(&handle, &metrics, None),
                            None => {}
                        }
                    } else {
                        let per_class = handle.sample_class_signals();
                        for (class, policy) in class_policies.iter_mut() {
                            let Some(&(_, (shards, queued_jobs, oldest_wait_s, lanes))) =
                                per_class.iter().find(|(c, _)| c == class)
                            else {
                                continue;
                            };
                            if shards == 0 {
                                // remove_shard's floor keeps every class
                                // populated; a transiently-crashed class
                                // produces no load signal to act on
                                continue;
                            }
                            let s = Signals {
                                shards,
                                queued_jobs,
                                oldest_wait_s,
                                outstanding_lanes: lanes,
                                interactive_p99_s,
                                model_secs,
                            };
                            match policy.observe(&s) {
                                Some(Action::Up) => {
                                    // each policy caps its own class at
                                    // max_shards; the fleet total holds too
                                    if handle.shards() < max_total {
                                        apply_up(&handle, &metrics, Some(*class));
                                    }
                                }
                                Some(Action::Down) => {
                                    apply_down(&handle, &metrics, Some(*class))
                                }
                                None => {}
                            }
                        }
                    }
                }
                // handle drops here: the autoscaler no longer keeps the
                // pool alive once stopped
            })
            .expect("spawning autoscaler thread");
        Autoscaler { stop, join: Some(join) }
    }

    /// Stop the policy loop and join its thread (idempotent).
    pub fn stop(&mut self) {
        {
            let (lock, cv) = &*self.stop;
            *lock_ok(lock) = true;
            cv.notify_all();
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsrConfig;

    fn test_cfg() -> SsrConfig {
        let mut cfg = SsrConfig::default();
        cfg.autoscale.enabled = true;
        cfg.autoscale.max_shards = 4;
        cfg.autoscale.scale_up_wait_s = 0.1;
        cfg.autoscale.scale_up_queue = 2.0;
        cfg.autoscale.scale_down_occupancy = 0.25;
        cfg.autoscale.interval_ms = 10;
        cfg.autoscale.cooldown_ms = 50;
        cfg.autoscale.hysteresis = 3;
        cfg.max_lanes = 8;
        cfg
    }

    fn pressured(shards: usize) -> Signals {
        Signals {
            shards,
            queued_jobs: 20,
            oldest_wait_s: 1.0,
            outstanding_lanes: (shards * 8) as u64,
            interactive_p99_s: 0.0,
            model_secs: 0.0,
        }
    }

    fn idle(shards: usize) -> Signals {
        Signals {
            shards,
            queued_jobs: 0,
            oldest_wait_s: 0.0,
            outstanding_lanes: 0,
            interactive_p99_s: 0.0,
            model_secs: 0.0,
        }
    }

    #[test]
    fn scale_up_requires_hysteresis_and_respects_max() {
        let cfg = test_cfg();
        let mut p = Policy::new(&cfg);
        // breaches 1 and 2: no action yet
        assert_eq!(p.observe(&pressured(1)), None);
        assert_eq!(p.observe(&pressured(1)), None);
        // breach 3: up
        assert_eq!(p.observe(&pressured(1)), Some(Action::Up));
        // at the ceiling the policy never fires Up
        for _ in 0..50 {
            assert_eq!(p.observe(&pressured(4)), None, "scaled past max_shards");
        }
    }

    #[test]
    fn cooldown_spaces_consecutive_events() {
        let cfg = test_cfg(); // cooldown 50ms = 5 intervals
        let mut p = Policy::new(&cfg);
        let mut ups = 0;
        let mut gap = 0usize;
        let mut gaps = Vec::new();
        for _ in 0..40 {
            gap += 1;
            if p.observe(&pressured(1)) == Some(Action::Up) {
                ups += 1;
                gaps.push(gap);
                gap = 0;
            }
        }
        assert!(ups >= 2, "sustained pressure produced {ups} events");
        // every event after the first waited out the cooldown
        for g in &gaps[1..] {
            assert!(*g >= 5, "events only {g} intervals apart (cooldown is 5)");
        }
    }

    #[test]
    fn scale_down_needs_sustained_slack_and_respects_min() {
        let cfg = test_cfg();
        let mut p = Policy::new(&cfg);
        // min_shards = 1: an idle 1-shard pool must never scale down
        for _ in 0..20 {
            assert_eq!(p.observe(&idle(1)), None);
        }
        // 3 shards fully idle: down after hysteresis
        let mut p = Policy::new(&cfg);
        assert_eq!(p.observe(&idle(3)), None);
        assert_eq!(p.observe(&idle(3)), None);
        assert_eq!(p.observe(&idle(3)), Some(Action::Down));
        // queued work vetoes slack even at low occupancy
        let mut p = Policy::new(&cfg);
        let queued = Signals {
            shards: 3,
            queued_jobs: 1,
            oldest_wait_s: 0.0,
            outstanding_lanes: 0,
            interactive_p99_s: 0.0,
            model_secs: 0.0,
        };
        for _ in 0..20 {
            assert_eq!(p.observe(&queued), None, "scaled down with queued work");
        }
    }

    #[test]
    fn square_wave_load_does_not_flap() {
        // ISSUE acceptance: a square-wave load (bursts separated by idle
        // gaps shorter than the hysteresis window) produces a bounded
        // number of scale events, not one per flip.
        let cfg = test_cfg(); // hysteresis 3, cooldown 5 intervals
        let mut p = Policy::new(&cfg);
        let mut shards = 1usize;
        let mut events = 0usize;
        // 10 cycles of [2 pressured, 2 idle] intervals: neither side
        // ever holds for 3 consecutive evaluations
        for _ in 0..10 {
            for _ in 0..2 {
                if let Some(a) = p.observe(&pressured(shards)) {
                    events += 1;
                    shards = match a {
                        Action::Up => shards + 1,
                        Action::Down => shards.saturating_sub(1).max(1),
                    };
                }
            }
            for _ in 0..2 {
                if let Some(a) = p.observe(&idle(shards)) {
                    events += 1;
                    shards = match a {
                        Action::Up => shards + 1,
                        Action::Down => shards.saturating_sub(1).max(1),
                    };
                }
            }
        }
        assert_eq!(events, 0, "hysteresis failed: {events} events on a fast square wave");

        // a SLOW square wave (each phase longer than hysteresis +
        // cooldown) may scale, but at most one event per phase
        let mut p = Policy::new(&cfg);
        let mut shards = 1usize;
        for cycle in 0..4 {
            let mut phase_events = 0;
            for _ in 0..10 {
                if let Some(a) = p.observe(&pressured(shards)) {
                    phase_events += 1;
                    shards = match a {
                        Action::Up => (shards + 1).min(4),
                        Action::Down => shards.saturating_sub(1).max(1),
                    };
                }
            }
            assert!(phase_events <= 2, "cycle {cycle}: {phase_events} up-events in one burst");
            let mut phase_events = 0;
            for _ in 0..10 {
                if let Some(a) = p.observe(&idle(shards)) {
                    phase_events += 1;
                    shards = match a {
                        Action::Up => (shards + 1).min(4),
                        Action::Down => shards.saturating_sub(1).max(1),
                    };
                }
            }
            assert!(phase_events <= 2, "cycle {cycle}: {phase_events} down-events in one lull");
        }
        assert!(shards >= 1 && shards <= 4, "shards left the [min, max] band: {shards}");
    }

    #[test]
    fn slo_breach_is_scale_up_pressure_and_vetoes_scale_down() {
        let mut cfg = test_cfg();
        cfg.qos.slo_ms = 200; // 0.2 s interactive SLO
        let mut p = Policy::new(&cfg);
        // shallow queues, zero wait — but the p99 is triple the SLO:
        // pressure comes from the latency contract alone
        let breach = Signals {
            shards: 1,
            queued_jobs: 0,
            oldest_wait_s: 0.0,
            outstanding_lanes: 4,
            interactive_p99_s: 0.6,
            model_secs: 0.0,
        };
        assert_eq!(p.observe(&breach), None);
        assert_eq!(p.observe(&breach), None);
        assert_eq!(p.observe(&breach), Some(Action::Up));
        // an otherwise-idle pool breaching its SLO must not scale DOWN
        let mut p = Policy::new(&cfg);
        let idle_breach = Signals { shards: 3, ..breach };
        for _ in 0..20 {
            assert_ne!(p.observe(&idle_breach), Some(Action::Down), "drained under SLO breach");
        }
        // without --slo-ms the same p99 is not pressure
        let mut p = Policy::new(&test_cfg());
        for _ in 0..20 {
            assert_eq!(p.observe(&Signals { shards: 1, ..breach }), None);
        }
    }

    #[test]
    fn cost_ceiling_vetoes_scale_up_but_not_scale_down() {
        let mut cfg = test_cfg();
        cfg.qos.cost_ceiling_s = 100.0;
        let mut p = Policy::new(&cfg);
        // over-budget sustained pressure: Up is vetoed forever
        let over = Signals { model_secs: 150.0, ..pressured(1) };
        for _ in 0..30 {
            assert_eq!(p.observe(&over), None, "scaled up past the cost ceiling");
        }
        // under budget the same pressure scales up normally
        let mut p = Policy::new(&cfg);
        let under = Signals { model_secs: 50.0, ..pressured(1) };
        assert_eq!(p.observe(&under), None);
        assert_eq!(p.observe(&under), None);
        assert_eq!(p.observe(&under), Some(Action::Up));
        // scale-down is never cost-vetoed
        let mut p = Policy::new(&cfg);
        let idle_over = Signals { model_secs: 150.0, ..idle(3) };
        assert_eq!(p.observe(&idle_over), None);
        assert_eq!(p.observe(&idle_over), None);
        assert_eq!(p.observe(&idle_over), Some(Action::Down));
    }

    #[test]
    fn class_policies_scale_against_a_floor_of_one() {
        use crate::config::ShardClass;
        let mut cfg = test_cfg();
        cfg.min_shards = 2;
        // pool-level policy: a 2-shard idle pool is already at its floor
        let mut p = Policy::new(&cfg);
        for _ in 0..20 {
            assert_eq!(p.observe(&idle(2)), None);
        }
        // class policy: the same slice drains toward one shard —
        // remove_shard's per-class floor guards the last member, the
        // pool min_shards is a fleet total, not a per-class bound
        let mut p = Policy::for_class(&cfg, ShardClass::TargetHeavy);
        assert_eq!(p.observe(&idle(2)), None);
        assert_eq!(p.observe(&idle(2)), None);
        assert_eq!(p.observe(&idle(2)), Some(Action::Down));
        // draft-heavy capacity doubles with its lane multiplier: 7
        // outstanding lanes on 2x8-lane shards is ~0.44 occupancy for a
        // balanced class (never sustained slack) but ~0.22 for a
        // draft-heavy class (slack -> down)
        let busy = Signals { outstanding_lanes: 7, ..idle(2) };
        let mut bal = Policy::for_class(&cfg, ShardClass::Balanced);
        let mut dh = Policy::for_class(&cfg, ShardClass::DraftHeavy);
        let mut bal_down = false;
        let mut dh_down = false;
        for _ in 0..20 {
            bal_down |= bal.observe(&busy) == Some(Action::Down);
            dh_down |= dh.observe(&busy) == Some(Action::Down);
        }
        assert!(!bal_down, "balanced class drained at ~0.44 occupancy");
        assert!(dh_down, "draft-heavy class never saw its doubled capacity");
    }

    #[test]
    fn ewmas_discount_stale_pressure() {
        let cfg = test_cfg();
        let mut p = Policy::new(&cfg);
        let _ = p.observe(&pressured(1));
        let _ = p.observe(&pressured(1));
        // pressure vanishes before the third breach: counters reset
        for _ in 0..30 {
            let act = p.observe(&idle(1));
            assert_eq!(act, None);
        }
        assert_eq!(p.up_breaches, 0);
    }
}
